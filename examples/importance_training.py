"""End-to-end driver: train a ~100M-param llama-family model with
importance-sampled optimization (Zhao & Zhang 2014 — the paper's §1
motivation) for a few hundred steps, with checkpointing, vs a uniform
baseline at the same number of optimizer steps.

    PYTHONPATH=src python examples/importance_training.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro import pex
from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg
from repro.nn.param import count_params, unbox
from repro.optim import adamw
from repro.optim.schedule import linear_warmup_cosine
from repro.train.trainer import TrainConfig, Trainer


def model_100m():
    """~100M params: 8L, d=512, llama-style."""
    return LMConfig(
        name="llama-100m", n_layers=8, d_model=512, vocab=32768,
        attn=AttnCfg(d_model=512, n_heads=8, n_kv=4, head_dim=64,
                     head_multiple=1),
        mlp=MlpCfg(d_model=512, d_ff=2048), dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_importance_ckpt")
    args = ap.parse_args()

    aspec = registry.get("llama3.2-1b")          # family entry points
    cfg = model_100m()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    print(f"params: {count_params(params) / 1e6:.1f}M")

    spec = PexSpec(enabled=True, method="auto")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=args.seq,
                      global_batch=args.batch, seed=11)
    ocfg = adamw.AdamWConfig(
        lr=1e-3, schedule=linear_warmup_cosine(20, args.steps))

    # consumer plans (DESIGN.md §9): Importance = norms on the 4x pool
    # → sample ∝ ‖∇L_j‖ → ONE weighted backward on the sub-batch, with
    # the pool norms reported alongside. The uniform baseline is the
    # classic grads+norms fused step.
    plans = {
        "importance": (pex.Importance(args.batch // 4, smoothing=0.2),
                       pex.Grads()),
        "norms": (pex.Norms(), pex.Grads()),
    }
    results = {}
    for mode, consumers in plans.items():
        t = Trainer(loss_fn, params, spec, ocfg,
                    TrainConfig(consumers=consumers, steps=args.steps,
                                log_every=25,
                                ckpt_dir=f"{args.ckpt}_{mode}",
                                ckpt_every=100),
                    dcfg)
        print(f"\n=== mode={mode} "
              f"({'pool=4x, sample ∝ ‖∇L_j‖' if mode == 'importance' else 'uniform'}) ===")
        ms = t.train()
        # the fused importance plan reports the exact candidate-POOL
        # loss (its norms pass computes it), so both modes normalize by
        # pool tokens
        tok = args.batch * args.seq
        final = np.mean([m["loss"] for m in ms[-10:]]) / tok
        results[mode] = final
        print(f"final loss/token: {final:.4f}")

    print(f"\nimportance={results['importance']:.4f} "
          f"uniform={results['norms']:.4f} "
          f"(importance uses 4x-smaller gradient batches picked by norm)")


if __name__ == "__main__":
    main()
