"""Quickstart: per-example gradient norms for free (Goodfellow 2015).

Builds a small llama-family model, runs ONE backward pass through the
pex v2 ``Engine`` that yields both the parameter gradients and every
example's gradient norm, and cross-checks against the naive
per-example method (paper §3).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import pex
from repro.configs.common import ShapeSpec
from repro.core import naive
from repro.models import registry
from repro.nn.param import unbox


def main():
    arch = registry.get("llama3.2-1b")
    cfg = arch.smoke()                      # reduced config, CPU-friendly
    mod = registry.family_module(arch)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    B, S = 8, 32
    batch = registry.make_train_batch(arch, cfg, ShapeSpec("q", "train", S, B))

    # Instrumentation is declared ONCE on the Engine; the model receives
    # a Tap collector and every dense layer registers (H, Z̄) with it.
    eng = pex.Engine(pex.PexSpec(method="auto"))
    loss_fn = registry.make_loss_fn_v2(arch, cfg)

    # Consumers declare WHAT you want; the Engine fuses them into the
    # minimal program — here ONE backward pass yields grads + all
    # per-example squared norms (§4–§5; DESIGN.md §9).
    res = jax.jit(lambda p, b: eng.step(
        loss_fn, p, b, consumers=[pex.Norms(), pex.Grads()]))(params, batch)
    norms = jnp.sqrt(jnp.sum(res.sq_norms, -1))
    print(f"loss = {float(res.loss):.3f}")
    print("per-example ‖∇L_j‖ :", np.array2string(np.asarray(norms),
                                                  precision=2))

    # Cross-check vs the naive method the paper replaces (§3): the same
    # model with the inert tap is the plain, uninstrumented network.
    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        lv, _ = loss_fn(p, b1, pex.NULL)
        return lv[0]

    oracle = jnp.sqrt(naive.per_example_sq_norms(single, params, batch))
    err = float(jnp.max(jnp.abs(norms - oracle) / oracle))
    print(f"max rel err vs naive per-example backprop: {err:.2e}")
    assert err < 1e-4
    print("OK — exact, in one backward pass.")


if __name__ == "__main__":
    main()
