"""Multi-tenant LoRA fine-tuning: hundreds of per-user adapters share
every batch, and each tenant gets independent DP guarantees — all from
ONE fused pass per step (DESIGN.md §14).

Each tenant owns a LoRA adapter (frozen shared base + low-rank delta).
A step takes an interleaved mixed-tenant batch; the service sorts it by
tenant, gathers the active adapter rows, computes exact per-example
gradient norms through the segmented tap (one launch across ALL
tenants), clips per example, draws each tenant's DP noise from
``fold_in(rng, tenant_id)`` — so tenant t's update is bit-identical to
what it would be if t trained alone — and scatters the updated rows
back. Admission and eviction recycle store slots between steps.

    PYTHONPATH=src python examples/lora_multitenant.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import lora
from repro.nn.linear import linear
from repro.tenancy import AdapterStore, TenantService

D_IN, D_OUT, RANK, SEQ = 32, 16, 4, 12
CLIP, SIGMA, LR = 1.0, 0.2, 0.05


def main():
    key = jax.random.PRNGKey(0)
    base_w = jax.random.normal(key, (D_IN, D_OUT)) * 0.2

    # one LoRA site per tenant; B starts random so step-0 grads are live
    def init_fn(k):
        return {"proj": lora.init_pair(k, D_IN, D_OUT, RANK, 8.0,
                                       boxed=False, b_std=0.3)}

    # user loss: per-example adapters ride a leading (B,) axis; the
    # factors route through tap.dense_batched inside nn.linear
    def loss_fn(adapters, data, tap):
        p = {"w": base_w, "lora": adapters["proj"]}
        z = linear(p, data["x"], tap=tap, group="all")
        tok = jnp.sum(jnp.square(z - data["y"]), axis=-1)
        return jnp.sum(tap.token_loss(tok), axis=1), {}

    store = AdapterStore(init_fn, capacity=512, key=jax.random.fold_in(key, 1))
    svc = TenantService(store, loss_fn, clip_norm=CLIP, noise_std=SIGMA,
                        lr=LR)

    rs = np.random.RandomState(0)
    tenants = rs.choice(20_000, size=300, replace=False)
    for step in range(5):
        # interleaved mixed batch: ~120 tenants, ragged 1-4 examples each
        active = rs.choice(tenants, size=120, replace=False)
        owner = np.concatenate(
            [np.full(rs.randint(1, 5), t) for t in active])
        rs.shuffle(owner)
        B = len(owner)
        x = jax.random.normal(jax.random.fold_in(key, 10 + step),
                              (B, SEQ, D_IN))
        y = jax.random.normal(jax.random.fold_in(key, 100 + step),
                              (B, SEQ, D_OUT))
        res = svc.step({"x": x, "y": y}, owner,
                       rng=jax.random.fold_in(key, 1000 + step))
        clipped = float(jnp.mean(res.clip_coef < 1.0)) * 100
        print(f"step {step}: B={B:3d} tenants={len(res.tenant_ids):3d} "
              f"resident={store.n_active:3d} loss={float(res.loss):9.1f} "
              f"clipped={clipped:4.0f}% "
              f"worst tenant loss={float(jnp.max(res.tenant_loss)):7.1f}")

        if step == 2:
            # mid-run churn: retire the coldest third, queue newcomers
            for t in [int(t) for t in store.tenants[:100]]:
                svc.evict(t)
            svc.submit(*rs.choice(50_000, size=40, replace=False).tolist())
            admitted = svc.admit_pending()
            print(f"         evicted 100 tenants, admitted "
                  f"{len(admitted)} from the queue")

    # the per-tenant DP contract: tenant t's noised update depended only
    # on its own examples and fold_in(rng, t) — batchmates never leak in
    # (tests/test_lora_tenancy.py proves this against a 110-tenant
    # per-tenant oracle loop, to allclose on norms/clip/updates)
    t = int(owner[0])
    n_t = int(np.sum(owner == t))
    row = store.gather(np.array([t]))
    print(f"\ntenant {t}: {n_t} examples in the last mixed batch; "
          f"adapter row head {np.asarray(row['proj'].a).ravel()[:3]}")


if __name__ == "__main__":
    main()
