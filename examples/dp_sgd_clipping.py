"""Per-example gradient clipping (paper §6) as DP-SGD: clip every
example's gradient to C, add Gaussian noise σ·C, train. The clipping
costs one norms pass + one weighted backward — never materializing a
single per-example gradient.

    PYTHONPATH=src python examples/dp_sgd_clipping.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api
from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    pex = PexSpec(enabled=True, method="auto")
    loss_fn = registry.make_loss_fn(aspec, cfg, pex)

    t = Trainer(loss_fn, params, pex,
                adamw.AdamWConfig(lr=1e-3),
                TrainConfig(mode="clip", clip_norm=0.5, noise_std=0.1,
                            steps=50, log_every=10),
                DataConfig(vocab=cfg.vocab, seq=64, global_batch=16))
    ms = t.train()
    print(f"\nfinal loss {ms[-1]['loss']:.2f}; "
          f"max per-example norm seen {max(m['norm_max'] for m in ms):.2f} "
          f"(every example's contribution clipped to 0.5)")

    # show the §6 semantics directly: post-clip per-example influence
    batch = t.data.batch_at(0)
    res = api.clipped_value_and_grads(loss_fn, t.params, batch, pex, 16, 0.5)
    c = api.clip_coefficients(res.sq_norms, 0.5)
    print("clip coefficients c_j:",
          np.array2string(np.asarray(c), precision=3))


if __name__ == "__main__":
    main()
