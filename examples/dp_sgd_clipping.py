"""Per-example gradient clipping (paper §6) as DP-SGD: clip every
example's gradient to C, add Gaussian noise σ·C, train — declared as a
consumer plan that the Engine fuses into one tapped forward, one
activation backward, and one reweighted backward, with
gradient-noise-scale telemetry riding along for free (DESIGN.md §9).
No per-example gradient is ever materialized.

    PYTHONPATH=src python examples/dp_sgd_clipping.py
"""
import jax
import numpy as np

from repro import pex
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    spec = pex.PexSpec(method="auto")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)

    # the step IS the consumer list: clipping, DP noise, and GNS
    # telemetry off one fused pass (the trainer injects step rngs)
    consumers = (pex.Norms(), pex.Clip(0.5), pex.Noise(0.1), pex.GNS())
    t = Trainer(loss_fn, params, spec,
                adamw.AdamWConfig(lr=1e-3),
                TrainConfig(consumers=consumers, steps=50, log_every=10),
                DataConfig(vocab=cfg.vocab, seq=64, global_batch=16))
    ms = t.train()
    print(f"\nfinal loss {ms[-1]['loss']:.2f}; "
          f"max per-example norm seen {max(m['norm_max'] for m in ms):.2f} "
          f"(every example's contribution clipped to 0.5); "
          f"B_simple last step {ms[-1]['gns']:.3g}")

    # show the §6 semantics directly: post-clip per-example influence
    eng = pex.Engine(spec)
    batch = t.data.batch_at(0)
    res = eng.step(loss_fn, t.params, batch,
                   consumers=[pex.Clip(0.5),
                              pex.Noise(0.1, jax.random.PRNGKey(1))])
    print("clip coefficients c_j:",
          np.array2string(np.asarray(res.clip_coef), precision=3))

    # per-TOKEN clipping is the same consumer at token granularity:
    # each token's loss term is reweighted by its own (B, S)
    # contribution norm — exact through every tap (DESIGN.md §9)
    res_t = eng.step(loss_fn, t.params, batch,
                     consumers=[pex.Clip(0.5, granularity="token"),
                                pex.Grads()])
    c = np.asarray(res_t.token_weights)
    print(f"per-token clip: {np.mean(c < 1.0) * 100:.0f}% of tokens "
          f"clipped (coefficient map shape {c.shape})")


if __name__ == "__main__":
    main()
