"""Per-example gradient clipping (paper §6) as DP-SGD: clip every
example's gradient to C, add Gaussian noise σ·C, train. The clipping
costs one norms pass + one weighted backward — never materializing a
single per-example gradient. Everything routes through the pex v2
``Engine``.

    PYTHONPATH=src python examples/dp_sgd_clipping.py
"""
import jax
import numpy as np

from repro import pex
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    spec = pex.PexSpec(method="auto")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)

    t = Trainer(loss_fn, params, spec,
                adamw.AdamWConfig(lr=1e-3),
                TrainConfig(mode="clip", clip_norm=0.5, noise_std=0.1,
                            steps=50, log_every=10),
                DataConfig(vocab=cfg.vocab, seq=64, global_batch=16))
    ms = t.train()
    print(f"\nfinal loss {ms[-1]['loss']:.2f}; "
          f"max per-example norm seen {max(m['norm_max'] for m in ms):.2f} "
          f"(every example's contribution clipped to 0.5)")

    # show the §6 semantics directly: post-clip per-example influence
    eng = pex.Engine(spec, clip_norm=0.5, noise_std=0.1)
    batch = t.data.batch_at(0)
    res = eng.clipped_step(loss_fn, t.params, batch,
                           rng=jax.random.PRNGKey(1))
    c = pex.clip_coefficients(res.sq_norms, 0.5)
    print("clip coefficients c_j:",
          np.array2string(np.asarray(c), precision=3))


if __name__ == "__main__":
    main()
