"""Batched serving demo: prefill + step-locked decode with slot
recycling, on a reduced qwen2 config (GQA + QKV bias + KV cache).

    PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs.common import ShapeSpec
from repro.models import registry
from repro.nn.param import unbox
from repro.serve.engine import Engine, Request


def main():
    arch = "qwen2-7b"
    aspec = registry.get(arch)
    cfg = registry.serving_config(aspec, aspec.smoke(),
                                  ShapeSpec("demo", "decode", 64, 4))
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(7), cfg))
    eng = Engine(arch, cfg, params, batch_slots=4, temperature=0.8)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab, size=n)),
                    max_new=8) for n in (3, 7, 5, 4, 6, 2)]
    done = eng.generate(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt={r.prompt} → out={r.out}")
    assert all(len(r.out) == r.max_new for r in done)
    print(f"\nserved {len(done)} requests in batches of 4 "
          f"(prefill + incremental decode, shared KV cache buffers)")


if __name__ == "__main__":
    main()
