"""Segmented direct-norm sweep: XLA scan/segment_sum vs the Pallas
sort-based kernel, plus validation of the two-sided segmented dispatch
model (core.norms.pick_segmented).

Times both backends of ``stat_direct_segmented`` across the
(T, p_in, p_out, n_seg) plane — long-T/few-segment points where the
kernel should win, many-tiny-segment points where the run-table padding
prices it out — re-derives the XLA↔Pallas crossover T under the cost
model, measures the actual crossover from a T sweep, and **asserts**
the auto pick is within ``TOL`` of the measured best wherever the
timings are meaningful on this host (both backends: real TPU only —
interpret mode's grid loop is an emulation, not a measurement; the
measured-crossover rows are still recorded on CPU, flagged
``interpret_mode``, for trend eyeballing).

``main(smoke=True)`` is the CI job: tiny shapes, the kernel still
executed (interpret mode) so a regression fails fast, no timing asserts.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms as N

from benchmarks.common import row, time_fn

TOL = 0.15  # picked backend may be at most 15% off the measured best


def _fns(n):
    return {
        "xla": jax.jit(lambda h, z, s: N.stat_direct_segmented(
            h, z, s, n, method="xla")),
        "pallas": jax.jit(lambda h, z, s: N.stat_direct_segmented(
            h, z, s, n, method="pallas")),
    }


def _data(t, pi, po, n, seed=0, drop_frac=0.15):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(t, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, po)), jnp.float32)
    seg = rng.integers(0, n, size=(t,))
    seg = np.where(rng.random(t) < drop_frac, n, seg)
    return h, z, jnp.asarray(seg, jnp.int32)


def run(t=2048, pi=256, po=256, n=8, check=True):
    h, z, seg = _data(t, pi, po, n)
    tag = f"t={t},p={pi}x{po},n={n}"
    on_tpu = jax.default_backend() == "tpu"
    picked = N.pick_segmented(t, pi, po, n, use_pallas=True)
    times = {}
    for name, fn in _fns(n).items():
        times[name] = time_fn(fn, h, z, seg)
        note = f"cost_model_pick={picked}" if name == picked else ""
        row(f"seg.{name}[{tag}]", times[name], note)
    best = min(times.values())
    if check and on_tpu:
        assert times[picked] <= (1 + TOL) * best, (
            f"{tag}: segmented cost model picked {picked} "
            f"({times[picked]:.0f}us) but best is {best:.0f}us "
            f"(> {TOL:.0%} off)")


def measured_crossover(pi=128, po=128, n=8, ts=(64, 128, 256, 512, 1024,
                                                2048, 4096)):
    """Sweep T at fixed (p, n): record both backends' timings, the first
    measured T where the kernel wins, and the cost model's prediction.
    On CPU the Pallas timings come from interpret mode (flagged)."""
    on_tpu = jax.default_backend() == "tpu"
    fns = _fns(n)
    first_win = None
    for t in ts:
        h, z, seg = _data(t, pi, po, n)
        tx = time_fn(fns["xla"], h, z, seg)
        tp = time_fn(fns["pallas"], h, z, seg)
        row(f"seg.sweep_xla[t={t},p={pi}x{po},n={n}]", tx, "")
        row(f"seg.sweep_pallas[t={t},p={pi}x{po},n={n}]", tp,
            "" if on_tpu else "interpret_mode")
        if first_win is None and tp < tx:
            first_win = t
    model_t = N.crossover_t(pi, po, n)
    row(f"seg.crossover[p={pi}x{po},n={n}]", 0.0,
        f"model_t={model_t};measured_t={first_win}"
        + ("" if on_tpu else ";interpret_mode"))


def crossover_report():
    """Cost-model crossover T across (p, n_seg): the kernel needs more
    tokens to win as segments multiply (each extra present segment is
    one more work item per feature block); at many tiny segments it
    never wins and the scan keeps the stat."""
    for pi, po, n in ((128, 128, 8), (256, 256, 8), (512, 512, 64),
                      (1536, 5120, 1440)):
        ct = N.crossover_t(pi, po, n)
        row(f"seg.crossover_model[p={pi}x{po},n={n}]", 0.0,
            f"t={ct}" if ct < (1 << 20) else "never")


def main(smoke=False):
    if smoke:
        # CI: exercise both backends in each dispatch regime at
        # interpreter-friendly shapes; numbers recorded for trend only.
        run(t=256, pi=64, po=64, n=4, check=False)      # kernel regime
        run(t=64, pi=32, po=32, n=48, check=False)      # scan regime
        measured_crossover(pi=32, po=32, n=4, ts=(32, 128, 512))
        crossover_report()
        return
    run(t=2048, pi=256, po=256, n=8)       # kernel regime (long T)
    run(t=4096, pi=512, po=512, n=16)      # deep kernel regime
    run(t=128, pi=64, po=64, n=96)         # many tiny segments → scan
    run(t=1024, pi=1536, po=512, n=64)     # MoE-expert-like geometry
    measured_crossover()
    crossover_report()


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
