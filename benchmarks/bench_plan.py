"""Fused-consumer-plan guard (DESIGN.md §9), pinned by HLO cost.

Asserts the structural claims of the plan layer on compiled programs:

  * ``step(consumers=[])`` lowers to EXACTLY the plain forward — plan
    analysis with nothing demanded never creates taps;
  * ``step([Grads()])`` costs no more than plain ``value_and_grad``;
  * ``step([Clip(C)])`` fits the one-forward budget
    ``cost(norms pass) + (cost(plain grad) − cost(plain forward))`` —
    i.e. one tapped forward + one activation backward + ONE reweighted
    backward, with no second forward (the pre-plan clipped path traced
    the forward twice);
  * ``step([Clip, Noise, GNS])`` costs strictly less than the
    sequential fixed-function calls it replaces (clipped_step +
    grads+norms pass for GNS), and only ε more than the Clip plan
    alone — Noise adds O(n_params) normals, GNS O(n_params)
    reductions, neither a new pass.

Rows are emitted for the BENCH json so the fused/sequential ratio is
diffable across PRs.
"""
import jax
import jax.numpy as jnp

from repro import pex
from repro.configs.common import ShapeSpec
from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec
from repro.models import registry
from repro.nn.param import unbox
from repro.roofline.hlo import compiled_cost

from benchmarks.common import row, time_fn

# the invariant arithmetic lives in repro.analysis.plan_invariants
# (pexlint pass 2); the bench measures once and calls the checks
from repro.analysis.plan_invariants import (BUDGET_TOL, EPS_TOL, EQ_TOL,
                                            backward_budget,
                                            check_backward_budget,
                                            check_empty_plan,
                                            check_fused_epsilon,
                                            check_grads_plan)


def run(b=8, s=64, check=True):
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("plan", "train", s, b))
    spec = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    eng = Engine(spec, clip_norm=1.0)
    key = jax.random.PRNGKey(1)
    tag = f"b={b},s={s}"

    def cost(fn):
        return compiled_cost(jax.jit(fn).lower(params).compile())

    def plain_fwd(p):
        return jnp.sum(loss_fn(p, batch, NULL)[0])

    f_fwd, _ = cost(plain_fwd)
    f_grad, _ = cost(lambda p: jax.value_and_grad(plain_fwd)(p))
    f_empty, _ = cost(lambda p: eng.step(loss_fn, p, batch, []).loss)
    f_gonly, _ = cost(lambda p: eng.step(loss_fn, p, batch,
                                         [pex.Grads()]).grads)
    f_norms, _ = cost(lambda p: eng.step(loss_fn, p, batch,
                                         [pex.Norms()]).sq_norms)
    f_clip, _ = cost(lambda p: eng.step(loss_fn, p, batch,
                                        [pex.Clip(1.0)]).grads)

    def fused(p):
        r = eng.step(loss_fn, p, batch,
                     consumers=[pex.Clip(1.0), pex.Noise(0.1, key),
                                pex.GNS()])
        return r.grads, r.sq_norms, r.gns

    f_fused, _ = cost(fused)

    def sequential(p):
        r1 = eng.clipped_step(loss_fn, p, batch, rng=key, noise_std=0.1)
        r2 = eng.value_grads_and_norms(loss_fn, p, batch)
        return r1.grads, r1.sq_norms, pex.gradient_noise_scale(r2.sq_norms,
                                                               r2.grads)

    f_seq, _ = cost(sequential)

    budget = backward_budget(f_norms, f_grad, f_fwd)
    row(f"plan.fused_step[{tag}]", time_fn(jax.jit(fused), params),
        f"flops={f_fused:.4g}")
    row(f"plan.sequential[{tag}]", time_fn(jax.jit(sequential), params),
        f"flops={f_seq:.4g}")
    row(f"plan.empty_vs_plain_fwd[{tag}]", 0.0, f"{f_empty / f_fwd:.8f}")
    row(f"plan.grads_vs_plain_grad[{tag}]", 0.0, f"{f_gonly / f_grad:.8f}")
    row(f"plan.clip_vs_budget[{tag}]", 0.0, f"{f_clip / budget:.6f}")
    row(f"plan.fused_vs_sequential[{tag}]", 0.0, f"{f_fused / f_seq:.6f}")
    if not check or f_fwd <= 0.0:
        return
    check_empty_plan(f_empty, f_fwd)
    check_grads_plan(f_gonly, f_grad)
    check_backward_budget(f_clip, f_norms, f_grad, f_fwd)
    check_fused_epsilon(f_fused, f_clip)
    assert f_fused < f_seq, (
        f"fused plan not cheaper than the sequential calls it replaces: "
        f"{f_fused} vs {f_seq}")


def main(smoke: bool = False):
    run(b=4, s=16) if smoke else run(b=8, s=64)
