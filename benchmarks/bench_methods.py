"""Norm-estimator cost sweep + dispatch-model validation.

Times factorized / gram / direct on both backends (XLA einsum-scan and
the Pallas kernels) across the (S, p_in, p_out) plane, re-derives the
gram↔direct crossover under each backend's cost model, and **asserts**
that the method ``pick_method`` selects is within ``TOL`` of the
measured best for that backend at every sweep point. The timing
assertion runs for the backend whose timings are meaningful on this
host: XLA always; Pallas only on real TPU (interpret mode's grid loop
is an emulation, not a measurement).

``main(smoke=True)`` is the CI job: tiny shapes, kernels still executed
(interpret mode) so a kernel regression fails fast, no timing asserts.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms as N
from repro.core.norms import crossover_s, pick_method
from repro.kernels import ops

from benchmarks.common import row, time_fn

TOL = 0.15  # picked method may be at most 15% off the measured best


def _backend_fns(use_pallas):
    if use_pallas:
        return {"gram": ops.gram_norm, "direct": ops.direct_norm}
    return {"gram": jax.jit(N.stat_gram), "direct": jax.jit(N.stat_direct)}


def run(b=8, s=128, pi=512, po=512, check=True):
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, po)), jnp.float32)
    tag = f"b={b},s={s},p={pi}x{po}"

    t_fact = time_fn(jax.jit(N.stat_factorized), h, z)
    row(f"methods.factorized[{tag}]", t_fact, "upper_bound")

    on_tpu = jax.default_backend() == "tpu"
    for use_pallas, suffix in ((False, ""), (True, "_pallas")):
        times = {}
        picked = pick_method(s, pi, po, use_pallas=use_pallas)
        for name, fn in _backend_fns(use_pallas).items():
            times[name] = time_fn(fn, h, z)
            note = f"cost_model_pick={picked}" if name == picked else ""
            row(f"methods.{name}{suffix}[{tag}]", times[name], note)
        best = min(times.values())
        measurable = on_tpu if use_pallas else True
        if check and measurable:
            assert times[picked] <= (1 + TOL) * best, (
                f"{tag}{suffix}: cost model picked {picked} "
                f"({times[picked]:.0f}us) but best is {best:.0f}us "
                f"(> {TOL:.0%} off)")


def crossover_report():
    """Re-derived gram↔direct crossover S under each backend's cost
    model. For aligned large dims the Pallas crossover sits ~1.5–1.6×
    later (the triangular grid halves gram's S² term; padding S to the
    128 tile claws some back). Where padding dominates — small or
    asymmetric dims — the two can coincide or even invert."""
    for pi, po in ((512, 512), (256, 256), (1024, 128), (640, 640)):
        sx = crossover_s(pi, po)
        sp = crossover_s(pi, po, use_pallas=True)
        row(f"methods.crossover[p={pi}x{po}]", 0.0,
            f"xla_s={sx};pallas_s={sp}")


def main(smoke=False):
    if smoke:
        # CI: exercise every estimator+kernel at interpreter-friendly
        # shapes, one point per dispatch regime; numbers are recorded
        # for trend eyeballing only.
        run(b=2, s=32, pi=128, po=128, check=False)    # gram regime
        run(b=2, s=256, pi=64, po=64, check=False)     # direct regime
        crossover_report()
        return
    run(b=8, s=64, pi=512, po=512)     # gram regime (s << p)
    run(b=8, s=512, pi=256, po=256)    # past the XLA crossover (s*=128)
    run(b=4, s=1024, pi=256, po=256)   # deep direct regime
    run(b=4, s=256, pi=1024, po=128)   # asymmetric dims, direct regime
    crossover_report()


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
