"""Norm-estimator cost sweep: factorized / gram / direct / pallas-gram
across sequence lengths — validates the adaptive policy's cost model
(gram wins when 2s²(pi+po) < 2s·pi·po, i.e. s < pi·po/(pi+po))."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms as N
from repro.core.norms import pick_method
from repro.kernels import ops

from benchmarks.common import row, time_fn


def run(b=8, s=128, pi=512, po=512):
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, po)), jnp.float32)

    fns = {
        "factorized": jax.jit(N.stat_factorized),
        "gram": jax.jit(N.stat_gram),
        "direct": jax.jit(N.stat_direct),
        "gram_pallas": lambda h, z: ops.gram_norm(h, z),
    }
    tag = f"b={b},s={s},p={pi}x{po}"
    picked = pick_method(s, pi, po)
    base = None
    for name, fn in fns.items():
        t = time_fn(fn, h, z)
        if name == "gram":
            base = t
        note = f"cost_model_pick={picked}" if name == picked else ""
        row(f"methods.{name}[{tag}]", t, note)


def main():
    run(b=8, s=64, pi=512, po=512)     # gram regime (s << p)
    run(b=8, s=512, pi=256, po=256)    # crossover region
    run(b=4, s=1024, pi=256, po=256)   # direct regime (s >> p·p/(p+p))


if __name__ == "__main__":
    main()
