"""pex v2 abstraction-tax guard.

The Engine facade (trace-time Tap collector + v2 loss adapter) must be
pure sugar: the train-step program it traces has to compile to HLO of
the same flop/byte cost as a hand-adapted explicit-accumulator call
into the underlying pass layer (core.passes — what the v1 public API
used to expose). This module lowers both paths for a smoke llama
config, asserts cost equality, and emits the numbers as benchmark rows
so the BENCH json records the (lack of) tax across PRs.
"""
import jax
import jax.numpy as jnp

from repro.configs.common import ShapeSpec
from repro.core import passes
from repro.core.engine import Engine
from repro.core.taps import PexSpec, Tap
from repro.models import registry
from repro.nn.param import unbox
from repro.roofline.hlo import compiled_cost

from benchmarks.common import row, time_fn

REL_TOL = 1e-6   # equal-cost: same program modulo float accounting noise


def run(b=4, s=16, check=True):
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("v2", "train", s, b))
    spec = PexSpec(enabled=True, method="gram")
    loss_v2 = registry.make_loss_fn_v2(aspec, cfg)
    eng = Engine(spec)

    def acc_loss(p, acc, bt):
        tap = Tap(spec, acc=acc)
        lv, aux = loss_v2(p, bt, tap)
        return lv, tap.carry(), aux

    def step_v1(p, bt):
        r = passes.value_grads_and_norms(acc_loss, p, bt, spec, b)
        return r.loss, r.sq_norms, r.grads

    def step_v2(p, bt):
        r = eng.value_grads_and_norms(loss_v2, p, bt)
        return r.loss, r.sq_norms, r.grads

    c1 = jax.jit(step_v1).lower(params, batch).compile()
    c2 = jax.jit(step_v2).lower(params, batch).compile()
    f1, by1 = compiled_cost(c1)
    f2, by2 = compiled_cost(c2)
    tag = f"b={b},s={s}"
    row(f"v2.engine_step[{tag}]", time_fn(jax.jit(step_v2), params, batch),
        f"flops={f2:.4g}")
    row(f"v2.v1_step[{tag}]", time_fn(jax.jit(step_v1), params, batch),
        f"flops={f1:.4g}")
    if f1 <= 0.0 or by1 <= 0.0:
        row(f"v2.flops_ratio[{tag}]", 0.0, "cost_analysis unavailable")
        return
    row(f"v2.flops_ratio[{tag}]", 0.0, f"{f2 / f1:.8f}")
    row(f"v2.bytes_ratio[{tag}]", 0.0, f"{by2 / by1:.8f}")
    if check:
        assert abs(f2 - f1) <= REL_TOL * f1, (
            f"Engine facade changed HLO flops: v1={f1} v2={f2}")
        assert abs(by2 - by1) <= REL_TOL * by1, (
            f"Engine facade changed HLO bytes: v1={by1} v2={by2}")


def main(smoke: bool = False):
    run(b=4, s=16) if smoke else run(b=8, s=64)
