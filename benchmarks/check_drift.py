"""Bench-drift gate: the static cost model vs the committed benchmark
baseline (``BENCH_PR*.json``).

The dispatch layer's method choices (``norms.pick_method`` /
``pick_segmented``) and the crossover tables the docs cite are *model*
outputs; the BENCH files record what the model said — and what was
measured — when the benchmarks last ran. Editing the cost model (a new
flop formula, a changed VPU weight, a retuned kernel cost) silently
invalidates those records: the model in HEAD starts disagreeing with
the picks and crossovers the committed baseline documents, and nothing
fails until someone re-runs the full bench suite.

This gate recomputes every model-derived row of the newest baseline
with the CURRENT code and fails on drift:

  * ``*.crossover[p=AxB]#derived = "xla_s=N;pallas_s=M"`` — recompute
    ``crossover_s`` per backend; > ``--tolerance`` (default 25%)
    relative deviation fails;
  * ``seg.crossover_model[p=AxB,n=N]#derived = "t=N"`` — recompute
    ``crossover_t`` likewise;
  * ``...#derived = "cost_model_pick=X"`` — recompute the pick
    (``pick_method`` for dense rows, ``pick_segmented`` for segmented
    ones); a categorical flip fails outright, and on XLA-measured
    dense rows the picked method's recorded time must be within
    tolerance of the measured best (the model must still pick a
    winner, not just the same name);
  * ``plan.fused_step`` / ``plan.sequential`` / ``v2.engine_step`` /
    ``v2.v1_step`` ``#derived = "flops=N"`` — XLA ``cost_analysis``
    flop telemetry, recomputed by the *static* pexcost walker
    (``analysis.traffic.program_flops``) over abstract re-traces of
    the same step constructions; > tolerance relative deviation fails
    — the cross-validation that keeps the CostReport numbers honest
    against measured baselines;
  * rows marked ``interpret_mode`` or ``upper_bound`` are
    measurements, not model outputs — skipped.

Pure Python + the cost-model functions + abstract jax traces — no
kernels run, no XLA compilation; CI runs it in the lint job.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, List, Tuple

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(_HERE), "src"))

_NAME = re.compile(r"^(?P<base>[\w.]+)\[(?P<cfg>[^\]]*)\]$")


def _parse(name: str) -> Tuple[str, Dict[str, str]]:
    m = _NAME.match(name)
    if not m:
        return name, {}
    cfg = {}
    for part in m.group("cfg").split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            cfg[k] = v
    return m.group("base"), cfg


def _p(cfg: Dict[str, str]) -> Tuple[int, int]:
    p_in, p_out = cfg["p"].split("x")
    return int(p_in), int(p_out)


def _rel(new: float, old: float) -> float:
    return abs(new - old) / max(abs(old), 1.0)


def newest_bench(root: str) -> str:
    """Latest baseline by PR number (BENCH_PR<k>.json)."""
    paths = glob.glob(os.path.join(root, "BENCH_PR*.json"))
    if not paths:
        raise FileNotFoundError(f"no BENCH_PR*.json under {root}")

    def pr(p):
        m = re.search(r"BENCH_PR(\d+)", p)
        return int(m.group(1)) if m else -1

    return max(paths, key=pr)


#: bench flop-telemetry rows the static pexcost walker re-predicts
_PEXCOST_ROWS = ("plan.fused_step", "plan.sequential", "v2.engine_step",
                 "v2.v1_step")


def _pexcost_programs(b: int, s: int) -> Dict[str, object]:
    """Abstract re-traces of the bench_plan/bench_v2_facade step
    constructions the flop-telemetry rows measured (trace-only —
    ``eval_shape`` params, ``train_batch_specs`` batches)."""
    import jax
    from repro import pex
    from repro.configs.common import ShapeSpec
    from repro.core import passes
    from repro.core.engine import Engine
    from repro.core.taps import PexSpec, Tap
    from repro.models import registry
    from repro.nn.param import unbox

    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = jax.eval_shape(
        lambda: unbox(mod.init(jax.random.PRNGKey(0), cfg)))
    batch = registry.train_batch_specs(aspec, cfg,
                                       ShapeSpec("drift", "train", s, b))
    spec = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    eng = Engine(spec, clip_norm=1.0)
    key = jax.random.PRNGKey(1)

    def fused(p, bt):
        r = eng.step(loss_fn, p, bt, [pex.Clip(1.0), pex.Noise(0.1, key),
                                      pex.GNS()])
        return r.grads, r.sq_norms, r.gns

    def sequential(p, bt):
        r1 = eng.clipped_step(loss_fn, p, bt, rng=key, noise_std=0.1)
        r2 = eng.value_grads_and_norms(loss_fn, p, bt)
        return r1.grads, r1.sq_norms, \
            pex.gradient_noise_scale(r2.sq_norms, r2.grads)

    def acc_loss(p, acc, bt):
        tap = Tap(spec, acc=acc)
        lv, aux = loss_fn(p, bt, tap)
        return lv, tap.carry(), aux

    def step_v1(p, bt):
        r = passes.value_grads_and_norms(acc_loss, p, bt, spec, b)
        return r.loss, r.sq_norms, r.grads

    def step_v2(p, bt):
        r = eng.value_grads_and_norms(loss_fn, p, bt)
        return r.loss, r.sq_norms, r.grads

    fns = {"plan.fused_step": fused, "plan.sequential": sequential,
           "v2.engine_step": step_v2, "v2.v1_step": step_v1}
    return {name: jax.make_jaxpr(fn)(params, batch)
            for name, fn in fns.items()}


def _check_pexcost(rows: Dict[str, Tuple[str, Dict[str, str], float]],
                   tolerance: float) -> List[str]:
    """Static flop predictions vs the measured telemetry rows."""
    if not rows:
        return []
    from repro.analysis.traffic import program_flops

    problems: List[str] = []
    programs: Dict[Tuple[int, int], Dict[str, object]] = {}
    for name, (base, cfg, old) in sorted(rows.items()):
        shape = (int(cfg.get("b", 4)), int(cfg.get("s", 16)))
        if shape not in programs:
            programs[shape] = _pexcost_programs(*shape)
        closed = programs[shape].get(base)
        if closed is None:
            continue
        new, _ = program_flops(closed)
        if _rel(new, old) > tolerance:
            problems.append(
                f"{name}: pexcost predicts {new:.4g} flops vs measured "
                f"{old:.4g} ({_rel(new, old):.0%} > {tolerance:.0%})")
    return problems


def check(bench: Dict, tolerance: float = 0.25) -> List[str]:
    """All drift errors of one baseline against the current model."""
    from repro.core import norms

    problems: List[str] = []
    derived = {k[: -len("#derived")]: v for k, v in bench.items()
               if k.endswith("#derived") and isinstance(v, str)}

    pexcost_rows: Dict[str, Tuple[str, Dict[str, str], float]] = {}
    for name, note in sorted(derived.items()):
        if "interpret_mode" in note or note == "upper_bound":
            continue
        base, cfg = _parse(name)

        if base in _PEXCOST_ROWS and note.startswith("flops="):
            pexcost_rows[name] = (base, cfg, float(note[len("flops="):]))
            continue

        if base.endswith(".crossover") and "xla_s=" in note:
            p_in, p_out = _p(cfg)
            rec = dict(kv.split("=") for kv in note.split(";"))
            for key, pallas in (("xla_s", False), ("pallas_s", True)):
                old = int(rec[key])
                new = norms.crossover_s(p_in, p_out, use_pallas=pallas)
                if _rel(new, old) > tolerance:
                    problems.append(
                        f"{name}: {key} drifted {old} -> {new} "
                        f"({_rel(new, old):.0%} > {tolerance:.0%})")
            continue

        if base.endswith(".crossover_model") and note.startswith("t="):
            p_in, p_out = _p(cfg)
            old = int(note[2:])
            new = norms.crossover_t(p_in, p_out, int(cfg["n"]))
            if _rel(new, old) > tolerance:
                problems.append(
                    f"{name}: crossover_t drifted {old} -> {new} "
                    f"({_rel(new, old):.0%} > {tolerance:.0%})")
            continue

        if note.startswith("cost_model_pick="):
            recorded = note[len("cost_model_pick="):]
            if base.startswith("seg."):
                now = norms.pick_segmented(
                    int(cfg["t"]), *_p(cfg), int(cfg["n"]),
                    use_pallas=True)
            else:
                now = norms.pick_method(
                    int(cfg["s"]), *_p(cfg),
                    use_pallas=base.endswith("_pallas"))
            if now != recorded:
                problems.append(
                    f"{name}: cost-model pick flipped "
                    f"{recorded!r} -> {now!r}")
            problems.extend(
                _measured_best(bench, base, cfg, recorded, tolerance))

    problems.extend(_check_pexcost(pexcost_rows, tolerance))
    return problems


def _measured_best(bench: Dict, base: str, cfg: Dict[str, str],
                   pick: str, tolerance: float) -> List[str]:
    """On XLA-measured dense rows: the model's pick must be within
    tolerance of the measured best of the gram/direct pair. Pallas
    rows are interpret-mode on CPU baselines — timing there is not a
    model property."""
    if not base.startswith("methods.") or base.endswith("_pallas"):
        return []
    tail = "[" + ",".join(f"{k}={v}" for k, v in cfg.items()) + "]"
    times = {m: bench.get(f"methods.{m}{tail}")
             for m in ("gram", "direct")}
    if any(t is None for t in times.values()):
        return []
    best = min(times.values())
    picked = times[pick]
    if picked > (1.0 + tolerance) * best:
        return [f"methods.{pick}{tail}: model pick measured at "
                f"{picked:.1f}us, {picked / best:.2f}x the measured "
                f"best ({best:.1f}us) — cost model no longer predicts "
                f"the winner"]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when the cost model drifts from the newest "
                    "committed benchmark baseline")
    ap.add_argument("--bench", default=None,
                    help="baseline JSON (default: newest BENCH_PR*.json "
                         "in the repo root)")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="max relative deviation (default 0.25)")
    args = ap.parse_args(argv)

    path = args.bench or newest_bench(os.path.dirname(_HERE))
    with open(path) as f:
        bench = json.load(f)
    problems = check(bench, tolerance=args.tolerance)
    n_rows = sum(1 for k in bench if k.endswith("#derived"))
    for p in problems:
        print(f"DRIFT {p}")
    print(f"bench-drift: {os.path.basename(path)}, {n_rows} derived "
          f"row(s), {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
