"""Benchmark timing utilities + machine-readable result collection.

Every ``row()`` both prints a CSV line and records it in ``RESULTS`` so
``run.py --json`` can emit a ``name → us_per_call`` map (BENCH_PR2.json)
and the perf trajectory can be diffed across PRs.
"""
import json
import time

import jax

#: (name, us_per_call, derived) triples in emission order.
RESULTS: list[tuple[str, float, str]] = []


def reset() -> None:
    RESULTS.clear()


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in microseconds (post-jit-warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    RESULTS.append((name, us, derived))
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line


def write_json(path: str) -> None:
    """Dump collected rows as {name: us_per_call} (derived notes under
    a parallel "name#derived" key when non-empty)."""
    out = {}
    for name, us, derived in RESULTS:
        out[name] = round(us, 1)
        if derived:
            out[f"{name}#derived"] = derived
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
