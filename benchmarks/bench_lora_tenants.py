"""Multi-tenant LoRA service sweep: the fused per-tenant-DP step vs a
plain (uninstrumented) multi-tenant LoRA step, across adapters/batch —
plus validation of the segmented dispatch model on the rank-r tap
geometries the LoRA factors actually produce.

The service contract (DESIGN.md §14): per-example norms, clipping and
per-tenant noise ride the SAME fused pass as the gradient, so the DP
step must price like the plain step plus a small segmented-stat tax —
asserted ≤ ``OVERHEAD_TOL`` at ≥256 adapters/batch on real hardware.

The LoRA factors tap as (T, d)×(T, r) and (T, r)×(T, o) segmented
stats — one feature side rank-sized. There the kernel's 128-lane
feature padding prices it out (r=8 pads 16×), so ``pick_segmented``
keeps the XLA scan; the sweep records both backends and asserts the
pick is within ``TOL`` of the measured best where timings are real
(TPU; CPU Pallas rows are interpret-mode, recorded for trend only).

``main(smoke=True)`` is the CI job: tiny shapes, no timing asserts.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import pex
from repro.core import norms as N
from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec
from repro.nn import lora
from repro.nn.linear import linear
from repro.tenancy import AdapterStore, assemble

from benchmarks.common import row, time_fn

TOL = 0.15           # picked backend within 15% of the measured best
OVERHEAD_TOL = 0.10  # fused DP step ≤ 10% over the plain LoRA step
LR = 0.1


def _setup(n_tenants, per, d, o, r, s, seed=0):
    key = jax.random.PRNGKey(seed)
    base_w = jax.random.normal(key, (d, o)) * 0.2

    def init_fn(k):
        return {"site": lora.init_pair(k, d, o, r, 2.0 * r, boxed=False,
                                       b_std=0.3)}

    rs = np.random.default_rng(seed)
    owner = np.repeat(np.arange(n_tenants), per)
    rs.shuffle(owner)
    b = len(owner)
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s, d))
    y = jax.random.normal(jax.random.fold_in(key, 2), (b, s, o))
    tb = assemble({"x": x, "y": y}, owner)
    store = AdapterStore(init_fn, capacity=n_tenants, key=key)
    for t in range(n_tenants):
        store.admit(t)
    active = store.gather(tb.unique_tenants)

    def loss(adapters, eb, tap):
        per_ex = jax.tree_util.tree_map(
            lambda v: jnp.take(v, eb["tenant_index"], axis=0), adapters)
        p = {"w": base_w, "lora": per_ex["site"]}
        z = linear(p, eb["x"], tap=tap, group="all")
        tok = jnp.sum(jnp.square(z - eb["y"]), axis=-1)
        return jnp.sum(tap.token_loss(tok), axis=1), {}

    return tb, active, loss


def step_pair(n_tenants=256, per=2, d=256, o=256, r=8, s=64, check=True):
    """Time the fused DP step (norms + clip + per-tenant noise + SGD)
    against the plain multi-tenant LoRA step on the same batch."""
    tb, active, loss = _setup(n_tenants, per, d, o, r, s)
    on_tpu = jax.default_backend() == "tpu"
    eng = Engine(PexSpec())
    cons = [pex.Clip(1.0),
            pex.Noise(0.2, jax.random.PRNGKey(9), scale=1.0,
                      segments=tb.segments())]

    def sgd(a, g):
        return jax.tree_util.tree_map(lambda x, gg: x - LR * gg, a, g)

    fused = jax.jit(lambda a: sgd(a, eng.step(loss, a, tb.batch,
                                              cons).grads))

    def plain_total(a):
        lv, _ = loss(a, tb.batch, NULL)
        return jnp.sum(lv)

    plain = jax.jit(lambda a: sgd(a, jax.grad(plain_total)(a)))

    tag = f"b={tb.batch_size},n={n_tenants},r={r},p={d}x{o}"
    t_f = time_fn(fused, active)
    t_p = time_fn(plain, active)
    over = (t_f - t_p) / t_p
    row(f"tenant.fused_dp[{tag}]", t_f, f"overhead={over:.3f}")
    row(f"tenant.plain[{tag}]", t_p, "")
    if check and on_tpu and n_tenants >= 256:
        assert t_f <= (1 + OVERHEAD_TOL) * t_p, (
            f"{tag}: fused per-tenant-DP step {t_f:.0f}us is "
            f"{over:.0%} over the plain step {t_p:.0f}us "
            f"(> {OVERHEAD_TOL:.0%})")


def rank_geometry_picks(t=4096, d=256, r=8, n=256, check=True):
    """The LoRA factor taps as the dispatch model sees them: segmented
    stats with one rank-sized feature side. Records both backends and
    the model's pick; asserts the pick wherever timings are real."""
    on_tpu = jax.default_backend() == "tpu"
    for pi, po in ((d, r), (r, d)):
        rng = np.random.default_rng(0)
        h = jnp.asarray(rng.normal(size=(t, pi)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(t, po)), jnp.float32)
        seg = jnp.asarray(rng.integers(0, n, size=(t,)), jnp.int32)
        picked = N.pick_segmented(t, pi, po, n, use_pallas=True)
        tag = f"t={t},p={pi}x{po},n={n}"
        times = {}
        for m in ("xla", "pallas"):
            fn = jax.jit(lambda h, z, s, m=m: N.stat_direct_segmented(
                h, z, s, n, method=m))
            times[m] = time_fn(fn, h, z, seg)
            note = f"cost_model_pick={picked}" if m == picked else (
                "" if (m == "xla" or on_tpu) else "interpret_mode")
            row(f"seg.lora_{m}[{tag}]", times[m], note)
        if check and on_tpu:
            best = min(times.values())
            assert times[picked] <= (1 + TOL) * best, (
                f"{tag}: pick_segmented chose {picked} "
                f"({times[picked]:.0f}us) but best is {best:.0f}us")


def dense_rank_estimators(b=8, s=128, d=256, r=8, check=True):
    """gram vs direct on the 3-D dense rank-r stat (shared LoRA factors,
    (B,S,d)×(B,S,r) cotangents): gram's B·S² score matrix dwarfs
    direct's rank-thin HᵀZ̄, so ``pick_method`` must keep direct. Rows
    use the ``lora.`` base — the drift gate recomputes the pick but not
    a measured-best (small-p CPU timings are noise-dominated)."""
    on_tpu = jax.default_backend() == "tpu"
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, r)), jnp.float32)
    picked = N.pick_method(s, d, r)
    tag = f"b={b},s={s},p={d}x{r}"
    times = {}
    for m in ("gram", "direct"):
        fn = jax.jit(lambda h, z, m=m: N.stat_dense(h, z, method=m))
        times[m] = time_fn(fn, h, z)
        row(f"lora.{m}[{tag}]", times[m],
            f"cost_model_pick={picked}" if m == picked else "")
    if check and on_tpu:
        best = min(times.values())
        assert times[picked] <= (1 + TOL) * best, (
            f"{tag}: pick_method chose {picked} ({times[picked]:.0f}us) "
            f"but best is {best:.0f}us")


def crossover_report(d=256, r=8):
    """Cost-model crossover T at the rank-r geometries: the 128-lane
    padding on the rank side means the kernel usually never wins —
    the model must say so, not dispatch a padded launch."""
    for pi, po, n in ((d, r, 64), (r, d, 64), (d, r, 1024), (d, d, 256)):
        ct = N.crossover_t(pi, po, n)
        row(f"seg.crossover_model[p={pi}x{po},n={n}]", 0.0,
            f"t={ct}" if ct < (1 << 20) else "never")


def main(smoke=False):
    if smoke:
        # CI: both backends exercised (Pallas in interpret mode), the
        # fused/plain pair recorded at toy scale, no timing asserts.
        step_pair(n_tenants=16, per=2, d=32, o=32, r=4, s=8, check=False)
        rank_geometry_picks(t=256, d=32, r=4, n=16, check=False)
        dense_rank_estimators(b=2, s=32, d=32, r=4, check=False)
        crossover_report(d=32, r=4)
        return
    step_pair(n_tenants=256, per=2)
    step_pair(n_tenants=1024, per=1)
    step_pair(n_tenants=64, per=8)
    rank_geometry_picks()
    dense_rank_estimators()
    crossover_report()


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
