"""Importance-sampled training (the paper's §1 application): loss curve
vs uniform sampling at matched *gradient-step* budget, plus the cost of
the norm pass on the candidate pool."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.train.trainer import (TrainConfig, Trainer,
                                 consumers_for_mode)

from benchmarks.common import row


def run(steps=30):
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(1), cfg))
    pex = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=32, global_batch=32, seed=5)
    ocfg = adamw.AdamWConfig(lr=3e-3)

    def train(mode):
        cons = consumers_for_mode(mode, 32, candidate_factor=4)
        t = Trainer(loss_fn, params, pex, ocfg,
                    TrainConfig(consumers=cons, steps=steps, log_every=0),
                    dcfg)
        ms = t.train()
        # per-token loss, averaged over last 5 steps
        return np.mean([m["loss"] for m in ms[-5:]]) / (32 * 32)

    final_imp = train("importance")
    final_norm = train("norms")
    row("importance.final_loss_per_tok", final_imp * 1e6,
        f"uniform={final_norm:.4f},importance={final_imp:.4f}")


def main():
    run()


if __name__ == "__main__":
    main()
