import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# ^ MUST precede any jax-touching import (device count locks at first init).

"""Sharded vs single-device per-example-norm throughput.

Times value_and_norms (norms-only) and value_grads_and_norms (grads +
norms) on the smoke model, single-device vs the dist.pex shard_map
pipeline over an 8-way host-CPU data mesh, and reports examples/s.

Host-CPU shards share the same silicon, so this measures the
*pipeline overhead* of the shard_map path (partitioning, psum,
layout), not real scaling — on a TPU pod the shards are physical.

    PYTHONPATH=src python benchmarks/bench_sharded_norms.py
    PYTHONPATH=src python benchmarks/bench_sharded_norms.py --batch 32 --seq 16
"""
import argparse
import sys

import jax

try:
    from benchmarks.common import row, time_fn
except ImportError:   # run as a script: python benchmarks/bench_sharded_norms.py
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import row, time_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--method", default="gram")
    args = ap.parse_args()

    from repro.configs.common import ShapeSpec
    from repro.core.engine import Engine
    from repro.core.taps import PexSpec
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.nn.param import unbox

    aspec = registry.get(args.arch)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    spec = PexSpec(enabled=True, method=args.method)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    batch = registry.make_train_batch(
        aspec, cfg, ShapeSpec("bench", "train", args.seq, args.batch))
    mesh = make_host_mesh(model_parallel=1)
    n_shards = mesh.shape["data"]
    b = args.batch
    print(f"# {args.arch} smoke, B={b} S={args.seq}, "
          f"{n_shards}-way data mesh vs single device")
    print("variant,us,examples_per_s")

    local = Engine(spec)
    sharded = Engine(spec, mesh=mesh)
    cases = {
        "norms_single": jax.jit(lambda p, d: local.value_and_norms(
            loss_fn, p, d).sq_norms),
        "norms_sharded": jax.jit(lambda p, d: sharded.value_and_norms(
            loss_fn, p, d).sq_norms),
        "grads_norms_single": jax.jit(lambda p, d: local.value_grads_and_norms(
            loss_fn, p, d).grads),
        "grads_norms_sharded": jax.jit(lambda p, d: sharded.value_grads_and_norms(
            loss_fn, p, d).grads),
    }
    for name, fn in cases.items():
        us = time_fn(fn, params, batch)
        row(name, us, f"{b / (us * 1e-6):.0f}")


if __name__ == "__main__":
    main()
