"""Paper §5 comparison — the paper's one 'table', measured.

Methods for per-example gradient norms on a minibatch of m examples:
  naive_loop   — m backprops at minibatch 1 (paper §3, literal)
  naive_vmap   — vmap(grad) materializing per-example grads (§3, modern)
  pex_norms    — the paper's method via cotangent taps (norms only)
  pex_combined — grads AND norms in one backward (paper's headline)
  grads_only   — plain backprop (the floor everything is measured against)

Paper's claims to validate: pex ≈ grads_only (negligible extra), and
naive methods are catastrophically slower because they forfeit
minibatch parallelism / materialize m copies of the gradient.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive
from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec

from benchmarks.common import row, time_fn


def _mlp_setup(m=64, d=256, depth=3, seed=0):
    """The paper's setting: an MLP, one weight use per example."""
    rng = np.random.default_rng(seed)
    params = {}
    dims = [d] * (depth + 1)
    for i in range(depth):
        params[f"w{i}"] = jnp.asarray(
            rng.normal(size=(dims[i], dims[i + 1])) / np.sqrt(dims[i]),
            jnp.float32)
    batch = {"x": jnp.asarray(rng.normal(size=(m, d)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}

    def loss_fn(p, b, tap):
        h = b["x"]
        for i in range(depth):
            z = tap.dense(h, p[f"w{i}"], method="factorized")
            h = jnp.tanh(z) if i < depth - 1 else z
        return jnp.sum(jnp.square(h - b["y"]), -1), {}

    return params, batch, loss_fn


def run(m=64, d=256, depth=3):
    params, batch, loss_fn = _mlp_setup(m, d, depth)
    eng = Engine(PexSpec(enabled=True, method="factorized"))

    @jax.jit
    def grads_only(p, b):
        def f(p):
            return jnp.sum(loss_fn(p, b, NULL)[0])
        return jax.grad(f)(p)

    @jax.jit
    def pex_norms(p, b):
        return eng.value_and_norms(loss_fn, p, b).sq_norms

    @jax.jit
    def pex_combined(p, b):
        r = eng.value_grads_and_norms(loss_fn, p, b)
        return r.grads, r.sq_norms

    @jax.jit
    def naive_vmap(p, b):
        def single(p, ex):
            b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
            return loss_fn(p, b1, NULL)[0][0]
        return naive.per_example_sq_norms(single, p, b)

    def naive_loop(p, b):
        # literal paper §3: one backprop per example, minibatch of 1
        outs = []
        for j in range(m):
            ex = jax.tree_util.tree_map(lambda x: x[j:j + 1], b)
            g = _loop_grad(p, ex)
            outs.append(sum(float(jnp.sum(jnp.square(x)))
                            for x in jax.tree_util.tree_leaves(g)))
        return np.asarray(outs)

    @jax.jit
    def _loop_grad(p, ex):
        def f(p):
            return jnp.sum(loss_fn(p, ex, NULL)[0])
        return jax.grad(f)(p)

    # correctness cross-check before timing
    np.testing.assert_allclose(
        np.asarray(jnp.sum(pex_norms(params, batch), -1)),
        np.asarray(naive_vmap(params, batch)), rtol=1e-4)

    t_g = time_fn(grads_only, params, batch)
    t_n = time_fn(pex_norms, params, batch)
    t_c = time_fn(pex_combined, params, batch)
    t_v = time_fn(naive_vmap, params, batch)
    t_l = time_fn(naive_loop, params, batch, warmup=1, iters=3)

    tag = f"m={m},d={d},L={depth}"
    row(f"paper5.grads_only[{tag}]", t_g, "baseline")
    row(f"paper5.pex_combined[{tag}]", t_c,
        f"overhead_vs_grads={t_c / t_g:.2f}x")
    row(f"paper5.pex_norms[{tag}]", t_n,
        f"vs_grads={t_n / t_g:.2f}x")
    row(f"paper5.naive_vmap[{tag}]", t_v,
        f"slower_than_pex={t_v / t_n:.1f}x")
    row(f"paper5.naive_loop[{tag}]", t_l,
        f"slower_than_pex={t_l / t_n:.1f}x")


def main():
    run(m=64, d=256, depth=3)
    run(m=128, d=512, depth=3)


if __name__ == "__main__":
    main()
