"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes the machine-readable ``BENCH_PR4.json`` (name → us_per_call) so
the perf trajectory is diffable across PRs. ``--smoke`` runs the
tiny-shape estimator/kernel sweeps plus the v2-facade guard (the CI
interpret-mode job).

  paper5.*     — the paper's §5 cost comparison (its only table)
  methods.*    — norm-estimator sweep validating the two-sided
                 (backend-aware) dispatch model + crossover derivation
  seg.*        — segmented direct-norm sweep (MoE expert buffers):
                 XLA scan vs the Pallas sort-based kernel, with the
                 measured and cost-model XLA↔Pallas crossover
  clip.*       — §6 clipping: two-pass ghost vs naive
  importance.* — §1 application: importance sampling vs uniform
  tenant.*     — multi-tenant LoRA service: the fused per-tenant-DP
                 step vs the plain multi-tenant step (overhead ≤10%
                 at ≥256 adapters/batch asserted on TPU), plus the
                 segmented dispatch model on rank-r tap geometries
  v2.*         — Engine-facade guard: the v2 path must compile to HLO
                 of the same flop/byte cost as the raw pass layer (no
                 abstraction tax; asserted)
  plan.*       — fused-consumer-plan guard: one fused step([Clip,
                 Noise, GNS]) must cost ≤ the sum of the separate-call
                 passes it replaces, fit the one-forward budget, and
                 == the plain program with consumers=[] (asserted)
"""
import argparse

from benchmarks import (bench_clipping, bench_importance,
                        bench_lora_tenants, bench_methods,
                        bench_paper_table, bench_plan, bench_segmented,
                        bench_v2_facade, common)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_PR10.json",
                    default=None, metavar="PATH",
                    help="write results as {name: us_per_call} JSON "
                         "(default path: BENCH_PR10.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, kernels in interpret mode, no "
                         "timing asserts (the CI job)")
    args = ap.parse_args(argv)

    common.reset()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_methods.main(smoke=True)
        bench_segmented.main(smoke=True)
        bench_lora_tenants.main(smoke=True)
        bench_v2_facade.main(smoke=True)
        bench_plan.main(smoke=True)
    else:
        bench_paper_table.main()
        bench_methods.main()
        bench_segmented.main()
        bench_clipping.main()
        bench_importance.main()
        bench_lora_tenants.main()
        bench_v2_facade.main()
        bench_plan.main()
    if args.json:
        common.write_json(args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
