"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes the machine-readable ``BENCH_PR2.json`` (name → us_per_call) so
the perf trajectory is diffable across PRs. ``--smoke`` runs only the
tiny-shape estimator/kernel sweep (the CI interpret-mode job).

  paper5.*     — the paper's §5 cost comparison (its only table)
  methods.*    — norm-estimator sweep validating the two-sided
                 (backend-aware) dispatch model + crossover derivation
  clip.*       — §6 clipping: two-pass ghost vs naive
  importance.* — §1 application: importance sampling vs uniform
"""
import argparse

from benchmarks import (bench_clipping, bench_importance, bench_methods,
                        bench_paper_table, common)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_PR2.json", default=None,
                    metavar="PATH",
                    help="write results as {name: us_per_call} JSON "
                         "(default path: BENCH_PR2.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, kernels in interpret mode, no "
                         "timing asserts (the CI job)")
    args = ap.parse_args(argv)

    common.reset()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_methods.main(smoke=True)
    else:
        bench_paper_table.main()
        bench_methods.main()
        bench_clipping.main()
        bench_importance.main()
    if args.json:
        common.write_json(args.json)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
