"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  paper5.*     — the paper's §5 cost comparison (its only table)
  methods.*    — norm-estimator sweep validating the adaptive cost model
  clip.*       — §6 clipping: two-pass ghost vs naive
  importance.* — §1 application: importance sampling vs uniform
"""
from benchmarks import (bench_clipping, bench_importance, bench_methods,
                        bench_paper_table)


def main() -> None:
    print("name,us_per_call,derived")
    bench_paper_table.main()
    bench_methods.main()
    bench_clipping.main()
    bench_importance.main()


if __name__ == "__main__":
    main()
