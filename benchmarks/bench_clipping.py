"""Per-example clipping cost (paper §6): two-pass ghost clip vs plain
grads vs naive per-example clip, on a small instrumented transformer."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import naive
from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec
from repro.models import registry
from repro.nn.param import unbox
from repro.configs.common import ShapeSpec

from benchmarks.common import row, time_fn


def run(arch="llama3.2-1b", b=8, s=64):
    aspec = registry.get(arch)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg, ShapeSpec("t", "train", s, b))
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    eng = Engine(PexSpec(enabled=True, method="gram"), clip_norm=1.0)

    @jax.jit
    def grads_only(p):
        def f(p):
            return jnp.sum(loss_fn(p, batch, NULL)[0])
        return jax.grad(f)(p)

    @jax.jit
    def twopass_clip(p):
        return eng.clipped_step(loss_fn, p, batch).grads

    @jax.jit
    def naive_clip(p):
        def single(p, ex):
            b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
            return loss_fn(p, b1, NULL)[0][0]
        pg = naive.per_example_grads(single, p, batch)
        sq = naive.per_example_grad_pytree_norms(pg)
        c = jnp.minimum(1.0, 1.0 / (jnp.sqrt(sq) + 1e-6))
        return jax.tree_util.tree_map(
            lambda g: jnp.einsum("b,b...->...", c, g), pg)

    t_g = time_fn(grads_only, params)
    t_2 = time_fn(twopass_clip, params)
    t_n = time_fn(naive_clip, params)
    tag = f"{arch},b={b},s={s}"
    row(f"clip.grads_only[{tag}]", t_g, "baseline")
    row(f"clip.twopass[{tag}]", t_2, f"vs_grads={t_2 / t_g:.2f}x")
    row(f"clip.naive[{tag}]", t_n, f"slower_than_twopass={t_n / t_2:.1f}x")


def main():
    run()


if __name__ == "__main__":
    main()
