"""Segmented direct-norm kernel: per-segment ||Σ_{t∈seg} h_t z̄_tᵀ||_F²
for token-major layers — the MoE expert capacity buffers.

The MoE expert taps need the direct estimator over *rows of a capacity
buffer*: row t belongs to example seg(t), and example j's partial
gradient for one expert is G_j = Σ_{t: seg(t)=j} h_t z̄_tᵀ. Until this
kernel existed that stat ran as an XLA ``lax.scan`` over token blocks
with a ``segment_sum`` scatter per block — the last stat hot path with
no Pallas kernel (ROADMAP). The scatter formulation keeps a dense
``(B, chunk_in, p_out)`` carry live through the whole scan and
round-trips it through HBM every block.

Here the segment scatter is replaced by a **sort**: the wrapper
(kernels/ops.py) orders rows by segment id, after which each segment is
a contiguous row run and its partial gradient is an ordinary masked
matmul. The run structure is encoded in five scalar-prefetched i32
tables — one entry per *work item* w, a (token block × segment) run:

    blk[w]   token-block index — steers the H / Z̄ panel DMAs
             (the same BlockSpec-index-map trick as the gram kernel's
             triangular tile-pair tables, but data-dependent: the
             tables are jnp arrays computed from the seg ids at trace
             time, not numpy constants)
    r0/r1[w] the run's row range inside the block (rows outside it
             belong to neighbouring segments and are masked to zero)
    seg[w]   output slot (dropped segments and padded work items carry
             r0 == r1 — an all-zero mask — and never fold)
    first/last[w]  run boundaries: ``first`` zeroes the VMEM
             accumulator, ``last`` squares-and-folds it

The grid is ``(k_in, k_out, n_work)`` with the work axis innermost: for
one (C_in, C_out) feature block the whole sorted sequence streams once,
the per-segment partial gradient living only as a (C_in × C_out) f32
VMEM scratch that is reset at ``first`` and squared at ``last``. The
fold lands in a per-(ci, co) output **column** of shape (1, n_seg) via
a one-hot lane select (no dynamic VMEM store — Mosaic-safe); the
wrapper reduces the (k_in·k_out, n_seg) partials. Nothing of size
``(B, p_in, p_out)`` ever exists in HBM or VMEM.

VMEM budget at Tt=128, C_in=C_out=512, f32 inputs:
    2 panels · 128·512·4 B = 512 KiB + scratch 512·512·4 B = 1 MiB
    + out column n_seg·4 B
well under the ~16 MiB/core budget; MXU dims are 128-aligned.

Consecutive work items in the same token block reuse the resident
panels (Pallas skips the DMA when the block index repeats), so HBM
traffic is the sorted panels once per feature block. The wrapper's
sort + gather of both panels is an O(T·log T + T·(p_in+p_out)) XLA
preamble outside the kernel; ``ops.segmented_cost`` deliberately omits
it — it is lower-order against the kernel's O(T·p_in·p_out) MXU work
whenever the kernel is worth launching, and near the crossover the
dispatch is protected instead by the measured-best assertion in
benchmarks/bench_segmented.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def flop_estimate(n_work: int, tile_t: int, p_in: int, p_out: int,
                  n_seg: int) -> int:
    """MXU+fold flops at the padded launch tiles.

    Every work item — including padded dummies, which still issue the
    masked dot on a zero panel — costs 2·Tt·C_in·C_out per (ci, co)
    step, i.e. 2·Tt·p_in·p_out over the whole feature grid; run
    splitting and padding are thus charged at the grid the kernel
    actually launches. Each segment close adds one square-and-reduce
    (2 flops/element) over its (p_in, p_out) partial gradient.
    """
    return int(2 * n_work * tile_t * p_in * p_out
               + 2 * min(n_seg, n_work) * p_in * p_out)


def bytes_estimate(n_work: int, tile_t: int, p_in: int, p_out: int,
                   n_seg: int, *, chunk_in: int, chunk_out: int,
                   itemsize: int = 4) -> int:
    """HBM traffic: each work item streams one H panel per p_out block
    column and one Z̄ panel per p_in block row (panels resident across
    same-block items are still charged — the estimate is a ceiling),
    plus the (k_in·k_out, n_seg) partial outputs."""
    n_ci = p_in // chunk_in
    n_co = p_out // chunk_out
    panels = n_work * tile_t * (chunk_in * n_ci * n_co + chunk_out * n_ci * n_co)
    return int(panels * itemsize + n_ci * n_co * n_seg * 4)


def launch_contract(t: int, p_in: int, p_out: int, n_seg_pad: int,
                    n_work: int, *, tile_t: int = 128, chunk_in: int = 512,
                    chunk_out: int = 512, dtype=jnp.float32):
    """Static launch geometry of :func:`segmented_norm_sorted` at padded
    shapes — the analyzer-checkable contract (kernels/contract.py)."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    return LaunchContract(
        kernel="segmented_norm",
        grid=(max(p_in // chunk_in, 1), max(p_out // chunk_out, 1),
              max(n_work, 1)),
        blocks=(
            Block("h", (tile_t, chunk_in), dtype),
            Block("zbar", (tile_t, chunk_out), dtype),
            Block("out", (1, n_seg_pad), jnp.float32, kind="out",
                  accumulator=True),
            Block("g_acc", (chunk_in, chunk_out), jnp.float32,
                  kind="scratch", accumulator=True),
        ),
        divisibility=(
            Divisibility("t", t, tile_t),
            Divisibility("p_in", p_in, chunk_in),
            Divisibility("p_out", p_out, chunk_out),
            Divisibility("n_seg_pad", n_seg_pad, 128),
        ),
        scalar_prefetch=6,
        # masked HᵀZ̄ contraction per work item across all block columns
        flops=2.0 * max(n_work, 1) * tile_t * p_in * p_out,
    )


def _kernel(blk_ref, r0_ref, r1_ref, seg_ref, first_ref, last_ref,
            h_ref, z_ref, out_ref, g_acc):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(first_ref[w] == 1)
    def _init_scratch():
        g_acc[...] = jnp.zeros_like(g_acc)

    # rows outside [r0, r1) belong to neighbouring segments: zero them on
    # the H side only — a zero row contributes nothing to HᵀZ̄
    rows = jax.lax.broadcasted_iota(jnp.int32, h_ref.shape, 0)
    mask = jnp.logical_and(rows >= r0_ref[w], rows < r1_ref[w])
    hm = jnp.where(mask, h_ref[...], jnp.zeros_like(h_ref))
    g_acc[...] += jax.lax.dot_general(
        hm, z_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(last_ref[w] == 1)
    def _fold():
        partial = jnp.sum(jnp.square(g_acc[...]))
        # one-hot lane select instead of a dynamic VMEM store
        lanes = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
        out_ref[...] += jnp.where(lanes == seg_ref[w], partial, 0.0)


@functools.partial(jax.jit, static_argnames=("n_seg_pad", "tile_t",
                                             "chunk_in", "chunk_out",
                                             "interpret"))
def segmented_norm_sorted(blk: jax.Array, r0: jax.Array, r1: jax.Array,
                          seg: jax.Array, first: jax.Array, last: jax.Array,
                          h: jax.Array, zbar: jax.Array, *, n_seg_pad: int,
                          tile_t: int = 128, chunk_in: int = 512,
                          chunk_out: int = 512,
                          interpret: bool = False) -> jax.Array:
    """h: (T, p_in), zbar: (T, p_out) — rows SORTED by segment id — plus
    the six (n_work,) i32 run tables → (n_seg_pad,) f32 per-segment
    ||G_j||². The wrapper in ops.py builds the tables (ops._run_tables)
    and guarantees T % tile_t == 0, p_in % chunk_in == 0,
    p_out % chunk_out == 0 and n_seg_pad % 128 == 0.
    """
    t, p_in = h.shape
    p_out = zbar.shape[-1]
    assert t % tile_t == 0, (t, tile_t)
    assert p_in % chunk_in == 0, (p_in, chunk_in)
    assert p_out % chunk_out == 0, (p_out, chunk_out)
    assert n_seg_pad % 128 == 0, n_seg_pad
    k_in = p_in // chunk_in
    k_out = p_out // chunk_out
    n_work = blk.shape[0]

    cost = pl.CostEstimate(
        flops=flop_estimate(n_work, tile_t, p_in, p_out, n_seg_pad),
        transcendentals=0,
        bytes_accessed=bytes_estimate(n_work, tile_t, p_in, p_out, n_seg_pad,
                                      chunk_in=chunk_in, chunk_out=chunk_out,
                                      itemsize=h.dtype.itemsize),
    )

    def h_map(ci, co, w, blk_r, r0_r, r1_r, seg_r, first_r, last_r):
        return (blk_r[w], ci)

    def z_map(ci, co, w, blk_r, r0_r, r1_r, seg_r, first_r, last_r):
        return (blk_r[w], co)

    def out_map(ci, co, w, blk_r, r0_r, r1_r, seg_r, first_r, last_r):
        return (ci * k_out + co, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(k_in, k_out, n_work),
        in_specs=[
            pl.BlockSpec((tile_t, chunk_in), h_map),
            pl.BlockSpec((tile_t, chunk_out), z_map),
        ],
        out_specs=pl.BlockSpec((1, n_seg_pad), out_map),
        scratch_shapes=[pltpu.VMEM((chunk_in, chunk_out), jnp.float32)],
    )
    partials = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_in * k_out, n_seg_pad),
                                       jnp.float32),
        cost_estimate=cost,
        interpret=interpret,
    )(blk, r0, r1, seg, first, last, h, zbar)
    return jnp.sum(partials, axis=0)
