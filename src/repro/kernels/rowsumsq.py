"""Fused row-wise Σx² — the O(mnp) extra work of paper §5 as one kernel.

Feeds the ``factorized`` (paper §4) and ``elementwise`` stat paths: one
HBM pass per tensor instead of separate square + reduce HLOs. Inputs
are viewed as (B, N); the grid tiles N, accumulating per-row partials
in the revisited output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def launch_contract(b: int, n: int, *, tile_b: int = 8, tile_n: int = 2048,
                    dtype=jnp.float32):
    """Static launch geometry of :func:`rowsumsq` at padded shapes —
    the analyzer-checkable contract (kernels/contract.py)."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    return LaunchContract(
        kernel="rowsumsq",
        grid=(max(b // tile_b, 1), max(n // tile_n, 1)),
        blocks=(
            Block("x", (tile_b, tile_n), dtype),
            # revisited output block: per-row partials accumulate across
            # the n axis, so the f32 rule applies
            Block("out", (tile_b, 1), jnp.float32, kind="out",
                  accumulator=True),
        ),
        divisibility=(
            Divisibility("b", b, tile_b),
            Divisibility("n", n, tile_n),
        ),
        flops=2.0 * b * n,  # square + accumulate per element
    )


def _kernel(n_k: int, x_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_n", "interpret"))
def rowsumsq(x: jax.Array, *, tile_b: int = 8, tile_n: int = 2048,
             interpret: bool = False) -> jax.Array:
    """x: (B, N) → (B,) f32. Caller pads B % tile_b == 0, N % tile_n == 0."""
    b, n = x.shape
    assert b % tile_b == 0 and n % tile_n == 0, (b, n, tile_b, tile_n)
    grid = (b // tile_b, n // tile_n)
    out = pl.pallas_call(
        functools.partial(_kernel, grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((tile_b, tile_n), lambda i, k: (i, k))],
        out_specs=pl.BlockSpec((tile_b, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:, 0]
