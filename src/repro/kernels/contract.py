"""Launch contracts — each Pallas kernel's geometry as checkable data.

A kernel module declares, next to its ``pallas_call``, a
``launch_contract(...)`` returning the grid, every block that will be
resident in VMEM (input/output panels and scratch accumulators), and
the divisibility constraints its index maps assume. The static
analyzer (``repro.analysis.launch``) then validates a launch *without
compiling it*: tile divisibility against the wrapper's chunk schedule,
estimated VMEM footprint against the per-backend budget, and the
f32-accumulator dtype rule — the failures that today only surface at
Mosaic compile time on a real TPU (or not at all in interpret mode on
CPU, the CI target).

The contract intentionally describes the launch the ``ops.py`` wrapper
would issue (padded shapes, selected tiles), not the logical shapes:
padding bugs and tile-selection regressions are exactly what it exists
to catch.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

#: ~16 MiB of VMEM per TPU core (pallas guide); interpret mode has no
#: hard ceiling but is validated against the TPU budget anyway — the
#: point of the static check is to catch TPU-only failures on CPU CI.
VMEM_BUDGETS = {"tpu": 16 * 1024 * 1024, "interpret": 16 * 1024 * 1024}

#: every partial-sum accumulator (scratch or revisited output block)
#: must accumulate in this dtype — bf16 accumulation loses the low
#: bits of exactly the squared-norm sums the paper's exactness claim
#: rests on.
ACCUMULATOR_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class Block:
    """One VMEM-resident buffer of a launch.

    kind: 'in' | 'out' | 'scratch'. in/out blocks are double-buffered
    by the Pallas pipeline (charged twice in the footprint); scratch
    persists across grid steps (charged once). ``accumulator`` marks
    buffers that hold partial sums across grid steps and are therefore
    subject to the f32 rule.
    """
    name: str
    shape: Tuple[int, ...]
    dtype: object
    kind: str = "in"
    accumulator: bool = False

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class Divisibility:
    """One ``extent % tile == 0`` assumption of the kernel's index maps
    (the asserts at the top of each kernel, promoted to data)."""
    axis: str
    extent: int
    tile: int

    @property
    def ok(self) -> bool:
        return self.tile >= 1 and self.extent >= 0 \
            and self.extent % self.tile == 0


@dataclasses.dataclass(frozen=True)
class LaunchContract:
    """The checkable surface of one ``pallas_call``.

    ``flops`` is the kernel's total useful floating-point work for the
    whole launch (all grid steps) — filled by each ``launch_contract``
    builder from the same arithmetic the kernel body performs, so the
    cost model (``analysis.cost``) can put Pallas launches on the same
    roofline as the surrounding XLA program.
    """
    kernel: str
    grid: Tuple[int, ...]
    blocks: Tuple[Block, ...]
    divisibility: Tuple[Divisibility, ...] = ()
    scalar_prefetch: int = 0
    flops: float = 0.0

    def vmem_bytes(self) -> int:
        """Footprint estimate: pipelined in/out blocks double-buffered,
        scratch resident once."""
        total = 0
        for blk in self.blocks:
            total += blk.bytes * (1 if blk.kind == "scratch" else 2)
        return total

    def hbm_bytes(self) -> int:
        """HBM traffic estimate for the whole launch: every in/out
        block is streamed through VMEM once per grid step it is mapped
        to — grid product × block bytes, scratch excluded (it lives in
        VMEM only). An upper bound when a block is revisited across a
        reduction axis (the pipeline keeps it resident), which is the
        conservative direction for a traffic budget."""
        steps = 1
        for g in self.grid:
            steps *= max(int(g), 1)
        total = 0
        for blk in self.blocks:
            if blk.kind != "scratch":
                total += blk.bytes * steps
        return total


def validate(contract: LaunchContract, backend: str = "tpu") -> list:
    """All violations of one contract (empty list ⇒ launch is
    well-formed). Checked entirely statically."""
    errors = []
    budget = VMEM_BUDGETS.get(backend, VMEM_BUDGETS["tpu"])
    for i, g in enumerate(contract.grid):
        if int(g) < 1:
            errors.append(
                f"{contract.kernel}: grid axis {i} has extent {g} < 1 "
                f"(grid={contract.grid})")
    for d in contract.divisibility:
        if not d.ok:
            errors.append(
                f"{contract.kernel}: {d.axis}={d.extent} is not divisible "
                f"by its tile {d.tile} — the wrapper's padding/chunk "
                f"schedule disagrees with the kernel's index maps")
    used = contract.vmem_bytes()
    if used > budget:
        errors.append(
            f"{contract.kernel}: estimated VMEM footprint {used} B "
            f"({used / 2**20:.2f} MiB) exceeds the {backend} budget "
            f"{budget} B — blocks: "
            + ", ".join(f"{b.name}{b.shape}:{jnp.dtype(b.dtype).name}"
                        for b in contract.blocks))
    for blk in contract.blocks:
        if blk.accumulator and jnp.dtype(blk.dtype) != \
                jnp.dtype(ACCUMULATOR_DTYPE):
            errors.append(
                f"{contract.kernel}: accumulator block {blk.name!r} has "
                f"dtype {jnp.dtype(blk.dtype).name}; partial sums must "
                f"accumulate in "
                f"{jnp.dtype(ACCUMULATOR_DTYPE).name}")
    return errors
