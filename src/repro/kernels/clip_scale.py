"""Row-rescale kernel: z'_j = c_j · z_j  (paper §6's Z̄ modification).

One fused HBM pass: each grid step loads a (tile_s × tile_p) block of
one example's Z̄ and multiplies by that example's scalar coefficient,
read from SMEM via scalar prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def launch_contract(b: int, s: int, p: int, *, tile_s: int = 256,
                    tile_p: int = 512, dtype=jnp.float32):
    """Static launch geometry of :func:`clip_scale` at padded shapes —
    the analyzer-checkable contract (kernels/contract.py)."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    return LaunchContract(
        kernel="clip_scale",
        grid=(b, max(s // tile_s, 1), max(p // tile_p, 1)),
        blocks=(
            Block("z", (1, tile_s, tile_p), dtype),
            Block("out", (1, tile_s, tile_p), dtype, kind="out"),
        ),
        divisibility=(
            Divisibility("s", s, tile_s),
            Divisibility("p", p, tile_p),
        ),
        scalar_prefetch=1,
        flops=float(b) * s * p,  # one multiply per element
    )


def _kernel(c_ref, z_ref, out_ref):
    b = pl.program_id(0)
    out_ref[...] = (z_ref[...].astype(jnp.float32) * c_ref[b]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_s", "tile_p", "interpret"))
def clip_scale(z: jax.Array, c: jax.Array, *, tile_s: int = 256,
               tile_p: int = 512, interpret: bool = False) -> jax.Array:
    """z: (B, S, p), c: (B,) f32 → (B, S, p) same dtype as z."""
    b, s, p = z.shape
    assert s % tile_s == 0 and p % tile_p == 0, (s, p, tile_s, tile_p)
    grid = (b, s // tile_s, p // tile_p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile_s, tile_p), lambda bi, i, j, c_ref: (bi, i, j))],
        out_specs=pl.BlockSpec((1, tile_s, tile_p), lambda bi, i, j, c_ref: (bi, i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(c, z)
