"""Tile-pair Gram kernel: per-example ||H_jᵀZ̄_j||_F² without the S×S Gram.

TPU adaptation of the paper's §4 quantity for weight-shared (sequence)
layers. The identity

    s_j = Σ_{t,t'} <h_t, h_t'> <z̄_t, z̄_t'>

is evaluated tile-by-tile: for each example b and each pair of sequence
tiles (i, j), two Ts×Ts MXU dots build the H-gram and Z̄-gram partials,
chunked over the (possibly different) feature dims; their elementwise
product is reduced into a per-example scalar. Nothing of size S×S ever
exists — the working set is four (Ts × C) row panels + two Ts×Ts f32
scratch accumulators in VMEM.

Grid: (B, S/Ts, S/Ts, K) with K = max(p_in/C_in, p_out/C_out) feature
chunks — the H-gram and Z̄-gram are chunked *independently* (C_in over
p_in, C_out over p_out), so asymmetric feature dims each pad only to
their own chunk size instead of the larger tensor's. The k axis is the
innermost (fastest) so the scratch accumulators for a given (i, j)
complete before the product is folded into the output. Feature chunks
beyond a tensor's own chunk count are masked with ``pl.when`` (their
index map clamps, so the loads stay in bounds).

VMEM budget at Ts=128, C_in=C_out=512, bf16 inputs:
    4 panels · 128·512·2 B = 512 KiB   + 2 scratch · 128·128·4 B = 128 KiB
well under the ~16 MiB/core budget; MXU dims (128, 512) are aligned to
the 128×128 systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(k_in: int, k_out: int, n_k: int,
            h_i_ref, h_j_ref, z_i_ref, z_j_ref, out_ref, a_acc, b_acc):
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init_scratch():
        a_acc[...] = jnp.zeros_like(a_acc)
        b_acc[...] = jnp.zeros_like(b_acc)

    @pl.when(k < k_in)
    def _acc_h_gram():
        a_acc[...] += jax.lax.dot_general(
            h_i_ref[0], h_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k < k_out)
    def _acc_z_gram():
        b_acc[...] += jax.lax.dot_general(
            z_i_ref[0], z_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fold():
        partial = jnp.sum(a_acc[...] * b_acc[...])

        @pl.when(jnp.logical_and(i == 0, j == 0))
        def _set():
            out_ref[0, 0] = partial

        @pl.when(jnp.logical_or(i != 0, j != 0))
        def _add():
            out_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("tile_s", "chunk_in",
                                              "chunk_out", "interpret"))
def gram_norm(h: jax.Array, zbar: jax.Array, *, tile_s: int = 128,
              chunk_in: int = 512, chunk_out: int = 512,
              interpret: bool = False) -> jax.Array:
    """h: (B, S, p_in), zbar: (B, S, p_out) → (B,) f32.

    Caller guarantees S % tile_s == 0, p_in % chunk_in == 0 and
    p_out % chunk_out == 0 (the ops.py wrapper pads with zeros, which
    contribute nothing). The two feature dims are chunked independently
    so an asymmetric pair never over-pads the smaller one.
    """
    b, s, p_in = h.shape
    _, _, p_out = zbar.shape
    assert s % tile_s == 0, (s, tile_s)
    assert p_in % chunk_in == 0, (p_in, chunk_in)
    assert p_out % chunk_out == 0, (p_out, chunk_out)
    k_in, k_out = p_in // chunk_in, p_out // chunk_out
    n_k = max(k_in, k_out)
    n_s = s // tile_s

    def h_map(bi, i, j, k):
        return (bi, i, jnp.minimum(k, k_in - 1))

    def h_map_j(bi, i, j, k):
        return (bi, j, jnp.minimum(k, k_in - 1))

    def z_map(bi, i, j, k):
        return (bi, i, jnp.minimum(k, k_out - 1))

    def z_map_j(bi, i, j, k):
        return (bi, j, jnp.minimum(k, k_out - 1))

    grid = (b, n_s, n_s, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, k_in, k_out, n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_s, chunk_in), h_map),
            pl.BlockSpec((1, tile_s, chunk_in), h_map_j),
            pl.BlockSpec((1, tile_s, chunk_out), z_map),
            pl.BlockSpec((1, tile_s, chunk_out), z_map_j),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bi, i, j, k: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_s, tile_s), jnp.float32),
            pltpu.VMEM((tile_s, tile_s), jnp.float32),
        ],
        interpret=interpret,
    )(h, h, zbar, zbar)[:, 0]
