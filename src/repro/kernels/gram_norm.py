"""Tile-pair Gram kernel: per-example ||H_jᵀZ̄_j||_F² without the S×S Gram.

TPU adaptation of the paper's §4 quantity for weight-shared (sequence)
layers. The identity

    s_j = Σ_{t,t'} <h_t, h_t'> <z̄_t, z̄_t'>

is evaluated tile-by-tile: for each example b and each pair of sequence
tiles (i, j), two Ts×Ts MXU dots build the H-gram and Z̄-gram partials,
chunked over the (possibly different) feature dims; their elementwise
product is reduced into a per-example scalar. Nothing of size S×S ever
exists — the working set is four (Ts × C) row panels + two Ts×Ts f32
scratch accumulators in VMEM.

Symmetry halving (the default): both Grams are symmetric, so the
summand for (i, j) equals the one for (j, i). The triangular grid
``(B, n_s(n_s+1)/2, K)`` visits only pairs with i ≤ j — the flat pair
index is decoded through two scalar-prefetched i32 row/col tables
(``pltpu.PrefetchScalarGridSpec``), which the BlockSpec index maps read
to steer the panel DMAs — and folds off-diagonal contributions with
weight 2. MXU FLOPs and HBM panel traffic drop by 2n_s/(n_s+1) → 2×
versus the full (B, n_s, n_s, K) grid, which is kept (``triangular=
False``) as the regression oracle for the symmetry optimisation.

Feature chunking: K = max(p_in/C_in, p_out/C_out) — the H-gram and
Z̄-gram are chunked *independently* (C_in over p_in, C_out over p_out),
so asymmetric feature dims each pad only to their own chunk size
instead of the larger tensor's. The k axis is the innermost (fastest)
so the scratch accumulators for a given pair complete before the
product is folded into the output. Feature chunks beyond a tensor's
own chunk count are masked with ``pl.when`` (their index map clamps,
so the loads stay in bounds).

VMEM budget at Ts=128, C_in=C_out=512, bf16 inputs:
    4 panels · 128·512·2 B = 512 KiB   + 2 scratch · 128·128·4 B = 128 KiB
well under the ~16 MiB/core budget; MXU dims (128, 512) are aligned to
the 128×128 systolic array.

Both grids attach a ``pl.CostEstimate`` built from :func:`flop_estimate`
so ``compiled.cost_analysis()`` on TPU reports the true (halved) MXU
work. (On CPU the interpreter lowers the grid to a loop whose body XLA
counts once — see roofline/analysis.py — so the flop model, not the
CPU cost_analysis, is the source of truth for the 2× claim.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def flop_estimate(b: int, s: int, p_in: int, p_out: int, *,
                  tile_s: int = 128, triangular: bool = True) -> int:
    """MXU+fold flops the kernel issues for padded shapes (b, s, p_*).

    Per visited tile pair: 2·Ts²·p_in (H-gram dots) + 2·Ts²·p_out
    (Z̄-gram dots) + ~2·Ts² for the elementwise product-and-reduce fold.
    The triangular grid visits n_s(n_s+1)/2 pairs instead of n_s².
    """
    n_s = s // tile_s
    pairs = n_s * (n_s + 1) // 2 if triangular else n_s * n_s
    per_pair = 2 * tile_s * tile_s * (p_in + p_out) + 2 * tile_s * tile_s
    return int(b * pairs * per_pair)


def bytes_estimate(b: int, s: int, p_in: int, p_out: int, *,
                   tile_s: int = 128, triangular: bool = True,
                   itemsize: int = 4) -> int:
    """HBM panel traffic: four (Ts × chunk) panels per (pair, k) step."""
    n_s = s // tile_s
    pairs = n_s * (n_s + 1) // 2 if triangular else n_s * n_s
    panel_rows = 2 * tile_s * (p_in + p_out)  # h_i+h_j, z_i+z_j over all k
    return int(b * pairs * panel_rows * itemsize + b * 4)


def launch_contract(b: int, s: int, p_in: int, p_out: int, *,
                    tile_s: int = 128, chunk_in: int = 512,
                    chunk_out: int = 512, triangular: bool = True,
                    dtype=jnp.float32):
    """Static launch geometry of :func:`gram_norm` at padded shapes —
    the analyzer-checkable contract (kernels/contract.py)."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    n_s = max(s // tile_s, 1)
    n_k = max(p_in // chunk_in, p_out // chunk_out, 1)
    pairs = n_s * (n_s + 1) // 2 if triangular else n_s * n_s
    grid = (b, pairs, n_k) if triangular else (b, n_s, n_s, n_k)
    return LaunchContract(
        kernel="gram_norm",
        grid=grid,
        blocks=(
            Block("h_i", (1, tile_s, chunk_in), dtype),
            Block("h_j", (1, tile_s, chunk_in), dtype),
            Block("z_i", (1, tile_s, chunk_out), dtype),
            Block("z_j", (1, tile_s, chunk_out), dtype),
            Block("out", (1, 1), jnp.float32, kind="out"),
            Block("a_acc", (tile_s, tile_s), jnp.float32, kind="scratch",
                  accumulator=True),
            Block("b_acc", (tile_s, tile_s), jnp.float32, kind="scratch",
                  accumulator=True),
        ),
        divisibility=(
            Divisibility("s", s, tile_s),
            Divisibility("p_in", p_in, chunk_in),
            Divisibility("p_out", p_out, chunk_out),
        ),
        scalar_prefetch=2 if triangular else 0,
        # two (Ts × p) gram contractions per pair (A += H_iH_jᵀ over p_in,
        # B += Z̄_iZ̄_jᵀ over p_out) plus the ⟨A, B⟩ fold
        flops=float(b) * pairs * (2.0 * tile_s * tile_s * (p_in + p_out)
                                  + 2.0 * tile_s * tile_s),
    )


def _tri_maps(n_s: int) -> tuple[np.ndarray, np.ndarray]:
    """Row/col tile indices of the upper triangle, row-major: pair t ↦
    (ti[t], tj[t]) with ti[t] <= tj[t]."""
    ti = np.concatenate([np.full(n_s - i, i, np.int32) for i in range(n_s)])
    tj = np.concatenate([np.arange(i, n_s, dtype=np.int32)
                         for i in range(n_s)])
    return ti, tj


def _kernel_tri(k_in: int, k_out: int, n_k: int, ti_ref, tj_ref,
                h_i_ref, h_j_ref, z_i_ref, z_j_ref, out_ref, a_acc, b_acc):
    t = pl.program_id(1)
    k = pl.program_id(2)
    i = ti_ref[t]
    j = tj_ref[t]

    @pl.when(k == 0)
    def _init_scratch():
        a_acc[...] = jnp.zeros_like(a_acc)
        b_acc[...] = jnp.zeros_like(b_acc)

    @pl.when(k < k_in)
    def _acc_h_gram():
        a_acc[...] += jax.lax.dot_general(
            h_i_ref[0], h_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k < k_out)
    def _acc_z_gram():
        b_acc[...] += jax.lax.dot_general(
            z_i_ref[0], z_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fold():
        # off-diagonal pairs stand in for their mirrored twin
        weight = jnp.where(i == j, 1.0, 2.0).astype(jnp.float32)
        partial = weight * jnp.sum(a_acc[...] * b_acc[...])

        @pl.when(t == 0)
        def _set():
            out_ref[0, 0] = partial

        @pl.when(t != 0)
        def _add():
            out_ref[0, 0] += partial


def _kernel_full(k_in: int, k_out: int, n_k: int,
                 h_i_ref, h_j_ref, z_i_ref, z_j_ref, out_ref, a_acc, b_acc):
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init_scratch():
        a_acc[...] = jnp.zeros_like(a_acc)
        b_acc[...] = jnp.zeros_like(b_acc)

    @pl.when(k < k_in)
    def _acc_h_gram():
        a_acc[...] += jax.lax.dot_general(
            h_i_ref[0], h_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k < k_out)
    def _acc_z_gram():
        b_acc[...] += jax.lax.dot_general(
            z_i_ref[0], z_j_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _fold():
        partial = jnp.sum(a_acc[...] * b_acc[...])

        @pl.when(jnp.logical_and(i == 0, j == 0))
        def _set():
            out_ref[0, 0] = partial

        @pl.when(jnp.logical_or(i != 0, j != 0))
        def _add():
            out_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("tile_s", "chunk_in",
                                             "chunk_out", "triangular",
                                             "interpret"))
def gram_norm(h: jax.Array, zbar: jax.Array, *, tile_s: int = 128,
              chunk_in: int = 512, chunk_out: int = 512,
              triangular: bool = True,
              interpret: bool = False) -> jax.Array:
    """h: (B, S, p_in), zbar: (B, S, p_out) → (B,) f32.

    Caller guarantees S % tile_s == 0, p_in % chunk_in == 0 and
    p_out % chunk_out == 0 (the ops.py wrapper pads with zeros, which
    contribute nothing). The two feature dims are chunked independently
    so an asymmetric pair never over-pads the smaller one.
    ``triangular=False`` runs the redundant full (i, j) grid — kept as
    the regression oracle for the symmetry halving.
    """
    b, s, p_in = h.shape
    _, _, p_out = zbar.shape
    assert s % tile_s == 0, (s, tile_s)
    assert p_in % chunk_in == 0, (p_in, chunk_in)
    assert p_out % chunk_out == 0, (p_out, chunk_out)
    k_in, k_out = p_in // chunk_in, p_out // chunk_out
    n_k = max(k_in, k_out)
    n_s = s // tile_s

    cost = pl.CostEstimate(
        flops=flop_estimate(b, s, p_in, p_out, tile_s=tile_s,
                            triangular=triangular),
        transcendentals=0,
        bytes_accessed=bytes_estimate(b, s, p_in, p_out, tile_s=tile_s,
                                      triangular=triangular,
                                      itemsize=h.dtype.itemsize),
    )
    scratch = [
        pltpu.VMEM((tile_s, tile_s), jnp.float32),
        pltpu.VMEM((tile_s, tile_s), jnp.float32),
    ]
    out_shape = jax.ShapeDtypeStruct((b, 1), jnp.float32)

    if not triangular:
        def h_map(bi, i, j, k):
            return (bi, i, jnp.minimum(k, k_in - 1))

        def h_map_j(bi, i, j, k):
            return (bi, j, jnp.minimum(k, k_in - 1))

        def z_map(bi, i, j, k):
            return (bi, i, jnp.minimum(k, k_out - 1))

        def z_map_j(bi, i, j, k):
            return (bi, j, jnp.minimum(k, k_out - 1))

        return pl.pallas_call(
            functools.partial(_kernel_full, k_in, k_out, n_k),
            grid=(b, n_s, n_s, n_k),
            in_specs=[
                pl.BlockSpec((1, tile_s, chunk_in), h_map),
                pl.BlockSpec((1, tile_s, chunk_in), h_map_j),
                pl.BlockSpec((1, tile_s, chunk_out), z_map),
                pl.BlockSpec((1, tile_s, chunk_out), z_map_j),
            ],
            out_specs=pl.BlockSpec((1, 1), lambda bi, i, j, k: (bi, 0)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            cost_estimate=cost,
            interpret=interpret,
        )(h, h, zbar, zbar)[:, 0]

    ti, tj = _tri_maps(n_s)
    n_tri = ti.shape[0]

    def h_map_i(bi, t, k, ti_r, tj_r):
        return (bi, ti_r[t], jnp.minimum(k, k_in - 1))

    def h_map_j(bi, t, k, ti_r, tj_r):
        return (bi, tj_r[t], jnp.minimum(k, k_in - 1))

    def z_map_i(bi, t, k, ti_r, tj_r):
        return (bi, ti_r[t], jnp.minimum(k, k_out - 1))

    def z_map_j(bi, t, k, ti_r, tj_r):
        return (bi, tj_r[t], jnp.minimum(k, k_out - 1))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_tri, n_k),
        in_specs=[
            pl.BlockSpec((1, tile_s, chunk_in), h_map_i),
            pl.BlockSpec((1, tile_s, chunk_in), h_map_j),
            pl.BlockSpec((1, tile_s, chunk_out), z_map_i),
            pl.BlockSpec((1, tile_s, chunk_out), z_map_j),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bi, t, k, ti_r, tj_r: (bi, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_kernel_tri, k_in, k_out, n_k),
        grid_spec=grid_spec,
        out_shape=out_shape,
        cost_estimate=cost,
        interpret=interpret,
    )(jnp.asarray(ti), jnp.asarray(tj), h, h, zbar, zbar)[:, 0]
