"""Pallas TPU kernels (BlockSpec VMEM tiling), jit wrappers (ops.py),
and pure-jnp oracles (ref.py) — validated in interpret mode on CPU.

  gram_norm        tile-pair Gram product: per-example ||HᵀZ̄||²_F
                   (triangular grid — symmetry halves the MXU work)
  direct_norm      blocked HᵀZ̄ partial-gradient norm for the
                   long-sequence regime; G never reaches HBM
  rowsumsq         fused row-wise Σx² (paper §4's O(mnp) extra work)
  clip_scale       §6's Z̄ row rescaling
  flash_attention  online-softmax attention, fwd + bwd kernels
"""
