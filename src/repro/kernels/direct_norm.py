"""Direct per-example norm kernel: ||H_jᵀZ̄_j||_F² block-by-block.

The ``direct`` estimator forms each example's partial gradient
``G_j = H_jᵀ Z̄_j ∈ (p_in, p_out)`` and squares it — exact under weight
sharing and, by the cost model in ``core.norms``, cheaper than the
Gram-pair route whenever S is large relative to the harmonic feature
dim (2·S·p_in·p_out < S²·(p_in+p_out)+…, i.e. every long-sequence
workload). Until this kernel existed that regime silently fell back to
a ``lax.scan`` over feature chunks that round-trips (B, chunk, p_out)
partials through HBM.

Here nothing of size (B, p_in, p_out) ever reaches HBM: the grid is
``(B, p_in/C_in, p_out/C_out, S/Ts)`` with the sequence axis innermost,
and each (C_in × C_out) block of G_j lives only as an f32 VMEM scratch
accumulator. Per step one Ts×C_in and one Ts×C_out row panel stream in,
an MXU dot contracts them over the Ts sequence rows into the block
accumulator, and when the sequence sweep completes the block is
squared-and-summed (VPU) straight into the per-example scalar. HBM
traffic is the input panels — each H panel re-read n_co times and each
Z̄ panel n_ci times — plus B output scalars.

VMEM budget at Ts=128, C_in=C_out=512, bf16 inputs:
    2 panels · 128·512·2 B = 256 KiB + scratch 512·512·4 B = 1 MiB
well under the ~16 MiB/core budget; all MXU dims are 128-aligned.

A ``pl.CostEstimate`` from :func:`flop_estimate` is attached so TPU
``cost_analysis()`` reflects the kernel's true MXU work (on CPU the
interpreter's grid loop is counted once by XLA — see gram_norm.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def flop_estimate(b: int, s: int, p_in: int, p_out: int) -> int:
    """MXU+fold flops for padded shapes: the HᵀZ̄ contraction
    2·S·p_in·p_out plus one square-and-reduce (2 flops/element) over
    each example's (p_in, p_out) partial gradient. Chunking only
    reorders this work, so it does not appear here (unlike
    :func:`bytes_estimate`, where it sets the re-read factors)."""
    return int(b * (2 * s * p_in * p_out + 2 * p_in * p_out))


def bytes_estimate(b: int, s: int, p_in: int, p_out: int, *,
                   chunk_in: int = 512, chunk_out: int = 512,
                   itemsize: int = 4) -> int:
    """HBM traffic: H panels re-read once per p_out block column and Z̄
    panels once per p_in block row; G blocks never leave VMEM."""
    n_ci = p_in // chunk_in
    n_co = p_out // chunk_out
    return int(b * s * (p_in * n_co + p_out * n_ci) * itemsize + b * 4)


def launch_contract(b: int, s: int, p_in: int, p_out: int, *,
                    tile_s: int = 128, chunk_in: int = 512,
                    chunk_out: int = 512, dtype=jnp.float32):
    """Static launch geometry of :func:`direct_norm` at padded shapes —
    the analyzer-checkable contract (kernels/contract.py)."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    return LaunchContract(
        kernel="direct_norm",
        grid=(b, max(p_in // chunk_in, 1), max(p_out // chunk_out, 1),
              max(s // tile_s, 1)),
        blocks=(
            Block("h", (1, tile_s, chunk_in), dtype),
            Block("zbar", (1, tile_s, chunk_out), dtype),
            Block("out", (1, 1), jnp.float32, kind="out"),
            Block("g_acc", (chunk_in, chunk_out), jnp.float32,
                  kind="scratch", accumulator=True),
        ),
        divisibility=(
            Divisibility("s", s, tile_s),
            Divisibility("p_in", p_in, chunk_in),
            Divisibility("p_out", p_out, chunk_out),
        ),
        # per-example HᵀZ̄ (2·S·p_in·p_out) plus the Σ(G²) fold
        flops=float(b) * (2.0 * s * p_in * p_out + 2.0 * p_in * p_out),
    )


def _kernel(n_s: int, h_ref, z_ref, out_ref, g_acc):
    ci = pl.program_id(1)
    co = pl.program_id(2)
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _init_scratch():
        g_acc[...] = jnp.zeros_like(g_acc)

    # (C_in × Ts) · (Ts × C_out): contract over the sequence-tile rows
    g_acc[...] += jax.lax.dot_general(
        h_ref[0], z_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(si == n_s - 1)
    def _fold():
        partial = jnp.sum(jnp.square(g_acc[...]))

        @pl.when(jnp.logical_and(ci == 0, co == 0))
        def _set():
            out_ref[0, 0] = partial

        @pl.when(jnp.logical_or(ci != 0, co != 0))
        def _add():
            out_ref[0, 0] += partial


@functools.partial(jax.jit, static_argnames=("tile_s", "chunk_in",
                                             "chunk_out", "interpret"))
def direct_norm(h: jax.Array, zbar: jax.Array, *, tile_s: int = 128,
                chunk_in: int = 512, chunk_out: int = 512,
                interpret: bool = False) -> jax.Array:
    """h: (B, S, p_in), zbar: (B, S, p_out) → (B,) f32.

    Caller guarantees S % tile_s == 0, p_in % chunk_in == 0 and
    p_out % chunk_out == 0 (the ops.py wrapper pads with zeros — zero
    sequence rows add nothing to HᵀZ̄ and zero feature columns add zero
    rows/columns to it, so padding is exact).
    """
    b, s, p_in = h.shape
    _, _, p_out = zbar.shape
    assert s % tile_s == 0, (s, tile_s)
    assert p_in % chunk_in == 0, (p_in, chunk_in)
    assert p_out % chunk_out == 0, (p_out, chunk_out)
    n_s = s // tile_s
    n_ci = p_in // chunk_in
    n_co = p_out // chunk_out

    cost = pl.CostEstimate(
        flops=flop_estimate(b, s, p_in, p_out),
        transcendentals=0,
        bytes_accessed=bytes_estimate(b, s, p_in, p_out, chunk_in=chunk_in,
                                      chunk_out=chunk_out,
                                      itemsize=h.dtype.itemsize),
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_s),
        grid=(b, n_ci, n_co, n_s),
        in_specs=[
            pl.BlockSpec((1, tile_s, chunk_in),
                         lambda bi, ci, co, si: (bi, si, ci)),
            pl.BlockSpec((1, tile_s, chunk_out),
                         lambda bi, ci, co, si: (bi, si, co)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda bi, ci, co, si: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((chunk_in, chunk_out), jnp.float32)],
        cost_estimate=cost,
        interpret=interpret,
    )(h, zbar)[:, 0]
