"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth for the kernel allclose sweeps in
``tests/test_kernels.py`` and are themselves validated against the
naive per-example-gradient oracle in ``tests/test_pex_correctness.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def gram_norm_ref(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """s_j = Σ_{t,t'} <h_t,h_t'><z̄_t,z̄_t'>  (== ||H_jᵀZ̄_j||_F²).

    h: (B, S, p_in), zbar: (B, S, p_out) → (B,) f32.
    """
    hh = jnp.einsum("bsi,bti->bst", h, h, preferred_element_type=_F32)
    zz = jnp.einsum("bsi,bti->bst", zbar, zbar, preferred_element_type=_F32)
    return jnp.sum(hh * zz, axis=(1, 2))


def rowsumsq_ref(x: jax.Array) -> jax.Array:
    """(B, N) → (B,) Σ x² in f32."""
    return jnp.sum(jnp.square(x.astype(_F32)), axis=-1)


def clip_scale_ref(z: jax.Array, c: jax.Array) -> jax.Array:
    """Scale each example's rows: (B, S, p) ⊙ c(B,) → same shape/dtype."""
    return (z * c.reshape((-1,) + (1,) * (z.ndim - 1)).astype(z.dtype))


def flash_attention_ref(q, k, v, *, scale, softcap=None, window=None):
    """Oracle: plain causal GQA attention. q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(_F32), k.astype(_F32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(_F32)).astype(q.dtype)
