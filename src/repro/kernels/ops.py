"""Jit'd public wrappers around the Pallas kernels.

Handle padding to tile boundaries (zero padding is exact for all ops),
backend selection (interpret mode on CPU — the container target;
compiled Mosaic on real TPU), and adaptive tile sizing for small
inputs. These are what ``core.norms``/``core.taps`` call. The
``gram_cost``/``direct_cost`` helpers expose each kernel's flop count
*at the padded shapes this wrapper would actually launch*, so the
dispatch model in ``core.norms`` charges padding waste to the method
that incurs it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import clip_scale as _cs
from repro.kernels import direct_norm as _dn
from repro.kernels import flash_attention as _fa
from repro.kernels import gram_norm as _gn
from repro.kernels import ref as _ref  # noqa: F401  (re-export for callers)
from repro.kernels import rowsumsq as _rs
from repro.kernels import segmented_norm as _sn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


#: Candidate feature-chunk sizes, all 128-lane aligned. Largest first so
#: padding ties resolve to the fewest grid steps.
_CHUNK_CANDIDATES = (512, 384, 256, 128)


def _chunk_for(p: int) -> int:
    """Feature-chunk size for one tensor of width ``p``.

    Small dims (< 512) fit one chunk rounded to the 128-lane boundary.
    Large dims pick from ``_CHUNK_CANDIDATES`` the chunk minimizing
    total padding (ties → largest chunk, i.e. fewest k steps): the old
    always-512 schedule padded 640→1024 (25% waste); now 640 → 5×128
    (exact), 768 → 2×384 (exact), 1152 → 3×384 (exact).
    """
    if p < 512:
        return _round_up(p, 128)
    best = min(_CHUNK_CANDIDATES,
               key=lambda c: (_round_up(p, c) - p, _CHUNK_CANDIDATES.index(c)))
    return best


def _launch_tiles(s: int, p_in: int, p_out: int):
    """(tile_s, chunk_in, chunk_out, s_pad, pi_pad, po_pad) the gram and
    direct wrappers would launch for the given logical shape."""
    tile_s = min(128, _round_up(s, 8))
    chunk_in = _chunk_for(p_in)
    chunk_out = _chunk_for(p_out)
    return (tile_s, chunk_in, chunk_out, _round_up(s, tile_s),
            _round_up(p_in, chunk_in), _round_up(p_out, chunk_out))


def gram_norm(h: jax.Array, zbar: jax.Array, *,
              triangular: bool = True) -> jax.Array:
    """(B,S,p_in),(B,S,p_out) → (B,) f32; pads S and feature dims.

    p_in and p_out get independently-sized chunks: a shared chunk of
    max(p_in, p_out) padded the smaller tensor up to the larger one's
    chunk (e.g. (p_in=1024, p_out=128) zero-padded zbar 4× and burned
    the MXU on all-zero Z̄-gram partials). ``triangular`` (default)
    visits only the upper triangle of sequence-tile pairs — ~2× fewer
    MXU flops; ``False`` keeps the full redundant grid for regression
    tests."""
    b, s, p_in = h.shape
    p_out = zbar.shape[-1]
    tile_s, chunk_in, chunk_out, s_pad, pi_pad, po_pad = _launch_tiles(
        s, p_in, p_out)
    if (s_pad, pi_pad) != (s, p_in):
        h = jnp.pad(h, ((0, 0), (0, s_pad - s), (0, pi_pad - p_in)))
    if (s_pad, po_pad) != (s, p_out):
        zbar = jnp.pad(zbar, ((0, 0), (0, s_pad - s), (0, po_pad - p_out)))
    return _gn.gram_norm(h, zbar, tile_s=tile_s, chunk_in=chunk_in,
                         chunk_out=chunk_out, triangular=triangular,
                         interpret=_interpret())


def gram_cost(s: int, p_in: int, p_out: int, *,
              triangular: bool = True) -> float:
    """Flops the Pallas gram path spends on a (·, s, p_in)×(·, s, p_out)
    layer, per example, **including padding waste** at the launch tiles."""
    tile_s, _, _, s_pad, pi_pad, po_pad = _launch_tiles(s, p_in, p_out)
    return float(_gn.flop_estimate(1, s_pad, pi_pad, po_pad, tile_s=tile_s,
                                   triangular=triangular))


def direct_norm(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """(B,S,p_in),(B,S,p_out) → (B,) ||H_jᵀZ̄_j||²_F; pads all dims.

    Zero-padding is exact: padded sequence rows add nothing to HᵀZ̄ and
    padded feature columns only append zero rows/columns to it."""
    b, s, p_in = h.shape
    p_out = zbar.shape[-1]
    tile_s, chunk_in, chunk_out, s_pad, pi_pad, po_pad = _launch_tiles(
        s, p_in, p_out)
    if (s_pad, pi_pad) != (s, p_in):
        h = jnp.pad(h, ((0, 0), (0, s_pad - s), (0, pi_pad - p_in)))
    if (s_pad, po_pad) != (s, p_out):
        zbar = jnp.pad(zbar, ((0, 0), (0, s_pad - s), (0, po_pad - p_out)))
    return _dn.direct_norm(h, zbar, tile_s=tile_s, chunk_in=chunk_in,
                           chunk_out=chunk_out, interpret=_interpret())


def direct_cost(s: int, p_in: int, p_out: int) -> float:
    """Flops of the Pallas direct path per example at the launch tiles
    (padding waste included)."""
    _, _, _, s_pad, pi_pad, po_pad = _launch_tiles(s, p_in, p_out)
    return float(_dn.flop_estimate(1, s_pad, pi_pad, po_pad))


def _seg_launch_tiles(t: int, p_in: int, p_out: int, n_seg: int):
    """Launch geometry of the segmented kernel for a logical
    (t, p_in)×(t, p_out) stat over ``n_seg`` segments: row tile, feature
    chunks, padded dims, and the static work-item count ``n_work`` — the
    grid the kernel launches regardless of how many (block × segment)
    runs the data actually produces."""
    tile_t = min(128, _round_up(max(t, 1), 8))
    chunk_in = _chunk_for(p_in)
    chunk_out = _chunk_for(p_out)
    t_pad = _round_up(max(t, 1), tile_t)
    n_tb = t_pad // tile_t
    # sorted keys take values in [0, n_seg] (n_seg = the dropped-row
    # bucket); runs per block boundary + one per key change bounds the
    # (block × segment) work items
    n_work = n_tb + min(n_seg + 1, t_pad)
    return (tile_t, chunk_in, chunk_out, t_pad,
            _round_up(p_in, chunk_in), _round_up(p_out, chunk_out),
            n_tb, n_work, _round_up(max(n_seg, 1), 128))


def _run_tables(key_s: jax.Array, t_pad: int, tile_t: int, n_seg: int,
                n_work: int):
    """Work-item tables for rows sorted by segment key.

    ``key_s`` is the (t_pad,) sorted key vector with values in
    [0, n_seg] (n_seg ⇒ dropped/padding row). A *run* is a maximal
    stretch of equal keys inside one token block; runs are enumerated
    in row order, scattered into ``n_work`` static slots (unused slots
    and dropped-segment runs become inert: empty mask, no fold).
    """
    pos = jnp.arange(t_pad, dtype=jnp.int32)
    prev = jnp.concatenate([jnp.full((1,), -1, key_s.dtype), key_s[:-1]])
    is_start = jnp.logical_or(pos % tile_t == 0, key_s != prev)
    run_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    count = jax.ops.segment_sum(jnp.ones_like(pos), run_id,
                                num_segments=n_work)
    starts = jax.ops.segment_min(pos, run_id, num_segments=n_work)
    seg_of = jax.ops.segment_min(key_s.astype(jnp.int32), run_id,
                                 num_segments=n_work)
    active = jnp.logical_and(count > 0, seg_of < n_seg)
    blk = jnp.where(active, starts // tile_t, 0)
    r0 = jnp.where(active, starts % tile_t, 0)
    r1 = jnp.where(active, r0 + count, 0)  # inert items: empty [0, 0)
    prv = jnp.concatenate([jnp.full((1,), -2, jnp.int32), seg_of[:-1]])
    nxt = jnp.concatenate([seg_of[1:], jnp.full((1,), -2, jnp.int32)])
    first = jnp.logical_or(seg_of != prv, jnp.logical_not(active))
    last = jnp.logical_and(active, seg_of != nxt)
    seg_col = jnp.where(active, seg_of, 0)
    return (blk.astype(jnp.int32), r0.astype(jnp.int32),
            r1.astype(jnp.int32), seg_col.astype(jnp.int32),
            first.astype(jnp.int32), last.astype(jnp.int32))


def segmented_norm(h: jax.Array, zbar: jax.Array, seg_ids: jax.Array,
                   n_seg: int) -> jax.Array:
    """(T,p_in),(T,p_out),(T,) int → (n_seg,) ||Σ_{t:seg=j} h_t z̄_tᵀ||².

    Rows with ``seg_ids >= n_seg`` (capacity-dropped tokens, padding)
    are discarded. The segment scatter becomes a stable sort: rows are
    reordered so each segment is contiguous, the run structure goes to
    the kernel as scalar-prefetched index tables, and the per-segment
    partial gradient lives only as a (chunk_in, chunk_out) f32 VMEM
    scratch. Zero-padding of T and both feature dims is exact (zero
    rows/columns add nothing to any HᵀZ̄)."""
    t, p_in = h.shape
    p_out = zbar.shape[-1]
    if n_seg <= 0:
        return jnp.zeros((max(n_seg, 0),), jnp.float32)
    if t == 0:
        return jnp.zeros((n_seg,), jnp.float32)
    (tile_t, chunk_in, chunk_out, t_pad, pi_pad, po_pad,
     _, n_work, n_seg_pad) = _seg_launch_tiles(t, p_in, p_out, n_seg)
    key = jnp.minimum(seg_ids.astype(jnp.int32), n_seg)
    if t_pad != t:
        h = jnp.pad(h, ((0, t_pad - t), (0, 0)))
        zbar = jnp.pad(zbar, ((0, t_pad - t), (0, 0)))
        key = jnp.pad(key, (0, t_pad - t), constant_values=n_seg)
    order = jnp.argsort(key, stable=True)
    key_s = jnp.take(key, order)
    h = jnp.take(h, order, axis=0)
    zbar = jnp.take(zbar, order, axis=0)
    if pi_pad != p_in:
        h = jnp.pad(h, ((0, 0), (0, pi_pad - p_in)))
    if po_pad != p_out:
        zbar = jnp.pad(zbar, ((0, 0), (0, po_pad - p_out)))
    tables = _run_tables(key_s, t_pad, tile_t, n_seg, n_work)
    out = _sn.segmented_norm_sorted(*tables, h, zbar, n_seg_pad=n_seg_pad,
                                    tile_t=tile_t, chunk_in=chunk_in,
                                    chunk_out=chunk_out,
                                    interpret=_interpret())
    return out[:n_seg]


def segmented_cost(t: int, p_in: int, p_out: int, n_seg: int) -> float:
    """Flops the Pallas segmented path spends on a (t, p_in)×(t, p_out)
    stat over ``n_seg`` segments, at the launch tiles it would actually
    use — the *static* work-item grid (run splitting, dummy items, and
    feature/row padding all charged), which is what makes this side of
    the model honest about small-T / many-segment launches."""
    (tile_t, _, _, _, pi_pad, po_pad,
     _, n_work, n_seg_pad) = _seg_launch_tiles(t, p_in, p_out, n_seg)
    return float(_sn.flop_estimate(n_work, tile_t, pi_pad, po_pad,
                                   n_seg_pad))


def rowsumsq(x: jax.Array) -> jax.Array:
    """(B, ...) → (B,) Σx² f32; flattens and pads the trailing dims."""
    b = x.shape[0]
    x = x.reshape(b, -1)
    n = x.shape[1]
    tile_b = 8 if b % 8 == 0 else 1
    tile_n = min(2048, _round_up(n, 128))
    n_pad = _round_up(n, tile_n)
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    return _rs.rowsumsq(x, tile_b=tile_b, tile_n=tile_n,
                        interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_vjp(q, k, v, scale, window=None):
    """Differentiable flash attention: Pallas forward (online softmax,
    lse residual) + Pallas backward (dq / dk / dv kernels). The S²
    score tensor never reaches HBM in either direction."""
    bq = min(256, q.shape[2])
    return _fa.flash_attention(q, k, v, scale=scale, window=window,
                               block_q=bq, block_k=bq,
                               interpret=_interpret())


def _fa_fwd(q, k, v, scale, window):
    bq = min(256, q.shape[2])
    o, lse = _fa.flash_attention(q, k, v, scale=scale, window=window,
                                 block_q=bq, block_k=bq,
                                 interpret=_interpret(), return_lse=True)
    return o, (q, k, v, o, lse)


def _fa_bwd(scale, window, res, g):
    q, k, v, o, lse = res
    bq = min(256, q.shape[2])
    return _fa.flash_attention_bwd(q, k, v, o, lse, g, scale=scale,
                                   window=window, block_q=bq, block_k=bq,
                                   interpret=_interpret())


flash_attention_vjp.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# launch contracts — what each wrapper above WOULD launch, as data.
# The static analyzer (repro.analysis.launch) validates these without
# compiling; each builder must mirror its wrapper's padding/tile
# arithmetic exactly, which is why they live here next to it.
# ---------------------------------------------------------------------------

def gram_contract(b: int, s: int, p_in: int, p_out: int, *,
                  triangular: bool = True, dtype=jnp.float32):
    tile_s, chunk_in, chunk_out, s_pad, pi_pad, po_pad = _launch_tiles(
        s, p_in, p_out)
    return _gn.launch_contract(b, s_pad, pi_pad, po_pad, tile_s=tile_s,
                               chunk_in=chunk_in, chunk_out=chunk_out,
                               triangular=triangular, dtype=dtype)


def direct_contract(b: int, s: int, p_in: int, p_out: int, *,
                    dtype=jnp.float32):
    tile_s, chunk_in, chunk_out, s_pad, pi_pad, po_pad = _launch_tiles(
        s, p_in, p_out)
    return _dn.launch_contract(b, s_pad, pi_pad, po_pad, tile_s=tile_s,
                               chunk_in=chunk_in, chunk_out=chunk_out,
                               dtype=dtype)


def segmented_contract(t: int, p_in: int, p_out: int, n_seg: int, *,
                       dtype=jnp.float32):
    (tile_t, chunk_in, chunk_out, t_pad, pi_pad, po_pad,
     _, n_work, n_seg_pad) = _seg_launch_tiles(t, p_in, p_out, n_seg)
    return _sn.launch_contract(t_pad, pi_pad, po_pad, n_seg_pad, n_work,
                               tile_t=tile_t, chunk_in=chunk_in,
                               chunk_out=chunk_out, dtype=dtype)


def clip_scale_contract(b: int, s: int, p: int, *, dtype=jnp.float32):
    tile_s = min(256, _round_up(s, 8))
    tile_p = min(512, _round_up(p, 128))
    return _cs.launch_contract(b, _round_up(s, tile_s), _round_up(p, tile_p),
                               tile_s=tile_s, tile_p=tile_p, dtype=dtype)


def rowsumsq_contract(b: int, n: int, *, dtype=jnp.float32):
    tile_b = 8 if b % 8 == 0 else 1
    tile_n = min(2048, _round_up(n, 128))
    return _rs.launch_contract(b, _round_up(n, tile_n), tile_b=tile_b,
                               tile_n=tile_n, dtype=dtype)


def attention_contracts(b: int, hq: int, hkv: int, sq: int, sk: int,
                        d: int, *, dtype=jnp.float32):
    bq = min(256, sq)
    return _fa.launch_contracts(b, hq, hkv, sq, sk, d, block_q=bq,
                                block_k=bq, dtype=dtype)


def clip_scale(z: jax.Array, c: jax.Array) -> jax.Array:
    """(B,S,p) ⊙ c(B,) → (B,S,p); pads S and p, slices back only when
    padding was actually applied (the common aligned case is copy-free)."""
    b, s, p = z.shape
    tile_s = min(256, _round_up(s, 8))
    tile_p = min(512, _round_up(p, 128))
    s_pad, p_pad = _round_up(s, tile_s), _round_up(p, tile_p)
    padded = (s_pad, p_pad) != (s, p)
    zp = jnp.pad(z, ((0, 0), (0, s_pad - s), (0, p_pad - p))) if padded else z
    out = _cs.clip_scale(zp, c.astype(jnp.float32), tile_s=tile_s,
                         tile_p=tile_p, interpret=_interpret())
    return out[:, :s, :p] if padded else out
