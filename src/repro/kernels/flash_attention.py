"""Flash attention (causal, GQA, optional softcap/window) — Pallas TPU.

The §Perf Cell-C analysis (EXPERIMENTS.md) shows the dense-arch memory
term is dominated by the S² f32 score tensor hitting HBM. This kernel
keeps scores VMEM-resident with the online-softmax recurrence:

    m ← max(m, rowmax(S_blk));  P = exp(S_blk − m)
    l ← l·corr + rowsum(P);     acc ← acc·corr + P·V_blk

Grid (B, Hq, Sq/bq, Sk/bk), k-block innermost; scratch (acc, m, l)
carries the running state per (b, h, qi). GQA is handled in the index
maps (kv head = q head // rep — no KV broadcast materialized). Causal
blocks above the diagonal contribute nothing and are masked; gemma2's
softcap folds into the score transform; a static sliding window adds a
second mask term.

VMEM at bq=bk=256, D=128, bf16 in / f32 scratch:
  q(64 KB) + k(64 KB) + v(64 KB) + acc(128 KB) + m,l(2 KB) ≪ 16 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _scores(q_ref, k_ref, scale, softcap, window, qi, kj, bq, bk):
    """Masked (softcapped) score block + mask, shared by fwd and bwd."""
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    return jnp.where(mask, s, NEG_INF), mask


def _kernel(scale: float, softcap: Optional[float], window: Optional[int],
            bq: int, bk: int, n_k: int,
            q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip fully-masked blocks (strictly above the causal diagonal)
    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _compute():
        s, _ = _scores(q_ref, k_ref, scale, softcap, window, qi, kj, bq, bk)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l))[:, 0]


def launch_contracts(b: int, hq: int, hkv: int, sq: int, sk: int, d: int, *,
                     block_q: int = 256, block_k: int = 256,
                     dtype=jnp.float32):
    """Static launch geometry of the forward, dq, and dkv kernels — the
    analyzer-checkable contracts (kernels/contract.py). Returns one
    contract per ``pallas_call`` the differentiable attention op
    issues."""
    from repro.kernels.contract import Block, Divisibility, LaunchContract
    rep = max(hq // max(hkv, 1), 1)
    n_q = max(sq // block_q, 1)
    n_k = max(sk // block_k, 1)
    div = (
        Divisibility("hq % hkv", hq, max(hkv, 1)),
        Divisibility("sq", sq, block_q),
        Divisibility("sk", sk, block_k),
    )
    # per (batch, query-head): QKᵀ and PV are each 2·sq·sk·d; the
    # backward kernels recompute P and add the dP/dQ (resp. dK/dV)
    # contractions on top
    attn_flops = float(b) * hq * sq * sk * d
    fwd = LaunchContract(
        kernel="flash_attention.fwd",
        grid=(b, hq, n_q, n_k),
        blocks=(
            Block("q", (1, 1, block_q, d), dtype),
            Block("k", (1, 1, block_k, d), dtype),
            Block("v", (1, 1, block_k, d), dtype),
            Block("o", (1, 1, block_q, d), dtype, kind="out"),
            Block("lse", (1, 1, block_q), jnp.float32, kind="out"),
            Block("acc", (block_q, d), jnp.float32, kind="scratch",
                  accumulator=True),
            Block("m", (block_q, 1), jnp.float32, kind="scratch",
                  accumulator=True),
            Block("l", (block_q, 1), jnp.float32, kind="scratch",
                  accumulator=True),
        ),
        divisibility=div,
        flops=4.0 * attn_flops,
    )
    dq = LaunchContract(
        kernel="flash_attention.bwd_dq",
        grid=(b, hq, n_q, n_k),
        blocks=(
            Block("q", (1, 1, block_q, d), dtype),
            Block("k", (1, 1, block_k, d), dtype),
            Block("v", (1, 1, block_k, d), dtype),
            Block("do", (1, 1, block_q, d), dtype),
            Block("lse", (1, 1, block_q), jnp.float32),
            Block("delta", (1, 1, block_q), jnp.float32),
            Block("dq", (1, 1, block_q, d), dtype, kind="out"),
            Block("dq_acc", (block_q, d), jnp.float32, kind="scratch",
                  accumulator=True),
        ),
        divisibility=div,
        flops=6.0 * attn_flops,
    )
    dkv = LaunchContract(
        kernel="flash_attention.bwd_dkv",
        grid=(b, max(hkv, 1), n_k, rep, n_q),
        blocks=(
            Block("q", (1, 1, block_q, d), dtype),
            Block("k", (1, 1, block_k, d), dtype),
            Block("v", (1, 1, block_k, d), dtype),
            Block("do", (1, 1, block_q, d), dtype),
            Block("lse", (1, 1, block_q), jnp.float32),
            Block("delta", (1, 1, block_q), jnp.float32),
            Block("dk", (1, 1, block_k, d), dtype, kind="out"),
            Block("dv", (1, 1, block_k, d), dtype, kind="out"),
            Block("dk_acc", (block_k, d), jnp.float32, kind="scratch",
                  accumulator=True),
            Block("dv_acc", (block_k, d), jnp.float32, kind="scratch",
                  accumulator=True),
        ),
        divisibility=div,
        flops=8.0 * attn_flops,
    )
    return (fwd, dq, dkv)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "block_q", "block_k", "interpret",
    "return_lse"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, softcap: Optional[float] = None,
                    window: Optional[int] = None, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False,
                    return_lse: bool = False):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D), Hq % Hkv == 0 → (B,Hq,Sq,D)
    [+ log-sum-exp (B,Hq,Sq) when return_lse, for the backward kernels].

    Causal; caller pads Sq/Sk to block multiples (padded k rows land
    above the diagonal or in masked tail — exact)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0 and sq % block_q == 0 and sk % block_k == 0
    rep = hq // hkv
    n_k = sk // block_k
    grid = (b, hq, sq // block_q, n_k)

    o, lse = pl.pallas_call(
        functools.partial(_kernel, scale, softcap, window, block_q, block_k,
                          n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, h, qi, kj: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, kj: (bi, h // rep, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, h, qi, kj: (bi, h // rep, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, h, qi, kj: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda bi, h, qi, kj: (bi, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return (o, lse) if return_lse else o


# ---------------------------------------------------------------------------
# backward kernels: dq (grid over q blocks) and dk/dv (grid over k blocks)
#
#   P   = exp(S − lse)
#   dP  = dO Vᵀ ;  dS = P ⊙ (dP − D),  D = rowsum(dO ⊙ O)
#   (softcap chain rule: dS_raw = dS · (1 − (S_cap/cap)²))
#   dQ  = dS K · scale ;  dK = dSᵀ Q · scale ;  dV = Pᵀ dO
# ---------------------------------------------------------------------------

def _bwd_scores(q_ref, k_ref, scale, softcap, window, qi, kj, bq, bk):
    """Returns (p-basis scores BEFORE exp (already capped), mask, and the
    softcap chain factor on raw scores)."""
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
        chain = 1.0 - t * t
    else:
        s = s_raw
        chain = None
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    return jnp.where(mask, s, NEG_INF), mask, chain


def _dq_kernel(scale, softcap, window, bq, bk, n_k,
               q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref, dq_ref,
               acc_ref):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _compute():
        s, mask, chain = _bwd_scores(q_ref, k_ref, scale, softcap, window,
                                     qi, kj, bq, bk)
        p = jnp.exp(s - lse_ref[0, 0][:, None])
        do = do_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec_ref[0, 0][:, None])
        if chain is not None:
            ds = ds * chain
        ds = jnp.where(mask, ds, 0.0)
        k = k_ref[0, 0].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(kj == n_k - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(scale, softcap, window, bq, bk, n_q, rep,
                q_ref, k_ref, v_ref, do_ref, lse_ref, dvec_ref,
                dk_ref, dv_ref, dk_acc, dv_acc):
    kj = pl.program_id(2)
    r = pl.program_id(3)
    qi = pl.program_id(4)

    @pl.when(jnp.logical_and(qi == 0, r == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _compute():
        s, mask, chain = _bwd_scores(q_ref, k_ref, scale, softcap, window,
                                     qi, kj, bq, bk)
        p = jnp.exp(s - lse_ref[0, 0][:, None])
        do = do_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        q = q_ref[0, 0].astype(jnp.float32)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dvec_ref[0, 0][:, None])
        if chain is not None:
            ds = ds * chain
        ds = jnp.where(mask, ds, 0.0)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(jnp.logical_and(qi == n_q - 1, r == rep - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "softcap", "window", "block_q", "block_k", "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, scale,
                        softcap: Optional[float] = None,
                        window: Optional[int] = None, block_q: int = 256,
                        block_k: int = 256, interpret: bool = False):
    """Backward kernels. Returns (dq, dk, dv). GQA: consecutive q heads
    sharing a kv head accumulate into the same dk/dv block (the h axis
    iterates sequentially; scratch carries the partial across the rep
    group)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    n_q, n_k = sq // block_q, sk // block_k
    dvec = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    q_map = lambda bi, h, qi, kj: (bi, h, qi, 0)
    k_map = lambda bi, h, qi, kj: (bi, h // rep, kj, 0)
    lse_map = lambda bi, h, qi, kj: (bi, h, qi)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale, softcap, window, block_q,
                          block_k, n_k),
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_k, d), k_map),
            pl.BlockSpec((1, 1, block_k, d), k_map),
            pl.BlockSpec((1, 1, block_q, d), q_map),
            pl.BlockSpec((1, 1, block_q), lse_map),
            pl.BlockSpec((1, 1, block_q), lse_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, dvec)

    # dk/dv: grid (b, hkv, kj, r, qi) — the rep-group (r) and q-block (qi)
    # loops are innermost so the scratch accumulation for one kv block is
    # contiguous; the block is emitted once per (b, hkv, kj)
    qk_map = lambda bi, hk, kj, r, qi: (bi, hk * rep + r, qi, 0)
    kk_map = lambda bi, hk, kj, r, qi: (bi, hk, kj, 0)
    lsek_map = lambda bi, hk, kj, r, qi: (bi, hk * rep + r, qi)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale, softcap, window, block_q,
                          block_k, n_q, rep),
        grid=(b, hkv, n_k, rep, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), qk_map),
            pl.BlockSpec((1, 1, block_k, d), kk_map),
            pl.BlockSpec((1, 1, block_k, d), kk_map),
            pl.BlockSpec((1, 1, block_q, d), qk_map),
            pl.BlockSpec((1, 1, block_q), lsek_map),
            pl.BlockSpec((1, 1, block_q), lsek_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), kk_map),
            pl.BlockSpec((1, 1, block_k, d), kk_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, dvec)
    return dq, dk, dv
