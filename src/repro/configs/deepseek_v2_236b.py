"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512,
q_lora=1536, nope=128, rope=64, v=128) vocab=102400; MoE: 2 shared +
160 routed experts, top-6, d_ff(expert)=1536; first layer dense
(d_ff=12288). [arXiv:2405.04434]

Decode uses the absorbed latent form: 576 floats/token of cache."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.mla import MlaCfg
from repro.nn.mlp import MlpCfg
from repro.nn.moe import MoeCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, vocab=102400,
        mla=MlaCfg(d_model=5120, n_heads=128, q_lora=1536, kv_lora=512,
                   qk_nope=128, qk_rope=64, v_dim=128),
        moe=MoeCfg(d_model=5120, d_ff=1536, n_experts=160, top_k=6,
                   n_shared=2, routed_scale=16.0,
                   dispatch_groups=16),  # gather-based group-local dispatch
                                         # (36x coll-bytes win, EXPERIMENTS §Perf)
        n_dense_prefix=1,
        dense_prefix_mlp=MlpCfg(d_model=5120, d_ff=12288, act="silu"),
        dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b-smoke", n_layers=3, d_model=64, vocab=128,
        mla=MlaCfg(d_model=64, n_heads=4, q_lora=32, kv_lora=16,
                   qk_nope=16, qk_rope=8, v_dim=16),
        moe=MoeCfg(d_model=64, d_ff=32, n_experts=8, top_k=2, n_shared=1,
                   routed_scale=1.0),
        n_dense_prefix=1,
        dense_prefix_mlp=MlpCfg(d_model=64, d_ff=128, act="silu"),
        dtype="float32")


def probes():
    # L=2: prefix + 1 MoE layer; L=3: prefix + 2 → slope = one MoE layer
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (2, 3)]


SPEC = ArchSpec(
    arch_id="deepseek-v2-236b", family="transformer",
    full=full, smoke=smoke, probes=probes,
    combine=lin2(60, small_n=2, big_n=3),
    train_microbatches=2,   # 236B needs activation halving to fit 16 GB

    skip_shapes=("long_500k",),
    skip_reason="pure full-attention (MLA is still quadratic prefill; "
                "524k decode KV fits but attention scan cost dominates)",
)
