"""zamba2-7b [hybrid]: 81L d_model=3584 Mamba2 (ssm_state=64) + one
shared attention block (32H, d_ff=14336) applied every 6 layers on
concat(h, h⁰), vocab=32000. [arXiv:2411.15242]

Runs long_500k: Mamba state is O(1); only the 13 shared-block KV caches
grow with context. Shared-block params are outside the pex norm scope
(weight reuse breaks the per-use rank factorization — DESIGN.md §5)."""
import dataclasses

from repro.configs.common import ArchSpec
from repro.models.zamba2 import Zamba2Config
from repro.nn.ssm import SsmCfg


def full(dtype="bfloat16") -> Zamba2Config:
    return Zamba2Config(name="zamba2-7b", n_layers=81, d_model=3584,
                        vocab=32000, d_ff=14336, n_heads=32, kv_heads=32,
                        ssm=SsmCfg(d_model=3584, d_state=64),
                        share_every=6, dtype=dtype)


def smoke() -> Zamba2Config:
    return Zamba2Config(name="zamba2-7b-smoke", n_layers=5, d_model=64,
                        vocab=128, d_ff=128, n_heads=4, kv_heads=4,
                        ssm=SsmCfg(d_model=64, d_state=8, head_dim=16),
                        share_every=2, dtype="float32")


def probes():
    # A: 1 group (6 mamba + 1 shared); B: 2 groups; C: 1 group + 3 tail
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (6, 12, 9)]


def combine(ms):
    out = {}
    for k in ms[0]:
        a, b, c = ms[0][k], ms[1][k], ms[2][k]
        mamba = (c - a) / 3.0
        shared = (b - a) - 6.0 * mamba
        c0 = a - 6.0 * mamba - shared
        out[k] = max(*(m[k] for m in ms), 0.0, c0 + 13.0 * (6.0 * mamba + shared) + 3.0 * mamba)
    return out


SPEC = ArchSpec(
    arch_id="zamba2-7b", family="zamba2",
    full=full, smoke=smoke, probes=probes, combine=combine,
)
