"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution. [arXiv:2409.12191]

Backbone only per the assignment: the vision frontend is a stub —
``input_specs`` supplies pre-merged visual embeddings, a visual-token
mask, and (3, B, S) M-RoPE position streams."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="qwen2-vl-7b", n_layers=28, d_model=3584, vocab=152064,
        attn=AttnCfg(d_model=3584, n_heads=28, n_kv=4, head_dim=128,
                     bias=True, rope_theta=1000000.0,
                     mrope_sections=(16, 24, 24)),
        mlp=MlpCfg(d_model=3584, d_ff=18944, act="silu"),
        vl_inputs=True, dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen2-vl-7b-smoke", n_layers=2, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=4, n_kv=2, head_dim=16, bias=True,
                     head_multiple=1, mrope_sections=(2, 3, 3)),
        mlp=MlpCfg(d_model=64, d_ff=128, act="silu"),
        vl_inputs=True, dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="qwen2-vl-7b", family="transformer",
    full=full, smoke=smoke, probes=probes, combine=lin2(28),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention (see llama3.2-1b)",
)
