"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias. [arXiv:2407.10671]

28 Q heads don't divide the model axis (16): padded to 32 (zero-init
pad rows ⇒ exact); kv=4 replicated across TP."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="qwen2-7b", n_layers=28, d_model=3584, vocab=152064,
        attn=AttnCfg(d_model=3584, n_heads=28, n_kv=4, head_dim=128,
                     bias=True, rope_theta=1000000.0),
        mlp=MlpCfg(d_model=3584, d_ff=18944, act="silu"),
        dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="qwen2-7b-smoke", n_layers=2, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=7, n_kv=1, head_dim=8, bias=True,
                     head_multiple=4),  # exercises head padding (7→8)
        mlp=MlpCfg(d_model=64, d_ff=160, act="silu"),
        dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="qwen2-7b", family="transformer",
    full=full, smoke=smoke, probes=probes, combine=lin2(28),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention (see llama3.2-1b)",
)
