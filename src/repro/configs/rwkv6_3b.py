"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536 — Finch, data-dependent decay. [arXiv:2404.05892]

Runs long_500k: O(1) recurrent state, decode cost is context-length
independent."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.rwkv6 import Rwkv6Config


def full(dtype="bfloat16") -> Rwkv6Config:
    return Rwkv6Config(name="rwkv6-3b", n_layers=32, d_model=2560,
                       vocab=65536, d_ff=8960, dtype=dtype)


def smoke() -> Rwkv6Config:
    return Rwkv6Config(name="rwkv6-3b-smoke", n_layers=2, d_model=64,
                       vocab=128, d_ff=128, dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="rwkv6-3b", family="rwkv6",
    full=full, smoke=smoke, probes=probes, combine=lin2(32),
)
