"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron: squared-ReLU MLP, no gate.
[arXiv:2407.14679]  24 Q heads pad → 32 for TP-16."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="minitron-4b", n_layers=32, d_model=3072, vocab=256000,
        attn=AttnCfg(d_model=3072, n_heads=24, n_kv=8, head_dim=128,
                     rope_theta=10000.0),
        mlp=MlpCfg(d_model=3072, d_ff=9216, act="relu2", gated=False),
        dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="minitron-4b-smoke", n_layers=2, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=3, n_kv=1, head_dim=16,
                     head_multiple=2),  # exercises head padding (3→4)
        mlp=MlpCfg(d_model=64, d_ff=128, act="relu2", gated=False),
        dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="minitron-4b", family="transformer",
    full=full, smoke=smoke, probes=probes, combine=lin2(32),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention (see llama3.2-1b)",
)
