"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — alternating local(4096)/global attention, attn softcap
50, final logit softcap 30, sandwich RMSNorms, (1+g) scales, embeds ×√d,
query scale 256^-1/2. [arXiv:2408.00118]"""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="gemma2-9b", n_layers=42, d_model=3584, vocab=256000,
        attn=AttnCfg(d_model=3584, n_heads=16, n_kv=8, head_dim=256,
                     softcap=50.0, window=4096, rope_theta=10000.0,
                     attn_scale=256.0 ** -0.5),
        mlp=MlpCfg(d_model=3584, d_ff=14336, act="gelu"),
        rms_plus_one=True, post_norms=True, alt_local_global=True,
        logit_softcap=30.0, scale_embeds=True, dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="gemma2-9b-smoke", n_layers=4, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=4, n_kv=2, head_dim=16,
                     softcap=50.0, window=8, head_multiple=1,
                     attn_scale=16.0 ** -0.5),
        mlp=MlpCfg(d_model=64, d_ff=128, act="gelu"),
        rms_plus_one=True, post_norms=True, alt_local_global=True,
        logit_softcap=30.0, scale_embeds=True, dtype="float32")


def probes():
    # period-2 local/global pattern → probe in whole periods
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (2, 4)]


SPEC = ArchSpec(
    arch_id="gemma2-9b", family="transformer",
    full=full, smoke=smoke, probes=probes,
    combine=lin2(42, small_n=2, big_n=4),
    skip_shapes=("long_500k",),
    skip_reason="half the layers are global full-attention (see llama3.2-1b)",
)
