"""repro.configs"""
