"""Shared config machinery: assigned input shapes, arch registry entry,
sharding-rule builders, analytic MODEL_FLOPS."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass
class ArchSpec:
    """Registry entry binding a config family to model entry points."""
    arch_id: str
    family: str                      # transformer | zamba2 | rwkv6 | seamless
    full: Callable[[], object]       # exact assigned config
    smoke: Callable[[], object]      # reduced config for CPU smoke tests
    probes: Callable[[], List[object]]   # unrolled variants for roofline
    combine: Callable[[List[dict]], dict]  # probe metrics → full-model metrics
    skip_shapes: Sequence[str] = ()      # e.g. long_500k for full-attention
    skip_reason: str = ""
    train_microbatches: int = 1          # gradient accumulation at train_4k
    n_params: Optional[int] = None       # analytic total params
    n_active: Optional[int] = None       # analytic active params (MoE)


def lin2(full_n: int, small_n: int = 1, big_n: int = 2):
    """metric(L) = a + b·L from two probes → extrapolate to full_n.
    Clamped to ≥ max(probe values): extrapolation noise (near-equal
    probes dominated by constant terms) must not go negative."""
    def combine(ms: List[dict]) -> dict:
        out = {}
        for k in ms[0]:
            b = (ms[1][k] - ms[0][k]) / (big_n - small_n)
            a = ms[0][k] - b * small_n
            out[k] = max(a + b * full_n, ms[0][k], ms[1][k], 0.0)
        return out
    return combine


def dense_lm_params(n_layers, d_model, n_heads, n_kv, head_dim, d_ff,
                    vocab, gated=True, qkv_bias=False):
    """Analytic parameter count for the GQA-transformer family."""
    attn = d_model * (n_heads + 2 * n_kv) * head_dim \
        + n_heads * head_dim * d_model
    if qkv_bias:
        attn += (n_heads + 2 * n_kv) * head_dim
    mlp = d_model * d_ff * (3 if gated else 2)
    norms = 2 * d_model
    per_layer = attn + mlp + norms
    return n_layers * per_layer + 2 * vocab * d_model + d_model


def train_model_flops(n_params_active: int, shape: ShapeSpec) -> float:
    """6·N·D with D = tokens per step."""
    return 6.0 * n_params_active * shape.seq * shape.batch


def serve_model_flops(n_params_active: int, shape: ShapeSpec) -> float:
    """2·N per generated token (decode: one token per example)."""
    tokens = shape.batch * (shape.seq if shape.kind == "prefill" else 1)
    return 2.0 * n_params_active * tokens


def base_rules(multi_pod: bool, *, kv_shardable: bool, batch_shard: bool = True,
               seq_to_data: bool = False) -> dict:
    """Logical→mesh axis rules shared by the arch configs."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp if batch_shard else None,
        "embed": dp,                 # FSDP: params' d_model dim over data
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model" if kv_shardable else None,
        "vocab": "model",
        "experts": "model",
        "capacity": None,
        "moe_groups": dp,
        "expert_ff": None,
        "qlora": None,
        "kvlora": None,
        "embed2": None,
        "heads_act": "model",
        "kv_heads_act": "model" if kv_shardable else None,
        "mlp_act": "model",
        "vocab_act": "model",
        "embed_act": None,
        "seq_kv": ("data",) if seq_to_data else None,
    }
