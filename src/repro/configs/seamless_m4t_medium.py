"""seamless-m4t-medium [audio]: 12L(enc)+12L(dec) d_model=1024 16H
d_ff=4096 vocab=256206 — enc-dec; audio frontend stubbed (precomputed
frame embeddings per the assignment). [arXiv:2308.11596]

vocab 256206 pads → 256208 (÷16) for TP."""
import dataclasses

from repro.configs.common import ArchSpec
from repro.models.seamless import SeamlessConfig


def full(dtype="bfloat16") -> SeamlessConfig:
    return SeamlessConfig(name="seamless-m4t-medium", n_enc=12, n_dec=12,
                          d_model=1024, n_heads=16, kv_heads=16,
                          d_ff=4096, vocab=256206, dtype=dtype)


def smoke() -> SeamlessConfig:
    return SeamlessConfig(name="seamless-m4t-medium-smoke", n_enc=2, n_dec=2,
                          d_model=64, n_heads=4, kv_heads=4, d_ff=128,
                          vocab=131, dtype="float32")


def probes():
    reps = [(1, 1), (2, 1), (1, 2)]
    return [dataclasses.replace(full(), n_enc=e, n_dec=d, stack_mode="unroll")
            for e, d in reps]


def combine(ms):
    out = {}
    for k in ms[0]:
        a, b, c = ms[0][k], ms[1][k], ms[2][k]
        enc, dec = b - a, c - a
        c0 = a - enc - dec
        out[k] = max(*(m[k] for m in ms), 0.0, c0 + 12.0 * enc + 12.0 * dec)
    return out


SPEC = ArchSpec(
    arch_id="seamless-m4t-medium", family="seamless",
    full=full, smoke=smoke, probes=probes, combine=combine,
    skip_shapes=("long_500k",),
    skip_reason="full-attention enc-dec; 524k target decode is out of "
                "family scope (speech segments are short)",
)
