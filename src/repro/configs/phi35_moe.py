"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8)
d_ff=6400, 16 experts top-2 (renormalized gates), vocab=32064.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.moe import MoeCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="phi3.5-moe", n_layers=32, d_model=4096, vocab=32064,
        attn=AttnCfg(d_model=4096, n_heads=32, n_kv=8, head_dim=128,
                     rope_theta=10000.0),
        moe=MoeCfg(d_model=4096, d_ff=6400, n_experts=16, top_k=2,
                   renorm_topk=True, dispatch_groups=16),
        dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="phi3.5-moe-smoke", n_layers=2, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=4, n_kv=2, head_dim=16,
                     head_multiple=1),
        moe=MoeCfg(d_model=64, d_ff=96, n_experts=4, top_k=2,
                   renorm_topk=True),
        dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="phi3.5-moe", family="transformer",
    full=full, smoke=smoke, probes=probes, combine=lin2(32),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention (see llama3.2-1b)",
)
