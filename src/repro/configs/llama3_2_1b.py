"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B]

Note: HF ties embed/lm_head; we keep them untied (tied weights couple
per-example Gram terms across the two uses — DESIGN.md §5)."""
import dataclasses

from repro.configs.common import ArchSpec, lin2
from repro.models.transformer import LMConfig
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MlpCfg


def full(dtype="bfloat16") -> LMConfig:
    return LMConfig(
        name="llama3.2-1b", n_layers=16, d_model=2048, vocab=128256,
        attn=AttnCfg(d_model=2048, n_heads=32, n_kv=8, head_dim=64,
                     rope_theta=500000.0),
        mlp=MlpCfg(d_model=2048, d_ff=8192, act="silu"),
        dtype=dtype)


def smoke() -> LMConfig:
    return LMConfig(
        name="llama3.2-1b-smoke", n_layers=2, d_model=64, vocab=128,
        attn=AttnCfg(d_model=64, n_heads=4, n_kv=2, head_dim=16,
                     head_multiple=1),
        mlp=MlpCfg(d_model=64, d_ff=128, act="silu"),
        dtype="float32")


def probes():
    return [dataclasses.replace(full(), n_layers=n, stack_mode="unroll")
            for n in (1, 2)]


SPEC = ArchSpec(
    arch_id="llama3.2-1b", family="transformer",
    full=full, smoke=smoke, probes=probes, combine=lin2(16),
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention: quadratic attention / O(s) KV cache "
                "per layer at 524k exceeds the sane-HBM envelope",
)
