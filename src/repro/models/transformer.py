"""Configurable decoder-only LM covering seven assigned architectures:
llama3.2-1b, qwen2-7b, qwen2-vl-7b, minitron-4b, gemma2-9b,
deepseek-v2-236b (MLA + MoE), phi3.5-moe.

Layers with identical parameter shapes are stacked and run under
``lax.scan`` (small HLO, fast pod-scale compiles); heterogeneous
prefixes (deepseek's first dense layer) are unstacked. Instrumentation
is a single ``Tap`` collector handed to ``loss_fn`` (pex v2): the
accumulator crosses the scan/remat boundaries via ``taps.scan`` /
``taps.checkpoint``. ``stack_mode='unroll'`` unrolls for the roofline
cost probes (cost_analysis counts scan bodies once — see
roofline/analysis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn import lora as lora_mod
from repro.nn import param as pm
from repro.nn.attention import AttnCfg, attention, init_attention, init_kv_cache
from repro.nn.embedding import (VocabCfg, embed, init_embedding, init_lm_head,
                                lm_head, per_example_xent)
from repro.nn.mla import MlaCfg, init_mla, init_mla_cache, mla_attention
from repro.nn.mlp import MlpCfg, init_mlp, mlp
from repro.nn.moe import MoeCfg, init_moe, moe
from repro.nn.norms import init_rmsnorm, rmsnorm


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    attn: Optional[AttnCfg] = None        # GQA family
    mla: Optional[MlaCfg] = None          # deepseek
    mlp: Optional[MlpCfg] = None          # dense FFN
    moe: Optional[MoeCfg] = None          # MoE FFN
    n_dense_prefix: int = 0               # deepseek: first k layers dense
    dense_prefix_mlp: Optional[MlpCfg] = None
    rms_eps: float = 1e-6
    rms_plus_one: bool = False            # gemma (1+g)
    post_norms: bool = False              # gemma2 sandwich norms
    alt_local_global: bool = False        # gemma2 even layers local
    logit_softcap: Optional[float] = None
    scale_embeds: bool = False            # gemma ×√d
    vl_inputs: bool = False               # qwen2-vl merged visual embeds
    lora: Optional[lora_mod.LoraCfg] = None  # LoRA-fy the linear sites:
                                          # frozen bases + tapped factors
    dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"            # full | dots  (dots: save matmul
                                          # outputs, recompute elementwise)
    stack_mode: str = "scan"              # scan | unroll
    max_cache_len: int = 0                # set by serve shapes

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def vocab_cfg(self) -> VocabCfg:
        return VocabCfg(self.vocab, self.d_model,
                        logit_softcap=self.logit_softcap,
                        scale_by_sqrt_dim=self.scale_embeds)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_block(key, cfg: LMConfig, *, dense_mlp: bool):
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    p = {"ln_attn": init_rmsnorm(cfg.d_model, dtype=dt,
                                 plus_one=cfg.rms_plus_one)}
    if cfg.mla is not None:
        p["attn"] = init_mla(ks[0], cfg.mla, dtype=dt)
    else:
        p["attn"] = init_attention(ks[0], cfg.attn, dtype=dt)
    p["ln_mlp"] = init_rmsnorm(cfg.d_model, dtype=dt,
                               plus_one=cfg.rms_plus_one)
    if dense_mlp or cfg.moe is None:
        mcfg = cfg.dense_prefix_mlp if dense_mlp and cfg.dense_prefix_mlp \
            else cfg.mlp
        p["mlp"] = init_mlp(ks[1], mcfg, dtype=dt)
    else:
        p["moe"] = init_moe(ks[1], cfg.moe, dtype=dt)
    if cfg.post_norms:
        p["ln_attn_post"] = init_rmsnorm(cfg.d_model, dtype=dt,
                                         plus_one=cfg.rms_plus_one)
        p["ln_mlp_post"] = init_rmsnorm(cfg.d_model, dtype=dt,
                                        plus_one=cfg.rms_plus_one)
    return p


def init(key, cfg: LMConfig):
    ks = jax.random.split(key, cfg.n_layers + 4)
    dt = cfg.jdtype
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_cfg, dtype=dt),
        "head": init_lm_head(ks[1], cfg.vocab_cfg, dtype=dt),
        "ln_f": init_rmsnorm(cfg.d_model, dtype=dt, plus_one=cfg.rms_plus_one),
    }
    n_pre = cfg.n_dense_prefix
    if n_pre:
        params["prefix"] = [
            _init_block(ks[4 + i], cfg, dense_mlp=True) for i in range(n_pre)]
    stacked = [_init_block(ks[4 + n_pre + i], cfg, dense_mlp=False)
               for i in range(cfg.n_layers - n_pre)]
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: pm.Boxed(jnp.stack([x.value for x in xs]),
                             (None,) + xs[0].axes),
        *stacked, is_leaf=pm.is_boxed)
    if cfg.lora is not None:
        # post-stacking: stacked sites get (L, d, r)/(L, r, d) factors
        # whose leading axis scans with the blocks; ks[2] is the spare
        # key reserved at the top of init
        params = lora_mod.attach(params, cfg.lora, ks[2], dtype=dt)
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _block(p, x, tap: Tap, cfg: LMConfig, *, positions,
           cache=None, cache_index=None, local_flag=None, dense_mlp=False):
    h = rmsnorm(p["ln_attn"], x, tap=tap, eps=cfg.rms_eps,
                plus_one=cfg.rms_plus_one)
    if cfg.mla is not None:
        a, cache = mla_attention(p["attn"], h, tap=tap, cfg=cfg.mla,
                                 positions=positions,
                                 cache=cache, cache_index=cache_index)
    else:
        a, cache = attention(p["attn"], h, tap=tap, cfg=cfg.attn,
                             positions=positions, cache=cache,
                             cache_index=cache_index,
                             local_flag=local_flag)
    if cfg.post_norms:
        a = rmsnorm(p["ln_attn_post"], a, tap=tap,
                    eps=cfg.rms_eps, plus_one=cfg.rms_plus_one)
    x = x + a
    h = rmsnorm(p["ln_mlp"], x, tap=tap, eps=cfg.rms_eps,
                plus_one=cfg.rms_plus_one)
    if "moe" in p and not dense_mlp:
        m = moe(p["moe"], h, tap=tap, cfg=cfg.moe)
    else:
        mcfg = cfg.dense_prefix_mlp if dense_mlp and cfg.dense_prefix_mlp \
            else cfg.mlp
        m = mlp(p["mlp"], h, tap=tap, cfg=mcfg)
    if cfg.post_norms:
        m = rmsnorm(p["ln_mlp_post"], m, tap=tap,
                    eps=cfg.rms_eps, plus_one=cfg.rms_plus_one)
    return x + m, cache


def _run_stack(params, x, tap: Tap, cfg: LMConfig, *, positions,
               caches=None, cache_index=None):
    """Apply prefix blocks then the scanned/unrolled homogeneous stack.
    caches: None (train) or dict {"prefix": [..], "blocks": stacked-pytree}."""
    n_pre = cfg.n_dense_prefix
    new_caches = {"prefix": [], "blocks": None} if caches is not None else None

    for i in range(n_pre):
        c = caches["prefix"][i] if caches is not None else None
        x, c = _block(params["prefix"][i], x, tap, cfg,
                      positions=positions, cache=c,
                      cache_index=cache_index, dense_mlp=True)
        if caches is not None:
            new_caches["prefix"].append(c)

    n_stack = cfg.n_layers - n_pre

    def body(x, xs):
        p_i, cache_i, idx = xs
        lf = (idx % 2 == 0) if cfg.alt_local_global else None
        x, cache_i = _block(p_i, x, tap, cfg, positions=positions,
                            cache=cache_i, cache_index=cache_index,
                            local_flag=lf)
        return x, cache_i

    remat = cfg.remat and caches is None
    policy = None if cfg.remat_policy == "full" else \
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    idxs = jnp.arange(n_stack)
    blk_caches = caches["blocks"] if caches is not None else None

    if cfg.stack_mode == "scan":
        x, out_caches = taps.scan(
            body, x, (params["blocks"], blk_caches, idxs), tap=tap,
            remat=remat, policy=policy)
    else:
        body_fn = taps.checkpoint(body, tap=tap, policy=policy) \
            if remat else body
        out_list = []
        for i in range(n_stack):
            p_i = jax.tree_util.tree_map(lambda v: v[i], params["blocks"])
            c_i = None if blk_caches is None else \
                jax.tree_util.tree_map(lambda v: v[i], blk_caches)
            x, c_i = body_fn(x, (p_i, c_i, idxs[i]))
            out_list.append(c_i)
        out_caches = None
        if caches is not None:
            out_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *out_list)
    if caches is not None:
        new_caches["blocks"] = out_caches
    return x, new_caches


def _inputs_to_embeds(params, batch, tap: Tap, cfg: LMConfig):
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)
    if cfg.vl_inputs and "vis_embeds" in batch:
        # merged multimodal stream: frontend (stub) supplies patch embeds
        x = jnp.where(batch["vis_mask"][..., None], batch["vis_embeds"], x)
    return x


def _positions(batch, cfg: LMConfig, s: int):
    if cfg.attn is not None and cfg.attn.mrope_sections is not None:
        pos = batch.get("positions")            # (B,3,S) from the VL stub
        return None if pos is None else jnp.moveaxis(pos, 1, 0)  # → (3,B,S)
    return None                                 # default arange


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def loss_fn(params, batch, tap: Tap, *, cfg: LMConfig):
    """Canonical v2 instrumented loss: (loss_vec, aux)."""
    ids = batch["ids"]
    b, s = ids.shape
    x = _inputs_to_embeds(params, batch, tap, cfg)
    x, _ = _run_stack(params, x, tap, cfg,
                      positions=_positions(batch, cfg, s))
    x = rmsnorm(params["ln_f"], x, tap=tap, eps=cfg.rms_eps,
                plus_one=cfg.rms_plus_one)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    loss_vec = per_example_xent(logits, batch["labels"],
                                batch.get("label_mask"), tap=tap)
    return loss_vec, {}


def init_caches(batch: int, cfg: LMConfig):
    """Decode caches for every layer (stacked to mirror params)."""
    dt = cfg.jdtype
    n_pre = cfg.n_dense_prefix
    mk = (lambda: init_mla_cache(batch, cfg.max_cache_len, cfg.mla, dtype=dt)) \
        if cfg.mla is not None else \
        (lambda: init_kv_cache(batch, cfg.max_cache_len, cfg.attn, dtype=dt))
    prefix = [mk() for _ in range(n_pre)]
    one = mk()
    n_stack = cfg.n_layers - n_pre
    blocks = jax.tree_util.tree_map(
        lambda v: jnp.zeros((n_stack,) + v.shape, v.dtype), one)
    return {"prefix": prefix, "blocks": blocks}


def forward_tokens(params, batch, caches, cache_index, *, cfg: LMConfig):
    """Prefill or decode: embeds tokens, runs the stack with caches,
    returns (logits, new_caches). Uninstrumented (serving)."""
    tap = taps.NULL
    ids = batch["ids"]
    b, s = ids.shape
    x = _inputs_to_embeds(params, batch, tap, cfg)
    pos = _positions(batch, cfg, s)
    if pos is None and cache_index is not None:
        pos = (cache_index + jnp.arange(s))[None]
    x, caches = _run_stack(params, x, tap, cfg, positions=pos,
                           caches=caches, cache_index=cache_index)
    x = rmsnorm(params["ln_f"], x, tap=tap, eps=cfg.rms_eps,
                plus_one=cfg.rms_plus_one)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    return logits, caches
