"""repro.models"""
