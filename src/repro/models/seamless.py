"""SeamlessM4T-medium backbone: transformer encoder–decoder.

The audio frontend is a stub per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_src, d_model). The decoder
trains with teacher forcing; decode caches both self-attention KV and
the precomputed cross-attention KV of the encoder memory.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import Tap
from repro.nn import param as pm
from repro.nn.attention import AttnCfg, attention, init_attention, init_kv_cache
from repro.nn.embedding import (VocabCfg, embed, init_embedding, init_lm_head,
                                lm_head, per_example_xent)
from repro.nn.linear import linear
from repro.nn.mlp import MlpCfg, init_mlp, mlp
from repro.nn.norms import init_layernorm, layernorm


@dataclasses.dataclass(frozen=True)
class SeamlessConfig:
    name: str
    n_enc: int = 12
    n_dec: int = 12
    d_model: int = 1024
    n_heads: int = 16
    kv_heads: int = 16
    d_ff: int = 4096
    vocab: int = 256206
    dtype: str = "float32"
    remat: bool = True
    stack_mode: str = "scan"
    max_cache_len: int = 0
    max_src_len: int = 0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_layers(self) -> int:
        return self.n_enc + self.n_dec

    def attn_cfg(self, *, cross: bool = False, causal: bool = True) -> AttnCfg:
        return AttnCfg(d_model=self.d_model, n_heads=self.n_heads,
                       n_kv=self.kv_heads,
                       head_dim=self.d_model // self.n_heads,
                       cross=cross, causal=causal)

    @property
    def vocab_cfg(self) -> VocabCfg:
        return VocabCfg(self.vocab, self.d_model)


def _init_enc_block(key, cfg: SeamlessConfig):
    ks = jax.random.split(key, 2)
    dt = cfg.jdtype
    return {"ln1": init_layernorm(cfg.d_model, dtype=dt),
            "attn": init_attention(ks[0], cfg.attn_cfg(causal=False), dtype=dt),
            "ln2": init_layernorm(cfg.d_model, dtype=dt),
            "mlp": init_mlp(ks[1], MlpCfg(cfg.d_model, cfg.d_ff, act="gelu",
                                          gated=False), dtype=dt)}


def _init_dec_block(key, cfg: SeamlessConfig):
    ks = jax.random.split(key, 3)
    dt = cfg.jdtype
    return {"ln1": init_layernorm(cfg.d_model, dtype=dt),
            "self": init_attention(ks[0], cfg.attn_cfg(), dtype=dt),
            "ln_x": init_layernorm(cfg.d_model, dtype=dt),
            "cross": init_attention(ks[1], cfg.attn_cfg(cross=True), dtype=dt),
            "ln2": init_layernorm(cfg.d_model, dtype=dt),
            "mlp": init_mlp(ks[2], MlpCfg(cfg.d_model, cfg.d_ff, act="gelu",
                                          gated=False), dtype=dt)}


def _stack(blocks):
    return jax.tree_util.tree_map(
        lambda *xs: pm.Boxed(jnp.stack([x.value for x in xs]),
                             (None,) + xs[0].axes),
        *blocks, is_leaf=pm.is_boxed)


def init(key, cfg: SeamlessConfig):
    ks = jax.random.split(key, cfg.n_layers + 4)
    dt = cfg.jdtype
    return {
        "embed": init_embedding(ks[0], cfg.vocab_cfg, dtype=dt),
        "head": init_lm_head(ks[1], cfg.vocab_cfg, dtype=dt),
        "ln_enc": init_layernorm(cfg.d_model, dtype=dt),
        "ln_dec": init_layernorm(cfg.d_model, dtype=dt),
        "enc": _stack([_init_enc_block(ks[4 + i], cfg)
                       for i in range(cfg.n_enc)]),
        "dec": _stack([_init_dec_block(ks[4 + cfg.n_enc + i], cfg)
                       for i in range(cfg.n_dec)]),
    }


def _encode(params, frames, tap: Tap, cfg: SeamlessConfig):
    def body(x, p_i):
        h = layernorm(p_i["ln1"], x, tap=tap)
        a, _ = attention(p_i["attn"], h, tap=tap,
                         cfg=cfg.attn_cfg(causal=False))
        x = x + a
        h = layernorm(p_i["ln2"], x, tap=tap)
        m = mlp(p_i["mlp"], h, tap=tap,
                cfg=MlpCfg(cfg.d_model, cfg.d_ff, act="gelu", gated=False))
        return x + m, None

    x, _ = taps.scan(body, frames, params["enc"], tap=tap,
                     remat=cfg.remat and tap.live)
    return layernorm(params["ln_enc"], x, tap=tap)


def _dec_block(p_i, x, memory, tap: Tap, cfg: SeamlessConfig,
               self_cache=None, cross_cache=None, cache_index=None):
    h = layernorm(p_i["ln1"], x, tap=tap)
    a, self_cache = attention(p_i["self"], h, tap=tap, cfg=cfg.attn_cfg(),
                              cache=self_cache, cache_index=cache_index)
    x = x + a
    h = layernorm(p_i["ln_x"], x, tap=tap)
    a, _ = attention(p_i["cross"], h, tap=tap, cfg=cfg.attn_cfg(cross=True),
                     memory=memory, cache=cross_cache)
    x = x + a
    h = layernorm(p_i["ln2"], x, tap=tap)
    m = mlp(p_i["mlp"], h, tap=tap, cfg=MlpCfg(cfg.d_model, cfg.d_ff,
                                               act="gelu", gated=False))
    return x + m, self_cache


def loss_fn(params, batch, tap: Tap, *, cfg: SeamlessConfig):
    """batch: src_frames (B,S_src,d), ids/labels (B,S_tgt)."""
    memory = _encode(params, batch["src_frames"], tap, cfg)
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)

    def body(x, p_i):
        x, _ = _dec_block(p_i, x, memory, tap, cfg)
        return x, None

    x, _ = taps.scan(body, x, params["dec"], tap=tap,
                     remat=cfg.remat and tap.live)
    x = layernorm(params["ln_dec"], x, tap=tap)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    loss_vec = per_example_xent(logits, batch["labels"],
                                batch.get("label_mask"), tap=tap)
    return loss_vec, {}


def init_caches(batch: int, cfg: SeamlessConfig):
    dt = cfg.jdtype
    self_one = init_kv_cache(batch, cfg.max_cache_len, cfg.attn_cfg(), dtype=dt)
    cross_one = init_kv_cache(batch, cfg.max_src_len,
                              cfg.attn_cfg(cross=True), dtype=dt)
    stack = lambda one: jax.tree_util.tree_map(
        lambda v: jnp.zeros((cfg.n_dec,) + v.shape, v.dtype), one)
    return {"self": stack(self_one), "cross": stack(cross_one),
            "memory": jnp.zeros((batch, cfg.max_src_len, cfg.d_model), dt)}


def precompute_cross(params, memory, *, cfg: SeamlessConfig):
    """Project encoder memory through every decoder layer's cross K/V."""

    def per_layer(p_i):
        k = linear(p_i["cross"]["wk"], memory, tap=taps.NULL)
        v = linear(p_i["cross"]["wv"], memory, tap=taps.NULL)
        hkv = cfg.kv_heads
        hd = cfg.d_model // cfg.n_heads
        return {"k": k.reshape(k.shape[0], k.shape[1], hkv, hd),
                "v": v.reshape(v.shape[0], v.shape[1], hkv, hd)}

    return jax.vmap(per_layer)(
        jax.tree_util.tree_map(lambda x: x, params["dec"]))


def forward_tokens(params, batch, caches, cache_index, *, cfg: SeamlessConfig):
    """Decode step(s): batch["ids"] (B,s). Encoder memory and cross K/V
    come precomputed in `caches` (set up at prefill)."""
    tap = taps.NULL

    if "src_frames" in batch:  # prefill: encode + fill cross caches
        memory = _encode(params, batch["src_frames"], tap, cfg)
        caches = {**caches, "memory": memory,
                  "cross": precompute_cross(params, memory, cfg=cfg)}

    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)

    def body(x, xs):
        p_i, sc_i, cc_i = xs
        x, sc_i = _dec_block(p_i, x, caches["memory"], tap, cfg,
                             self_cache=sc_i, cross_cache=cc_i,
                             cache_index=cache_index)
        return x, sc_i

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], caches["self"], caches["cross"]))
    x = layernorm(params["ln_dec"], x, tap=tap)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    return logits, {**caches, "self": new_self}
