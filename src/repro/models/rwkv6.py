"""RWKV6-3B ("Finch"): attention-free LM; 32 blocks of tmix + cmix.

Decode carries O(1) state (wkv matrix + token-shift rows) — the
`long_500k` cell costs the same per token as `decode_32k`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import Tap
from repro.nn import param as pm
from repro.nn.embedding import (VocabCfg, embed, init_embedding, init_lm_head,
                                lm_head, per_example_xent)
from repro.nn.norms import init_layernorm, layernorm
from repro.nn.rwkv import (RwkvCfg, init_rwkv_cmix, init_rwkv_state,
                           init_rwkv_tmix, rwkv_cmix, rwkv_tmix)


@dataclasses.dataclass(frozen=True)
class Rwkv6Config:
    name: str
    n_layers: int = 32
    d_model: int = 2560
    vocab: int = 65536
    d_ff: int = 8960
    rms_eps: float = 1e-5
    dtype: str = "float32"
    remat: bool = True
    stack_mode: str = "scan"
    max_cache_len: int = 0   # unused: O(1) state

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def rwkv_cfg(self) -> RwkvCfg:
        return RwkvCfg(self.d_model, self.d_ff)

    @property
    def vocab_cfg(self) -> VocabCfg:
        return VocabCfg(self.vocab, self.d_model)


def _init_block(key, cfg: Rwkv6Config):
    ks = jax.random.split(key, 2)
    dt = cfg.jdtype
    return {
        "ln1": init_layernorm(cfg.d_model, dtype=dt),
        "tmix": init_rwkv_tmix(ks[0], cfg.rwkv_cfg, dtype=dt),
        "ln2": init_layernorm(cfg.d_model, dtype=dt),
        "cmix": init_rwkv_cmix(ks[1], cfg.rwkv_cfg, dtype=dt),
    }


def init(key, cfg: Rwkv6Config):
    ks = jax.random.split(key, cfg.n_layers + 4)
    dt = cfg.jdtype
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_cfg, dtype=dt),
        "ln_in": init_layernorm(cfg.d_model, dtype=dt),
        "head": init_lm_head(ks[1], cfg.vocab_cfg, dtype=dt),
        "ln_f": init_layernorm(cfg.d_model, dtype=dt),
    }
    blocks = [_init_block(ks[4 + i], cfg) for i in range(cfg.n_layers)]
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: pm.Boxed(jnp.stack([x.value for x in xs]),
                             (None,) + xs[0].axes),
        *blocks, is_leaf=pm.is_boxed)
    return params


def _block(p, x, tap: Tap, cfg: Rwkv6Config, state=None):
    h = layernorm(p["ln1"], x, tap=tap)
    y, state = rwkv_tmix(p["tmix"], h, tap=tap, cfg=cfg.rwkv_cfg,
                         state=state)
    x = x + y
    h = layernorm(p["ln2"], x, tap=tap)
    y, state = rwkv_cmix(p["cmix"], h, tap=tap, cfg=cfg.rwkv_cfg,
                         state=state)
    return x + y, state


def _run(params, x, tap: Tap, cfg: Rwkv6Config, states=None):
    def body(x, xs):
        p_i, st_i = xs
        x, st_i = _block(p_i, x, tap, cfg, state=st_i)
        return x, st_i

    remat = cfg.remat and states is None
    if cfg.stack_mode == "scan":
        x, states = taps.scan(body, x, (params["blocks"], states),
                              tap=tap, remat=remat)
    else:
        body_fn = taps.checkpoint(body, tap=tap) if remat else body
        outs = []
        for i in range(cfg.n_layers):
            p_i = jax.tree_util.tree_map(lambda v: v[i], params["blocks"])
            st_i = None if states is None else \
                jax.tree_util.tree_map(lambda v: v[i], states)
            x, st_i = body_fn(x, (p_i, st_i))
            outs.append(st_i)
        states = None if outs[0] is None else \
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    return x, states


def loss_fn(params, batch, tap: Tap, *, cfg: Rwkv6Config):
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)
    x = layernorm(params["ln_in"], x, tap=tap)
    x, _ = _run(params, x, tap, cfg)
    x = layernorm(params["ln_f"], x, tap=tap)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    loss_vec = per_example_xent(logits, batch["labels"],
                                batch.get("label_mask"), tap=tap)
    return loss_vec, {}


def init_caches(batch: int, cfg: Rwkv6Config):
    one = init_rwkv_state(batch, cfg.rwkv_cfg, dtype=cfg.jdtype)
    return jax.tree_util.tree_map(
        lambda v: jnp.zeros((cfg.n_layers,) + v.shape, v.dtype), one)


def forward_tokens(params, batch, caches, cache_index, *, cfg: Rwkv6Config):
    tap = taps.NULL
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)
    x = layernorm(params["ln_in"], x, tap=tap)
    x, caches = _run(params, x, tap, cfg, states=caches)
    x = layernorm(params["ln_f"], x, tap=tap)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    return logits, caches
