"""Arch registry: --arch <id> resolves here.

Binds each ArchSpec to its model family's entry points and builds
ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.common import SHAPES, ArchSpec, ShapeSpec, base_rules
from repro.models import rwkv6, seamless, transformer, zamba2

from repro.configs import (deepseek_v2_236b, gemma2_9b, llama3_2_1b,
                           minitron_4b, phi35_moe, qwen2_7b, qwen2_vl_7b,
                           rwkv6_3b, seamless_m4t_medium, zamba2_7b)

ARCHS: Dict[str, ArchSpec] = {
    s.arch_id: s for s in [
        qwen2_vl_7b.SPEC, zamba2_7b.SPEC, llama3_2_1b.SPEC, qwen2_7b.SPEC,
        minitron_4b.SPEC, gemma2_9b.SPEC, rwkv6_3b.SPEC,
        seamless_m4t_medium.SPEC, deepseek_v2_236b.SPEC, phi35_moe.SPEC,
    ]
}

_FAMILIES = {
    "transformer": transformer,
    "zamba2": zamba2,
    "rwkv6": rwkv6,
    "seamless": seamless,
}

#: Parameters *intentionally* outside the pex norm scope, per arch
#: (DESIGN.md §5, §10) — the single source of truth consumed by both
#: the static tap-coverage verifier (``repro.analysis``/
#: ``Engine.verify``) and the exactness-test scope filters
#: (tests/helpers.py), so the analyzer and the oracle can never
#: disagree about scope. Entries are substrings matched against a
#: parameter leaf's key path. zamba2: the weight-shared global block
#: runs with ``taps.NULL`` and the ssm conv/decay tensors
#: (conv_w/conv_b/a_log/d) take non-matmul gradient paths; rwkv6: the
#: token/channel-mix interpolation bases (mu), decay base (w0), and
#: bonus (u) likewise. An arch with untapped trained params *not*
#: declared here fails `python -m repro.analysis`.
UNTAPPED_ALLOWLIST: Dict[str, tuple] = {
    "zamba2-7b": ("shared", "a_log", "'d'", "conv_w", "conv_b"),
    "rwkv6-3b": ("mu", "w0", "'u'"),
}


def untapped_allowlist(arch_id: str) -> tuple:
    """Declared intentionally-untapped path patterns for an arch."""
    return UNTAPPED_ALLOWLIST.get(arch_id, ())


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def family_module(spec: ArchSpec):
    return _FAMILIES[spec.family]


def runnable(arch_id: str, shape_name: str) -> bool:
    return shape_name not in get(arch_id).skip_shapes


def make_loss_fn_v2(spec: ArchSpec, cfg):
    """pex v2 canonical loss for an arch:
    ``loss_fn(params, batch, tap) -> (loss_vec, aux)``."""
    mod = family_module(spec)

    def loss_fn(params, batch, tap):
        return mod.loss_fn(params, batch, tap, cfg=cfg)
    return loss_fn


def make_forward_tokens(spec: ArchSpec, cfg):
    mod = family_module(spec)

    def fwd(params, batch, caches, cache_index):
        return mod.forward_tokens(params, batch, caches, cache_index, cfg=cfg)
    return fwd


def serving_config(spec: ArchSpec, cfg, shape: ShapeSpec):
    """Configure cache lengths for a serve shape."""
    kw = {"max_cache_len": shape.seq, "remat": False}
    if spec.family == "seamless":
        kw["max_src_len"] = shape.seq
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — no allocation; the dry-run contract)
# ---------------------------------------------------------------------------

def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_batch_specs(spec: ArchSpec, cfg, shape: ShapeSpec):
    b, s = shape.batch, shape.seq
    batch = {"ids": _i32(b, s), "labels": _i32(b, s)}
    dt = cfg.jdtype
    if spec.family == "seamless":
        batch["src_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    if getattr(cfg, "vl_inputs", False):
        batch["vis_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
        batch["vis_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        batch["positions"] = _i32(b, 3, s)
    return batch


def serve_batch_specs(spec: ArchSpec, cfg, shape: ShapeSpec, *,
                      prefill: bool):
    b = shape.batch
    s = shape.seq if prefill else 1
    batch = {"ids": _i32(b, s)}
    dt = cfg.jdtype
    if spec.family == "seamless" and prefill:
        batch["src_frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    if getattr(cfg, "vl_inputs", False) and prefill:
        batch["vis_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
        batch["vis_mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        batch["positions"] = _i32(b, 3, s)
    return batch


def cache_specs(spec: ArchSpec, cfg, shape: ShapeSpec):
    mod = family_module(spec)
    return jax.eval_shape(lambda: mod.init_caches(shape.batch, cfg))


def make_train_batch(spec: ArchSpec, cfg, shape: ShapeSpec, rng_seed=0):
    """Concrete synthetic batch matching train_batch_specs (smoke/bench)."""
    import numpy as np
    rng = np.random.default_rng(rng_seed)
    b, s = shape.batch, shape.seq
    batch = {
        "ids": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    dt = cfg.jdtype
    if spec.family == "seamless":
        batch["src_frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.1, dt)
    if getattr(cfg, "vl_inputs", False):
        batch["vis_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.1, dt)
        vm = np.zeros((b, s), bool)
        vm[:, : s // 2] = True  # first half of the stream is visual
        batch["vis_mask"] = jnp.asarray(vm)
        pos = np.broadcast_to(np.arange(s), (b, 3, s)).copy()
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    return batch


def rules_for(spec: ArchSpec, cfg, shape: ShapeSpec, multi_pod: bool,
              model_size: int = 16, data_size: int = 16) -> dict:
    """Logical→mesh rules for one dry-run cell."""
    kv_shardable = True
    if spec.family == "transformer" and cfg.attn is not None:
        kv_shardable = cfg.attn.n_kv % model_size == 0
    if spec.family == "zamba2":
        kv_shardable = cfg.kv_heads % model_size == 0
    dp = data_size * (2 if multi_pod else 1)
    batch_shard = shape.batch % dp == 0
    return base_rules(multi_pod, kv_shardable=kv_shardable,
                      batch_shard=batch_shard,
                      seq_to_data=(shape.name == "long_500k"))
