"""Zamba2-7B: Mamba2 backbone + one *shared* full-attention block applied
every `share_every` layers on concat(h, h⁰) (the original embeddings).

81 mamba blocks = 13 groups of 6 (shared block between groups) + 3 tail
blocks. The mamba stack runs as nested scans (groups × 6); the shared
block's params are closed over (true weight sharing).

Pex scope: mamba blocks are fully tapped. The shared block's params are
reused 13× per forward — the rank-structure the paper's trick exploits
does not factor across re-uses (cross-use Gram terms), so the shared
block is *excluded* from the accumulator (inert tap inside) and
from the per-example norm scope. Recorded in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import Tap
from repro.nn import param as pm
from repro.nn.attention import AttnCfg, attention, init_attention, init_kv_cache
from repro.nn.embedding import (VocabCfg, embed, init_embedding, init_lm_head,
                                lm_head, per_example_xent)
from repro.nn.mlp import MlpCfg, init_mlp, mlp
from repro.nn.norms import init_rmsnorm, rmsnorm
from repro.nn.ssm import SsmCfg, init_ssm, init_ssm_state, ssm


@dataclasses.dataclass(frozen=True)
class Zamba2Config:
    name: str
    n_layers: int = 81
    d_model: int = 3584
    vocab: int = 32000
    d_ff: int = 14336
    n_heads: int = 32
    kv_heads: int = 32
    ssm: SsmCfg = dataclasses.field(
        default_factory=lambda: SsmCfg(d_model=3584, d_state=64))
    share_every: int = 6
    rms_eps: float = 1e-5
    dtype: str = "float32"
    remat: bool = True
    stack_mode: str = "scan"
    max_cache_len: int = 0

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.share_every

    @property
    def n_tail(self) -> int:
        return self.n_layers - self.n_groups * self.share_every

    @property
    def n_shared_uses(self) -> int:
        return self.n_groups

    @property
    def attn_cfg(self) -> AttnCfg:
        # attention over concat(h, h0): 2·d_model, out back to d_model
        return AttnCfg(d_model=2 * self.d_model, n_heads=self.n_heads,
                       n_kv=self.kv_heads, head_dim=2 * self.d_model // self.n_heads,
                       d_out=self.d_model, rope_theta=10000.0)

    @property
    def vocab_cfg(self) -> VocabCfg:
        return VocabCfg(self.vocab, self.d_model)


def _init_mamba_block(key, cfg: Zamba2Config):
    ks = jax.random.split(key, 2)
    dt = cfg.jdtype
    return {"ln": init_rmsnorm(cfg.d_model, dtype=dt),
            "ssm": init_ssm(ks[0], cfg.ssm, dtype=dt)}


def init(key, cfg: Zamba2Config):
    ks = jax.random.split(key, cfg.n_layers + 6)
    dt = cfg.jdtype
    params = {
        "embed": init_embedding(ks[0], cfg.vocab_cfg, dtype=dt),
        "head": init_lm_head(ks[1], cfg.vocab_cfg, dtype=dt),
        "ln_f": init_rmsnorm(cfg.d_model, dtype=dt),
        "shared": {
            "ln": init_rmsnorm(2 * cfg.d_model, dtype=dt),
            "attn": init_attention(ks[2], cfg.attn_cfg, dtype=dt),
            "ln_mlp": init_rmsnorm(cfg.d_model, dtype=dt),
            "mlp": init_mlp(ks[3], MlpCfg(cfg.d_model, cfg.d_ff), dtype=dt),
        },
    }
    blocks = [_init_mamba_block(ks[6 + i], cfg) for i in range(cfg.n_layers)]
    n_grouped = cfg.n_groups * cfg.share_every
    grouped = blocks[:n_grouped]
    params["blocks"] = jax.tree_util.tree_map(
        lambda *xs: pm.Boxed(
            jnp.stack([x.value for x in xs]).reshape(
                (cfg.n_groups, cfg.share_every) + xs[0].value.shape),
            (None, None) + xs[0].axes),
        *grouped, is_leaf=pm.is_boxed)
    if cfg.n_tail:
        params["tail"] = jax.tree_util.tree_map(
            lambda *xs: pm.Boxed(jnp.stack([x.value for x in xs]),
                                 (None,) + xs[0].axes),
            *blocks[n_grouped:], is_leaf=pm.is_boxed)
    return params


def _mamba_block(p, x, tap: Tap, cfg: Zamba2Config, state=None):
    h = rmsnorm(p["ln"], x, tap=tap, eps=cfg.rms_eps)
    y, state = ssm(p["ssm"], h, tap=tap, cfg=cfg.ssm, state=state)
    return x + y, state


def _shared_block(p, x, x0, cfg: Zamba2Config, *, cache=None,
                  cache_index=None):
    """Shared attention+MLP on concat(h, h0); pex-excluded (inert tap:
    weight reuse breaks the per-use rank factorization, DESIGN.md §5)."""
    tap = taps.NULL
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rmsnorm(p["ln"], cat, tap=tap, eps=cfg.rms_eps)
    a, cache = attention(p["attn"], h, tap=tap, cfg=cfg.attn_cfg,
                         cache=cache, cache_index=cache_index)
    x = x + a
    h = rmsnorm(p["ln_mlp"], x, tap=tap, eps=cfg.rms_eps)
    m = mlp(p["mlp"], h, tap=tap, cfg=MlpCfg(cfg.d_model, cfg.d_ff))
    return x + m, cache


def _run(params, x, tap: Tap, cfg: Zamba2Config, *,
         states=None, shared_caches=None, cache_index=None):
    """states: {"blocks": stacked (G,K,...) ssm states, "tail": (T,...)} or
    None (training — fresh zero states are implicit in nn.ssm)."""
    x0 = x
    new_shared = [] if shared_caches is not None else None

    def inner(x, xs):
        p_i, st_i = xs
        x, st_i = _mamba_block(p_i, x, tap, cfg, state=st_i)
        return x, st_i

    remat = cfg.remat and states is None

    new_states = {"blocks": None, "tail": None}
    if cfg.stack_mode == "scan" and shared_caches is None and states is None:
        # training: shared block interleaves via python loop over groups,
        # each group's 6 mamba blocks scanned
        for g in range(cfg.n_groups):
            p_g = jax.tree_util.tree_map(lambda v: v[g], params["blocks"])
            x, _ = taps.scan(inner, x, (p_g, None), tap=tap, remat=remat)
            x, _ = _shared_block(params["shared"], x, x0, cfg)
        if cfg.n_tail:
            x, _ = taps.scan(inner, x, (params["tail"], None), tap=tap,
                             remat=remat)
    else:
        # serving (or unroll): python loop, explicit states/caches
        for g in range(cfg.n_groups):
            p_g = jax.tree_util.tree_map(lambda v: v[g], params["blocks"])
            st_g = None if states is None else \
                jax.tree_util.tree_map(lambda v: v[g], states["blocks"])
            x, st_g = taps.scan(inner, x, (p_g, st_g), tap=tap, remat=remat)
            if states is not None:
                new_states.setdefault("blocks_list", []).append(st_g)
            c = None if shared_caches is None else \
                jax.tree_util.tree_map(lambda v: v[g], shared_caches)
            x, c = _shared_block(params["shared"], x, x0, cfg, cache=c,
                                 cache_index=cache_index)
            if shared_caches is not None:
                new_shared.append(c)
        if cfg.n_tail:
            st_t = None if states is None else states["tail"]
            x, st_t = taps.scan(inner, x, (params["tail"], st_t), tap=tap,
                                remat=remat)
            new_states["tail"] = st_t
    if states is not None:
        new_states["blocks"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_states.pop("blocks_list"))
    if shared_caches is not None:
        new_shared = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                            *new_shared)
    return x, new_states if states is not None else None, new_shared


def loss_fn(params, batch, tap: Tap, *, cfg: Zamba2Config):
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)
    x, _, _ = _run(params, x, tap, cfg)
    x = rmsnorm(params["ln_f"], x, tap=tap, eps=cfg.rms_eps)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    loss_vec = per_example_xent(logits, batch["labels"],
                                batch.get("label_mask"), tap=tap)
    return loss_vec, {}


def init_caches(batch: int, cfg: Zamba2Config):
    dt = cfg.jdtype
    st_one = init_ssm_state(batch, cfg.ssm, dtype=dt)
    states = {
        "blocks": jax.tree_util.tree_map(
            lambda v: jnp.zeros((cfg.n_groups, cfg.share_every) + v.shape,
                                v.dtype), st_one),
        "tail": jax.tree_util.tree_map(
            lambda v: jnp.zeros((cfg.n_tail,) + v.shape, v.dtype), st_one)
        if cfg.n_tail else None,
    }
    kv_one = init_kv_cache(batch, cfg.max_cache_len, cfg.attn_cfg, dtype=dt)
    shared = jax.tree_util.tree_map(
        lambda v: jnp.zeros((cfg.n_groups,) + v.shape, v.dtype), kv_one)
    return {"states": states, "shared": shared}


def forward_tokens(params, batch, caches, cache_index, *, cfg: Zamba2Config):
    tap = taps.NULL
    x = embed(params["embed"], batch["ids"], tap=tap, cfg=cfg.vocab_cfg)
    x, states, shared = _run(params, x, tap, cfg,
                             states=caches["states"],
                             shared_caches=caches["shared"],
                             cache_index=cache_index)
    x = rmsnorm(params["ln_f"], x, tap=tap, eps=cfg.rms_eps)
    logits = lm_head(params["head"], x, tap=tap, cfg=cfg.vocab_cfg)
    return logits, {"states": states, "shared": shared}
