"""Kernel-launch validator: prove every Pallas launch is well-formed
without compiling it (pexlint pass 3, DESIGN.md §10).

The Pallas wrappers (``kernels/ops.py``) pick tiles and padding per
logical shape; the kernels assert divisibility and allocate VMEM
scratch. On a real TPU a bad schedule fails at Mosaic compile time; in
interpret mode on CPU — the CI target — it may not fail at all. This
pass closes that gap statically: each kernel module declares its
launch geometry as a ``LaunchContract`` (kernels/contract.py), the
wrapper-side builders reproduce the exact padding/tile arithmetic of
the call site, and ``contract.validate`` checks

  * tile divisibility against the chunk schedule,
  * estimated VMEM footprint (double-buffered in/out blocks + resident
    scratch) against the per-backend budget,
  * the f32-accumulator dtype rule on every partial-sum buffer.

Workloads come from two sources: the **tap sites of an actual trace**
(``coverage.TapSite`` records every instrumented op's operand shapes,
so the validator exercises the exact geometries the estimators would
launch for that model), and **config-derived production cases** (full
model dims at the assigned train shape, including the flash-attention
geometry that small smoke traces never reach).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.kernels import contract as _c
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class LaunchReport:
    contracts: Tuple[_c.LaunchContract, ...]
    errors: Tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        head = (f"{len(self.contracts)} kernel launches checked, "
                f"{len(self.errors)} ERROR")
        return "\n".join([head] + [f"  ERROR {e}" for e in self.errors])

    def raise_if_errors(self) -> "LaunchReport":
        if not self.ok:
            raise AssertionError("kernel launch validation failed:\n"
                                 + self.summary())
        return self


def _dt(name: str):
    return jnp.dtype(name)


def contracts_for_site(site) -> list:
    """Launch contracts for the kernels one tap site's stat could
    dispatch to, at the exact operand shapes of the trace."""
    out = []
    avals = site.operand_avals
    if site.op == "dense":
        (h_shape, h_dt), (w_shape, _) = avals[0], avals[1]
        p_in, p_out = w_shape[-2], w_shape[-1]
        dt = _dt(h_dt)
        if len(h_shape) >= 3:
            b, s = h_shape[0], h_shape[1]
            out.append(ops.gram_contract(b, s, p_in, p_out, dtype=dt))
            out.append(ops.direct_contract(b, s, p_in, p_out, dtype=dt))
            out.append(ops.clip_scale_contract(b, s, p_out, dtype=dt))
            out.append(ops.rowsumsq_contract(b, s * p_in, dtype=dt))
        else:
            b = h_shape[0]
            out.append(ops.rowsumsq_contract(b, p_in, dtype=dt))
            out.append(ops.rowsumsq_contract(b, p_out, dtype=dt))
    elif site.op in ("bias_add", "scale", "embedding"):
        # stat work for these is rowsumsq-shaped (factorized/elementwise)
        z_shape, z_dt = avals[0]
        n = 1
        for d in z_shape[1:]:
            n *= d
        out.append(ops.rowsumsq_contract(z_shape[0], n, dtype=_dt(z_dt)))
    elif site.op == "dense_expert":
        (x_shape, x_dt), (w_shape, _) = avals[0], avals[1]
        acc_shape, _ = avals[-1]
        e, c, d = x_shape
        f = w_shape[-1]
        n_seg = e * (acc_shape[0] + 1)
        out.append(ops.segmented_contract(e * c, d, f, n_seg,
                                          dtype=_dt(x_dt)))
    elif site.op == "dense_expert_grouped":
        (x_shape, x_dt), (w_shape, _) = avals[0], avals[1]
        acc_shape, _ = avals[-1]
        ng, e, c, d = x_shape
        f = w_shape[-1]
        bg = max(acc_shape[0] // max(ng, 1), 1)
        n_seg = ng * e * (bg + 1)
        out.append(ops.segmented_contract(ng * e * c, d, f, n_seg,
                                          dtype=_dt(x_dt)))
    return out


def contracts_for_sites(sites: Sequence) -> list:
    out = []
    for site in sites:
        out.extend(contracts_for_site(site))
    return out


def production_cases(cfg, *, batch: int = 8, seq: int = 4096) -> list:
    """Config-derived launch cases at production dims: the dense-stat
    kernels at (d_model, d_ff) / (d_model, vocab), and the
    flash-attention forward/backward geometry (never reached by smoke
    traces — attention only goes through the Pallas path when
    S % 128 == 0)."""
    out = []
    dt = getattr(cfg, "jdtype", jnp.float32)
    d_model = getattr(cfg, "d_model", None)
    d_ff = getattr(cfg, "d_ff", None) \
        or getattr(getattr(cfg, "mlp", None), "d_ff", None)
    vocab = getattr(cfg, "vocab", None)
    if d_model:
        pairs = [(d_model, d_model)]
        if d_ff:
            pairs += [(d_model, d_ff), (d_ff, d_model)]
        if vocab:
            pairs.append((d_model, vocab))
        for p_in, p_out in pairs:
            out.append(ops.gram_contract(batch, seq, p_in, p_out, dtype=dt))
            out.append(ops.direct_contract(batch, seq, p_in, p_out,
                                           dtype=dt))
            out.append(ops.clip_scale_contract(batch, seq, p_out, dtype=dt))
        out.append(ops.rowsumsq_contract(batch, seq * d_model, dtype=dt))
    attn = getattr(cfg, "attn", None)
    n_heads = getattr(attn, "n_heads", None) or getattr(cfg, "n_heads", None)
    n_kv = getattr(attn, "n_kv", None) or getattr(cfg, "kv_heads", None) \
        or n_heads
    head_dim = getattr(attn, "head_dim", None) \
        or getattr(cfg, "head_dim", None)
    if n_heads and head_dim:
        out.extend(ops.attention_contracts(batch, n_heads, n_kv, seq, seq,
                                           head_dim, dtype=dt))
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        n_exp = getattr(moe, "n_experts", None)
        d_exp = getattr(moe, "d_ff", None) or d_ff
        if n_exp and d_model and d_exp:
            cap = (batch * seq * getattr(moe, "top_k", 2)) // n_exp
            out.append(ops.segmented_contract(
                n_exp * max(cap, 1), d_model, d_exp,
                n_exp * (batch + 1), dtype=dt))
    return out


def validate_contracts(contracts: Sequence, *,
                       backend: str = "tpu") -> LaunchReport:
    errors = []
    for ct in contracts:
        errors.extend(_c.validate(ct, backend))
    # identical workloads repeat across layers/sites — dedupe messages
    seen, uniq = set(), []
    for e in errors:
        if e not in seen:
            seen.add(e)
            uniq.append(e)
    return LaunchReport(tuple(contracts), tuple(uniq))


def validate_sites(sites: Sequence, cfg=None, *, backend: str = "tpu",
                   batch: int = 8, seq: int = 4096,
                   production: bool = True) -> LaunchReport:
    """Full pass: trace-site workloads plus (optionally) the
    config-derived production cases."""
    contracts = contracts_for_sites(sites)
    if production and cfg is not None:
        contracts.extend(production_cases(cfg, batch=batch, seq=seq))
    return validate_contracts(contracts, backend=backend)
