"""``python -m repro.analysis`` — lint every registered model.

For each arch × granularity {example, token} × consumer-set
combination, run plan analysis, tap-coverage verification, and
kernel-launch validation, entirely at trace level: params come from
``jax.eval_shape`` over the initializer, batches from
``registry.train_batch_specs`` — no weights are ever materialized and
no XLA compilation happens. A guard on the XLA compile entry point
enforces that (``--no-trace-guard`` to disable, e.g. when adding an
opt-in compiled pass); the CI ``lint`` job relies on it to stay under
its time budget on CPU.

Exit status: 0 when every combination is clean, 1 with
``--fail-on-error`` when any coverage/launch error survives.
"""
from __future__ import annotations

import argparse
import sys
import time


def _consumer_sets(granularity: str, key):
    from repro import pex
    if granularity == "token":
        # token+GNS and token+Importance are rejected by analyze();
        # Noise must carry an explicit sensitivity at token clip
        return [[], [pex.Norms()],
                [pex.Clip(1.0, granularity="token"),
                 pex.Noise(0.1, key, scale=1.0)]]
    return [[], [pex.Norms()],
            [pex.Clip(1.0), pex.Noise(0.1, key), pex.GNS()]]


class _TraceOnlyGuard:
    """Fail loudly if anything under the lint reaches XLA compilation."""

    def __enter__(self):
        from jax._src import compiler
        self._compiler = compiler
        self._orig = compiler.backend_compile

        def _blocked(*a, **kw):
            raise RuntimeError(
                "pexlint is trace-only, but something tried to compile an "
                "XLA computation; keep analyzers on jax.make_jaxpr / "
                "jax.eval_shape (or rerun with --no-trace-guard)")

        compiler.backend_compile = _blocked
        return self

    def __exit__(self, *exc):
        self._compiler.backend_compile = self._orig
        return False


def lint_arch(arch_id: str, *, backend: str, production: bool,
              key) -> list:
    """All error strings for one arch across every lint combination."""
    import jax
    from repro.analysis.verify import verify as _verify
    from repro.configs.common import ShapeSpec
    from repro.models import registry
    from repro.nn.param import unbox

    aspec = registry.get(arch_id)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = jax.eval_shape(
        lambda: unbox(mod.init(jax.random.PRNGKey(0), cfg)))
    shape = ShapeSpec("lint", "train", 8, 3)
    batch = registry.train_batch_specs(aspec, cfg, shape)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    allow = registry.untapped_allowlist(arch_id)

    errors = []
    for gran in ("example", "token"):
        try:
            rep = _verify(
                loss_fn, params, batch, _consumer_sets(gran, key),
                granularity=gran, allow=allow, seq=shape.seq,
                cfg=aspec.full(), backend=backend,
                production=production and gran == "example")
        except Exception as e:  # a trace failure is itself a lint error
            errors.append(f"{arch_id}[{gran}]: {type(e).__name__}: {e}")
            continue
        errors.extend(f"{arch_id}[{gran}]: {e}" for e in rep.errors)
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="pexlint: static tap-coverage, plan, and "
                    "kernel-launch checks")
    ap.add_argument("--all-models", action="store_true",
                    help="lint every registered arch")
    ap.add_argument("--arch", action="append", default=[],
                    help="lint one arch id (repeatable)")
    ap.add_argument("--fail-on-error", action="store_true",
                    help="exit 1 if any lint error is found")
    ap.add_argument("--backend", default="tpu",
                    help="launch-contract budget profile (default: tpu)")
    ap.add_argument("--no-production", action="store_true",
                    help="skip the config-derived production-shape "
                         "launch cases")
    ap.add_argument("--no-trace-guard", action="store_true",
                    help="allow XLA compilation during the lint")
    args = ap.parse_args(argv)

    from repro.models import registry
    arch_ids = sorted(registry.ARCHS) if args.all_models or not args.arch \
        else args.arch

    # concrete PRNG key for the Noise consumer — created BEFORE the
    # trace guard goes up (key creation itself compiles a tiny program)
    import jax
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    all_errors = []
    guard = _TraceOnlyGuard() if not args.no_trace_guard else None
    try:
        if guard is not None:
            guard.__enter__()
        for aid in arch_ids:
            t1 = time.time()
            errs = lint_arch(aid, backend=args.backend,
                             production=not args.no_production, key=key)
            all_errors.extend(errs)
            status = "ok" if not errs else f"{len(errs)} ERROR"
            print(f"  {aid:24s} {status:12s} {time.time() - t1:5.1f}s")
    finally:
        if guard is not None:
            guard.__exit__(None, None, None)

    for e in all_errors:
        print(f"ERROR {e}")
    n = len(all_errors)
    print(f"pexlint: {len(arch_ids)} arch(s), {n} error(s), "
          f"{time.time() - t0:.1f}s")
    return 1 if (n and args.fail_on_error) else 0


if __name__ == "__main__":
    sys.exit(main())
