"""``python -m repro.analysis`` — lint every registered model.

For each arch × granularity {example, token} × consumer-set
combination, run plan analysis, tap-coverage verification,
kernel-launch validation, and the flow passes — privacy (DP dataflow
over a full traced step), collectives (shard_map layout against a
one-device data mesh), determinism (data-pipeline purity, checked once
per run) — entirely at trace level: params come from
``jax.eval_shape`` over the initializer, batches from
``registry.train_batch_specs`` — no weights are ever materialized and
no XLA compilation happens. A guard on the XLA compile entry point
enforces that (``--no-trace-guard`` to disable, e.g. when adding an
opt-in compiled pass); the CI ``lint`` jobs rely on it to stay under
their time budget on CPU.

``--fast`` skips the flow passes (coverage + plan + launch only) — the
CI ``lint-fast`` job's mode; ``lint-full`` runs everything. ``--json``
emits the findings machine-readably on stdout (human status lines move
to stderr).

Exit status (``resolve_exit``): errors fail the run only under
``--fail-on-error``; warnings only under ``--fail-on-warn``. A
warnings-only run under ``--fail-on-error`` exits 0 — warnings are
advisory (stale allowlist entries, unregistered allowlist keys) and
must not break CI that only gates on errors.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List


def _consumer_sets(granularity: str, key):
    from repro import pex
    if granularity == "token":
        # token+GNS and token+Importance are rejected by analyze();
        # Noise must carry an explicit sensitivity at token clip
        return [[], [pex.Norms()],
                [pex.Clip(1.0, granularity="token"),
                 pex.Noise(0.1, key, scale=1.0)]]
    return [[], [pex.Norms()],
            [pex.Clip(1.0), pex.Noise(0.1, key), pex.GNS()]]


class _TraceOnlyGuard:
    """Fail loudly if anything under the lint reaches XLA compilation."""

    def __enter__(self):
        from jax._src import compiler
        self._compiler = compiler
        self._orig = compiler.backend_compile

        def _blocked(*a, **kw):
            raise RuntimeError(
                "pexlint is trace-only, but something tried to compile an "
                "XLA computation; keep analyzers on jax.make_jaxpr / "
                "jax.eval_shape (or rerun with --no-trace-guard)")

        compiler.backend_compile = _blocked
        return self

    def __exit__(self, *exc):
        self._compiler.backend_compile = self._orig
        return False


def lint_arch(arch_id: str, *, backend: str, production: bool,
              key, mesh=None, deep: bool = True, cost: bool = False,
              profile=None):
    """(findings, cost reports) for one arch across every lint
    combination."""
    import jax
    from repro.analysis import findings as F
    from repro.analysis.verify import verify as _verify
    from repro.configs.common import ShapeSpec
    from repro.models import registry
    from repro.nn.param import unbox

    aspec = registry.get(arch_id)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = jax.eval_shape(
        lambda: unbox(mod.init(jax.random.PRNGKey(0), cfg)))
    shape = ShapeSpec("lint", "train", 8, 3)
    batch = registry.train_batch_specs(aspec, cfg, shape)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    allow = registry.untapped_allowlist(arch_id)

    found: List = []
    costs: List = []
    for gran in ("example", "token"):
        try:
            rep = _verify(
                loss_fn, params, batch, _consumer_sets(gran, key),
                granularity=gran, allow=allow, seq=shape.seq,
                cfg=aspec.full(), backend=backend,
                production=production and gran == "example",
                mesh=mesh if gran == "example" else None,
                deep=deep, determinism=False,
                cost=cost, profile=profile, model=arch_id)
        except Exception as e:  # a trace failure is itself a lint error
            found.append(F.Finding(
                "trace", F.ERROR, "trace-failure",
                f"{type(e).__name__}: {e}", model=arch_id,
                granularity=gran))
            continue
        costs.extend(rep.cost)
        per_gran: List = [
            F.Finding("coverage", F.ERROR, "untapped-leaf",
                      f"{l.path} is {l.status}", leaf=str(l.path))
            for l in rep.coverage.errors]
        per_gran += [F.Finding("launch", F.ERROR, "contract-violation", e)
                     for e in rep.launch.errors]
        per_gran += [F.Finding("coverage", F.WARNING, "stale-allow-entry",
                               f"allowlist entry {a!r} matches no "
                               f"parameter leaf of {arch_id}")
                     for a in rep.coverage.stale_allow]
        per_gran += list(rep.findings)
        found.extend(F.tag(per_gran, model=arch_id, granularity=gran))
    return found, costs


def _default_baseline() -> str:
    import os
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "COST_BASELINE.json")


def _cost_gate(args, costs, say) -> List:
    """Baseline-gate findings for this run's CostReports; also writes
    the baseline / artifact files when asked."""
    import os
    from repro.analysis import cost as C

    path = args.cost_baseline or _default_baseline()
    if args.cost_report:
        with open(args.cost_report, "w") as f:
            json.dump({"profile": costs[0].profile if costs else None,
                       "reports": [c.to_json() for c in costs]}, f,
                      indent=2, sort_keys=True)
        say(f"pexcost: wrote {len(costs)} CostReport(s) to "
            f"{args.cost_report}")
    if args.write_cost_baseline:
        with open(path, "w") as f:
            json.dump(C.baseline_payload(costs), f, indent=2)
            f.write("\n")
        say(f"pexcost: wrote baseline {path}")
        return []
    if not os.path.exists(path):
        from repro.analysis import findings as F
        return [F.Finding(C.PASS, F.WARNING, "cost-baseline-missing",
                          f"no baseline at {path}; create it with "
                          f"--write-cost-baseline")]
    with open(path) as f:
        baseline = json.load(f)
    out = C.check_baseline(costs, baseline, full_matrix=args.all_models)
    say(f"pexcost: {len(costs)} report(s) vs {os.path.basename(path)}, "
        f"{sum(f.severity == 'error' for f in out)} regression(s)")
    return out


def registry_findings() -> List:
    """Run-level registry hygiene: allowlist keys must name archs."""
    from repro.analysis import findings as F
    from repro.models import registry
    return [F.Finding("coverage", F.WARNING, "unknown-allowlist-key",
                      f"UNTAPPED_ALLOWLIST key {k!r} is not a "
                      f"registered arch id")
            for k in sorted(registry.UNTAPPED_ALLOWLIST)
            if k not in registry.ARCHS]


def resolve_exit(n_errors: int, n_warnings: int, fail_on_error: bool,
                 fail_on_warn: bool) -> int:
    """Errors gate only under --fail-on-error, warnings only under
    --fail-on-warn; a warnings-only run is a pass for error-gated CI."""
    if fail_on_error and n_errors:
        return 1
    if fail_on_warn and n_warnings:
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="pexlint: static tap-coverage, plan, kernel-launch, "
                    "privacy-flow, collective-layout, and determinism "
                    "checks")
    ap.add_argument("--all-models", action="store_true",
                    help="lint every registered arch")
    ap.add_argument("--arch", action="append", default=[],
                    help="lint one arch id (repeatable)")
    ap.add_argument("--fail-on-error", action="store_true",
                    help="exit 1 if any lint ERROR is found")
    ap.add_argument("--fail-on-warn", action="store_true",
                    help="exit 1 if any WARNING is found (errors too)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--fast", action="store_true",
                    help="coverage/plan/launch only — skip the flow "
                         "passes (CI lint-fast)")
    ap.add_argument("--cost", action="store_true",
                    help="run the pexcost traffic/cost passes over a "
                         "full traced training step per consumer set "
                         "(independent of --fast)")
    ap.add_argument("--profile", default=None,
                    help="hardware profile for CostReports "
                         "(roofline.constants.PROFILES; default tpu-v5e)")
    ap.add_argument("--cost-baseline", default=None,
                    help="committed prediction baseline to gate against "
                         "(default: COST_BASELINE.json in the repo root)")
    ap.add_argument("--write-cost-baseline", action="store_true",
                    help="rewrite the baseline from this run instead of "
                         "gating against it")
    ap.add_argument("--cost-report", default=None,
                    help="write the full CostReport JSON artifact here")
    ap.add_argument("--backend", default="tpu",
                    help="launch-contract budget profile (default: tpu)")
    ap.add_argument("--no-production", action="store_true",
                    help="skip the config-derived production-shape "
                         "launch cases")
    ap.add_argument("--no-trace-guard", action="store_true",
                    help="allow XLA compilation during the lint")
    args = ap.parse_args(argv)
    say = (lambda m: print(m, file=sys.stderr)) if args.json else print

    from repro.models import registry
    arch_ids = sorted(registry.ARCHS) if args.all_models or not args.arch \
        else args.arch

    # concrete PRNG key and the one-device data mesh for the collective
    # pass — both created BEFORE the trace guard goes up (key creation
    # itself compiles a tiny program)
    import jax
    import numpy as np
    from jax.sharding import Mesh
    key = jax.random.PRNGKey(0)
    mesh = None if args.fast else Mesh(np.array(jax.devices()[:1]),
                                       ("data",))

    t0 = time.time()
    findings: List = list(registry_findings())
    costs: List = []
    guard = _TraceOnlyGuard() if not args.no_trace_guard else None
    try:
        if guard is not None:
            guard.__enter__()
        if not args.fast:
            from repro.analysis import determinism as det
            findings.extend(det.analyze().findings)
        for aid in arch_ids:
            t1 = time.time()
            fs, cs = lint_arch(aid, backend=args.backend,
                               production=not args.no_production, key=key,
                               mesh=mesh, deep=not args.fast,
                               cost=args.cost, profile=args.profile)
            findings.extend(fs)
            costs.extend(cs)
            n_e = sum(f.severity == "error" for f in fs)
            status = "ok" if not n_e else f"{n_e} ERROR"
            say(f"  {aid:24s} {status:12s} {time.time() - t1:5.1f}s")
    finally:
        if guard is not None:
            guard.__exit__(None, None, None)

    if args.cost:
        findings.extend(_cost_gate(args, costs, say))

    n_err = sum(f.severity == "error" for f in findings)
    n_warn = sum(f.severity == "warning" for f in findings)
    for f in findings:
        say(f.render())
    say(f"pexlint: {len(arch_ids)} arch(s), {n_err} error(s), "
        f"{n_warn} warning(s), {time.time() - t0:.1f}s")
    if args.json:
        payload = {
            "archs": arch_ids, "errors": n_err, "warnings": n_warn,
            "elapsed_s": round(time.time() - t0, 2),
            "findings": [f.to_json() for f in findings],
        }
        if args.cost:
            payload["cost"] = [c.to_json() for c in costs]
            payload["plans"] = [
                {"model": c.model, "granularity": c.granularity,
                 "plan": c.plan_desc, "flops_hlo": c.flops_hlo,
                 "hbm_bytes": c.hbm_bytes} for c in costs]
        print(json.dumps(payload, indent=2))
    return resolve_exit(n_err, n_warn, args.fail_on_error,
                        args.fail_on_warn)


if __name__ == "__main__":
    sys.exit(main())
