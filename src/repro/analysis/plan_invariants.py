"""Plan-invariant analyzers: the zero-overhead and one-forward-budget
claims, pinned by HLO cost (pexlint pass 2, DESIGN.md §10).

These promote the flop/byte assertions that grew up inside
``tests/test_dce.py`` and ``benchmarks/bench_plan.py`` into reusable
checks, so the CLI lints them per model and the test/bench callers
share one definition of each invariant:

  * a DISABLED spec (or an enabled spec whose stats nobody reads)
    lowers to the plain model — the DCE property;
  * ``step([])`` compiles to exactly the plain forward;
  * ``step([Grads()])`` costs no more than plain ``value_and_grad``;
  * the Clip plan fits the one-forward budget
    ``cost(norms) + (cost(grad) − cost(forward))`` — one tapped
    forward, one activation backward, ONE reweighted backward.

Each invariant exists at two levels: a pure arithmetic ``check_*``
(takes costs, raises AssertionError — what the bench calls after
measuring once) and a compile-and-check ``assert_*`` (takes the model,
measures, delegates — what the tests and the CLI call). The ``assert_*``
level does compile HLO, so it is NOT trace-only; the CLI keeps it
behind an opt-in flag.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import Engine
from repro.core.taps import DISABLED, ExampleLayout, NULL, PexSpec, Tap
from repro.roofline.hlo import compiled_cost

#: "the same program" modulo float accounting noise
EQ_TOL = 1e-6
#: Clip-plan headroom over the 1F + 1aB + 1wB budget
BUDGET_TOL = 0.02
#: Noise+GNS epsilon over the Clip plan alone (O(n_params) extras)
EPS_TOL = 0.25


def cost_of(fn, *args) -> Tuple[float, float]:
    """(flops, bytes) of ``jit(fn)(*args)`` from XLA's cost model."""
    return compiled_cost(jax.jit(fn).lower(*args).compile())


def grad_cost(loss_fn, params, batch,
              spec: Optional[PexSpec]) -> Tuple[float, float]:
    """Cost of grad-wrt-params of the total loss; ``spec=None`` runs
    the NULL tap (plain model), otherwise a live Tap whose accumulator
    gradient is never requested."""
    b = jax.tree_util.tree_leaves(batch)[0].shape[0]

    def total(p):
        if spec is None:
            lv, _ = loss_fn(p, batch, NULL)
        else:
            tap = Tap(spec, acc=ExampleLayout(spec.n_groups).init(b))
            lv, _ = loss_fn(p, batch, tap)
        return jnp.sum(lv)

    return cost_of(jax.grad(total), params)


# ---------------------------------------------------------------------------
# pure checks (cost arithmetic only — no compilation)
# ---------------------------------------------------------------------------

def check_empty_plan(f_empty: float, f_fwd: float, *,
                     tol: float = EQ_TOL) -> None:
    if f_fwd <= 0.0:
        return
    assert abs(f_empty - f_fwd) <= tol * f_fwd, (
        f"step([]) is not the plain forward: {f_empty} vs {f_fwd}")


def check_grads_plan(f_gonly: float, f_grad: float, *,
                     tol: float = EQ_TOL) -> None:
    assert f_gonly <= f_grad * (1 + tol), (
        f"step([Grads()]) exceeds plain value_and_grad: "
        f"{f_gonly} vs {f_grad}")


def backward_budget(f_norms: float, f_grad: float, f_fwd: float) -> float:
    """The one-forward flop budget for any norm-consuming plan: the
    norms pass already pays one tapped forward + one activation
    backward; a reweighted parameter backward may add at most
    ``cost(plain grad) − cost(plain forward)``."""
    return f_norms + (f_grad - f_fwd)


def check_backward_budget(f_plan: float, f_norms: float, f_grad: float,
                          f_fwd: float, *,
                          tol: float = BUDGET_TOL) -> None:
    budget = backward_budget(f_norms, f_grad, f_fwd)
    assert f_plan <= budget * (1 + tol), (
        f"plan exceeds the one-forward budget (a second forward crept "
        f"in?): {f_plan} vs budget {budget}")


def check_fused_epsilon(f_fused: float, f_base: float, *,
                        tol: float = EPS_TOL) -> None:
    assert f_fused <= f_base * (1 + tol), (
        f"extra consumers are not folding into the base plan: "
        f"{f_fused} vs {f_base}")


def check_dce(f_inst: float, b_inst: float, f_plain: float,
              b_plain: float, *, tol: float = EQ_TOL,
              exact: bool = False) -> None:
    """Instrumented-but-unread stat chains must be dead code. With
    ``exact`` the programs must match bidirectionally (DISABLED spec);
    otherwise the instrumented program may lower marginally cheaper
    (custom_vjp bwd rules emit slightly different HLO under remat) but
    never costlier."""
    if exact:
        assert abs(f_inst - f_plain) <= tol * max(f_plain, 1.0), (
            f"disabled taps changed the program: flops {f_inst} vs "
            f"{f_plain}")
        assert abs(b_inst - b_plain) <= tol * max(b_plain, 1.0), (
            f"disabled taps changed the program: bytes {b_inst} vs "
            f"{b_plain}")
    else:
        assert f_inst <= f_plain * (1 + tol), (
            f"unread stat work survived DCE: flops {f_inst} vs {f_plain}")
        assert b_inst <= b_plain * (1 + tol), (
            f"unread stat work survived DCE: bytes {b_inst} vs {b_plain}")


# ---------------------------------------------------------------------------
# compile-and-check analyzers (measure, then delegate)
# ---------------------------------------------------------------------------

def assert_disabled_spec_is_plain(loss_fn, params, batch, *,
                                  tol: float = EQ_TOL) -> None:
    """DISABLED taps compile to the plain model, flop- and byte-exact."""
    f_p, b_p = grad_cost(loss_fn, params, batch, None)
    f_o, b_o = grad_cost(loss_fn, params, batch, DISABLED)
    check_dce(f_o, b_o, f_p, b_p, tol=tol, exact=True)


def assert_unrequested_norms_dce(loss_fn, params, batch, *,
                                 spec: Optional[PexSpec] = None,
                                 tol: float = EQ_TOL) -> None:
    """Taps ENABLED, grad w.r.t. params only: every stat chain must be
    DCE-dead — no flop/byte cost over the plain model."""
    spec = spec if spec is not None else PexSpec(enabled=True,
                                                method="gram")
    f_p, b_p = grad_cost(loss_fn, params, batch, None)
    f_i, b_i = grad_cost(loss_fn, params, batch, spec)
    check_dce(f_i, b_i, f_p, b_p, tol=tol, exact=False)


def assert_empty_plan_is_plain(loss_fn, params, batch, *,
                               engine: Optional[Engine] = None,
                               tol: float = EQ_TOL) -> None:
    """``Engine.step(consumers=[])`` lowers to exactly the plain
    forward — plan analysis with nothing demanded never creates taps."""
    eng = engine if engine is not None else Engine(
        PexSpec(enabled=True, method="gram"))

    def plain_fwd(p):
        return jnp.sum(loss_fn(p, batch, NULL)[0])

    f_fwd, _ = cost_of(plain_fwd, params)
    f_empty, _ = cost_of(
        lambda p: eng.step(loss_fn, p, batch, []).loss, params)
    check_empty_plan(f_empty, f_fwd, tol=tol)


def assert_backward_budget(loss_fn, params, batch, consumers, *,
                           engine: Optional[Engine] = None,
                           tol: float = BUDGET_TOL) -> None:
    """A norm-consuming plan (Clip and friends) fits the one-forward
    budget: cost(norms pass) + (cost(plain grad) − cost(plain fwd))."""
    from repro import pex
    eng = engine if engine is not None else Engine(
        PexSpec(enabled=True, method="gram"), clip_norm=1.0)

    def plain_fwd(p):
        return jnp.sum(loss_fn(p, batch, NULL)[0])

    f_fwd, _ = cost_of(plain_fwd, params)
    f_grad, _ = cost_of(jax.value_and_grad(plain_fwd), params)
    f_norms, _ = cost_of(
        lambda p: eng.step(loss_fn, p, batch, [pex.Norms()]).sq_norms,
        params)
    f_plan, _ = cost_of(
        lambda p: eng.step(loss_fn, p, batch, list(consumers)).grads,
        params)
    check_backward_budget(f_plan, f_norms, f_grad, f_fwd, tol=tol)
