"""HBM-traffic attribution over a full *training* step — the static
half of the paper's overhead claim ("pexcost", DESIGN.md §13).

The paper's value proposition is a cost statement: per-example norms
ride along with one backward at near-zero extra traffic. The repo
checks that empirically (BENCH_PR*.json) and piecemeal (the HLO
one-forward budget); this pass certifies it *statically*, over the
whole step — ``plan.execute``/``dist.pex.plan_step`` **plus** the
optimizer apply (clip-scale, noise add, AdamW/Adafactor moments),
which no other pass covers.

The walk (over ``_jaxpr.Walker``, on the DCE'd jaxpr of
``_jaxpr.trace_train_step``) labels every equation with

  * a **phase** — forward / activation-bwd / weight-bwd / stats /
    apply — from taint lineage: gradient-leaf markers
    (``core.provenance.mark_grad_tree``, planted by ``plan.execute``
    at the plan/apply boundary), backward-seed markers, optimizer-
    state invars, and the noise key;
  * a **fusion component** — elementwise producer→consumer chains of
    equal reduction rank merge (reductions absorb their input chains;
    single-consumer dtype converts ride along; markers are barriers)
    — the static stand-in for XLA fusion, so "materialized bytes"
    means bytes that cross HBM, not every intermediate.

From those it derives the per-leaf **gradient stream count**: the
number of apply-phase fusion components that re-read a full
leaf-sized, gradient-tainted array. Today's DP-SGD path streams every
gradient three times (noise add; the optimizer's global-norm
reduction; the fused scale/moments/update) — the known 3× waste the
ROADMAP's fused-apply item documents, reported here as an allowlisted
finding so the future fused kernel lands against a ready oracle.

Findings:

  * ``redundant-hbm-stream`` — more full-gradient HBM passes than the
    plan + optimizer structurally require (the allowlisted "expected
    today" count is reported separately);
  * ``duplicate-forward``   — forward-phase flops exceed the plain
    forward of the same model (× the importance-region factor);
  * ``dead-residual``       — a two-backward plan whose reweighted
    backward does not reuse the norms backward's residuals (a second
    linearization doubles residual traffic);
  * ``upcast-materialization`` — a widening dtype copy of a gradient
    leaf materialized as its own pass (f32 copies of bf16 trees).

Trace-only: ``jax.make_jaxpr`` + pure-python graph analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis import _jaxpr as _J
from repro.analysis.findings import ERROR, Finding
from repro.core.provenance import (MARK_PRIMITIVE, TAG_GLEAF, TAG_RNG,
                                   TAG_SEED, meta_dict)

PASS = "traffic"
EMPTY = _J.EMPTY

#: taint tokens
T_PARAM = "p"
T_OPT = "opt"
T_BATCH = "b"
T_KEY = "key"
T_NOISEKEY = "nz"       # the DP noise draw's key lineage (rng_use marker)

#: phases, in attribution priority order
PH_APPLY = "apply"
PH_STATS = "stats"
PH_WEIGHT = "weight-bwd"
PH_ACT = "activation-bwd"
PH_FWD = "forward"
PHASES = (PH_FWD, PH_ACT, PH_WEIGHT, PH_STATS, PH_APPLY)

# ---------------------------------------------------------------------------
# primitive classification
# ---------------------------------------------------------------------------

#: elementwise compute that XLA fuses freely (1 flop / output element
#: when the output is floating)
_ELEM = frozenset({
    "add", "sub", "mul", "div", "rem", "max", "min", "pow", "atan2",
    "neg", "sign", "floor", "ceil", "round", "abs", "exp", "exp2", "log",
    "log1p", "expm1", "tanh", "sqrt", "rsqrt", "cbrt", "logistic", "erf",
    "erfc", "erf_inv", "sin", "cos", "tan", "asin", "acos", "atan",
    "sinh", "cosh", "asinh", "acosh", "atanh", "integer_pow", "square",
    "clamp", "select_n", "is_finite", "eq", "ne", "lt", "le", "gt", "ge",
    "and", "or", "xor", "not", "shift_left", "shift_right_logical",
    "shift_right_arithmetic", "population_count", "clz", "nextafter",
    "stop_gradient", "copy", "convert_element_type", "reduce_precision",
})

#: pure data-movement/layout ops — fusible, zero flops
_SHAPE = frozenset({
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "slice", "rev", "pad", "concatenate", "iota", "split",
})

#: reductions — one flop per *input* element; absorb their elementwise
#: input chains (XLA input fusion)
_REDUCE = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin",
})

_CUMULATIVE = frozenset({"cumsum", "cumprod", "cummax", "cummin",
                         "cumlogsumexp"})

#: free ops — layout changes and dtype converts. They belong to the
#: kernel that *consumes* them (an operand cast/reshape), never to
#: their producer's; one left behind as its own component is a
#: materialized copy.
_FREE = _SHAPE | frozenset({"convert_element_type"})


def _aval_bytes(aval) -> float:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0.0
    n = 1
    for d in shape:
        n *= int(d)
    dt = getattr(aval, "dtype", None)
    return float(n) * (dt.itemsize if dt is not None else 4)


def _aval_size(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d)
    return n


_FLOAT_CACHE: Dict[Any, bool] = {}


def _is_float(aval) -> bool:
    # numpy's dtype.kind is 'V' for ml_dtypes extension floats
    # (bfloat16, fp8) — go through issubdtype, cached per dtype
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return False
    r = _FLOAT_CACHE.get(dt)
    if r is None:
        import jax.numpy as jnp
        r = bool(jnp.issubdtype(dt, jnp.floating))
        _FLOAT_CACHE[dt] = r
    return r


def eqn_flops(eqn) -> float:
    """Static flop estimate of one equation, XLA ``cost_analysis``
    convention: 2·M·N·K for contractions, one per output element for
    floating elementwise, one per input element for reductions, zero
    for data movement."""
    name = eqn.primitive.name
    if name == "dot_general":
        (lc, _rc), _batch = eqn.params["dimension_numbers"]
        lhs = eqn.invars[0].aval
        k = 1
        for i in lc:
            k *= int(lhs.shape[i])
        return 2.0 * _aval_size(eqn.outvars[0].aval) * k
    if name in _REDUCE or name in _CUMULATIVE:
        return float(_aval_size(eqn.invars[0].aval))
    if name in _ELEM:
        out = eqn.outvars[0].aval
        return float(_aval_size(out)) if _is_float(out) else 0.0
    return 0.0


def eqn_bytes(eqn) -> float:
    """Operand + result bytes of one equation (pre-fusion touch
    estimate; the component model turns these into materialized
    traffic)."""
    if eqn.primitive.name == MARK_PRIMITIVE:
        return 0.0
    total = 0.0
    for v in eqn.invars:
        if not hasattr(v, "val"):
            total += _aval_bytes(v.aval)
    for v in eqn.outvars:
        total += _aval_bytes(v.aval)
    return total


# ---------------------------------------------------------------------------
# DCE — match XLA's post-optimization counts (the norms backward's dead
# dW chains must not be charged; the compiler deletes them)
# ---------------------------------------------------------------------------

def dce(closed):
    """Dead-code-eliminate a ClosedJaxpr, keeping every output and
    every invar position (the traffic pass's index maps depend on
    invar order). Falls back to the raw jaxpr if the internal API
    moved."""
    try:
        from jax._src.interpreters import partial_eval as pe
        jaxpr = closed.jaxpr
        used = [True] * len(jaxpr.outvars)
        new_jaxpr, _ = pe.dce_jaxpr(jaxpr, used, instantiate=True)
        return new_jaxpr
    except Exception:
        return closed.jaxpr


# ---------------------------------------------------------------------------
# the walker
# ---------------------------------------------------------------------------

_STRUCTURAL = frozenset({"pjit", "shard_map", "scan", "while", "cond"})


@dataclasses.dataclass
class Rec:
    """One recorded equation (or structural container)."""
    idx: int
    eqn: Any
    name: str
    kind: str                   # elem | reduce | barrier | mark | container
    trips: float
    in_taints: Tuple[frozenset, ...]
    container: int              # enclosing container Rec idx, -1 at top
    flops: float = 0.0
    bytes: float = 0.0
    rank: int = 0
    phase: str = PH_FWD
    comp: int = -1              # fusion-component root (union-find)


def _kind(eqn) -> str:
    name = eqn.primitive.name
    if name == MARK_PRIMITIVE:
        return "mark"
    if name in _REDUCE:
        return "reduce"
    if name in _ELEM or name in _SHAPE:
        return "elem"
    return "barrier"


class _TrafficWalker(_J.Walker):
    """Taint + equation recorder for the traffic pass.

    Taint tokens: ``p`` (parameters), ``opt`` (optimizer state), ``b``
    (batch), ``key``/``nz`` (PRNG lineage), ``seed:<kind>`` (backward
    seeds), ``g:<i>`` (gradient leaf i, from the plan/apply boundary
    markers). ``g:*`` is stripped at scalar reduction outputs so a
    global-norm scalar does not smear every leaf's token over the
    whole apply phase.
    """

    def __init__(self):
        super().__init__()
        self.records: List[Rec] = []
        self.trips = 1.0
        self._stack: List[int] = []       # enclosing container Rec idxs
        self.gleaf_sizes: Dict[int, int] = {}

    # -- recording ---------------------------------------------------------
    def _record(self, eqn, kind, in_t) -> Rec:
        rec = Rec(idx=len(self.records), eqn=eqn, name=eqn.primitive.name,
                  kind=kind, trips=self.trips, in_taints=tuple(in_t),
                  container=self._stack[-1] if self._stack else -1)
        if kind != "container":
            rec.flops = eqn_flops(eqn)
            rec.bytes = eqn_bytes(eqn)
        self.records.append(rec)
        return rec

    # -- the hook ----------------------------------------------------------
    def hook(self, eqn, in_t):
        name = eqn.primitive.name
        if name == MARK_PRIMITIVE:
            return self._mark(eqn, in_t)

        subs = _J.sub_jaxprs(eqn.params)
        structural = name in _STRUCTURAL or (
            len(subs) == 1
            and len(_J.as_open(subs[0]).invars) == len(in_t))
        if subs and structural:
            if self.recording:
                rec = self._record(eqn, "container", in_t)
                self._stack.append(rec.idx)
                # popped by _eqn's wrapper below
            return None

        # leaf or opaque equation: take it over
        if self.recording:
            self._record(eqn, _kind(eqn), in_t)
        out = frozenset().union(*in_t) if in_t else EMPTY
        # scalar outputs drop gradient-leaf taint: a global-norm scalar
        # is not "the gradient", and smearing every leaf's token over
        # the whole apply chain would make stream counting meaningless
        stripped = frozenset(t for t in out if not t.startswith("g:"))
        return [stripped if _aval_size(ov.aval) <= 1 else out
                for ov in eqn.outvars]

    def _mark(self, eqn, in_t):
        tag = eqn.params["tag"]
        meta = meta_dict(eqn.params["meta"])
        t = in_t[0]
        if tag == TAG_GLEAF:
            leaf = int(meta.get("leaf", -1))
            t = t | {f"g:{leaf}"}
            if self.recording:
                self.gleaf_sizes[leaf] = _aval_size(eqn.outvars[0].aval)
        elif tag == TAG_SEED:
            t = t | {f"seed:{meta.get('kind', '?')}"}
        elif tag == TAG_RNG and meta.get("purpose") == "noise":
            t = t | {T_NOISEKEY}
        if self.recording:
            self._record(eqn, "mark", in_t)
        return [t]

    # -- structural dispatch with trip tracking ----------------------------
    def _eqn(self, eqn, env):
        name = eqn.primitive.name
        depth = len(self._stack)
        old_trips = self.trips
        if name == "scan":
            self.trips = old_trips * float(eqn.params.get("length", 1))
        try:
            super()._eqn(eqn, env)
        finally:
            self.trips = old_trips
            # pop the container frame hook() pushed (if any)
            if self.recording and len(self._stack) > depth:
                self._stack.pop()


# ---------------------------------------------------------------------------
# fusion components
# ---------------------------------------------------------------------------

class _UnionFind:
    def __init__(self, n):
        self.parent = list(range(n))

    def find(self, i):
        while self.parent[i] != i:
            self.parent[i] = self.parent[self.parent[i]]
            i = self.parent[i]
        return i

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def _build_graph(records: Sequence[Rec]):
    """Producer map (var id → Rec), consumer counts, ranks, and the
    fusion components. Var objects are unique per jaxpr scope, so
    producer/consumer edges exist exactly within one scope — which is
    also where fusion happens."""
    producer: Dict[int, Rec] = {}
    for rec in records:
        if rec.kind == "container":
            continue
        for ov in rec.eqn.outvars:
            if type(ov).__name__ != "DropVar":
                producer[id(ov)] = rec

    consumers: Dict[int, int] = {}
    consumer_recs: Dict[int, List[Rec]] = {}
    for rec in records:
        for v in rec.eqn.invars:
            if hasattr(v, "val"):
                continue
            consumers[id(v)] = consumers.get(id(v), 0) + 1
            consumer_recs.setdefault(id(v), []).append(rec)

    # ranks: how many reduction/barrier boundaries feed an equation
    for rec in records:
        if rec.kind == "container":
            continue
        in_rank = 0
        for v in rec.eqn.invars:
            p = producer.get(id(v))
            if p is not None:
                in_rank = max(in_rank, p.rank)
        rec.rank = in_rank if rec.kind == "elem" else in_rank + 1

    # single-output fusion, XLA-shaped: free ops (casts, reshapes) ride
    # into the kernel that consumes them; elementwise chains of equal
    # rank form one loop fusion; a reduction absorbs its elementwise
    # input chain. No multi-output fusion — a value needed both by a
    # reduction and past it (the unfused apply's signature shape) is
    # materialized, which is exactly the traffic this pass certifies.
    # A producer fuses forward only if EVERY consumer of the value
    # would land in the same kernel — otherwise the value crosses HBM.
    def _all_consumers_fuse(v, p):
        for c in consumer_recs.get(id(v), ()):
            if c.kind in ("container", "mark") or c.name in _FREE:
                return False
            if c.kind == "elem" and c.rank == p.rank:
                continue
            if c.kind == "reduce" and c.rank == p.rank + 1:
                continue
            return False
        return True

    uf = _UnionFind(len(records))
    for rec in records:
        if rec.kind in ("container", "mark"):
            continue
        for v in rec.eqn.invars:
            p = producer.get(id(v))
            if p is None or p.kind in ("container", "mark"):
                continue
            if p.name in _FREE:
                if consumers.get(id(v), 0) == 1:
                    uf.union(p.idx, rec.idx)   # operand cast/layout
            elif rec.name in _FREE:
                pass                           # free ops never pull
            elif p.kind == "elem" and rec.kind in ("elem", "reduce") \
                    and _all_consumers_fuse(v, p):
                uf.union(p.idx, rec.idx)
    for rec in records:
        rec.comp = uf.find(rec.idx)
    return producer, consumers


# ---------------------------------------------------------------------------
# phase attribution
# ---------------------------------------------------------------------------

def _phase(rec: Rec, batch_size: int, stat_elems: int,
           group_of: Dict[int, frozenset]) -> str:
    union = frozenset().union(*rec.in_taints) if rec.in_taints else EMPTY
    if any(t.startswith("g:") for t in union) or T_OPT in union \
            or T_NOISEKEY in union:
        return PH_APPLY
    seeds = {t for t in union if t.startswith("seed:")}
    groups = group_of.get(rec.idx, frozenset())
    if not seeds:
        # parameter/batch-only work that does not feed the loss is
        # either norms-only statistics or a backward region's remat
        # recompute — charged to the phase that demanded it, the way
        # rematerialization is normally accounted
        if groups and "loss_vec" not in groups:
            if groups <= frozenset({"sq_norms", "gns"}):
                return PH_STATS
            return PH_ACT
        return PH_FWD
    if groups and groups <= frozenset({"sq_norms", "gns", "loss_vec"}):
        return PH_STATS
    if "seed:norms" in seeds and rec.kind != "container":
        out = rec.eqn.outvars[0].aval
        if _aval_size(out) <= stat_elems:
            return PH_STATS
    if rec.kind != "container":
        shape = getattr(rec.eqn.outvars[0].aval, "shape", ())
        if batch_size not in tuple(int(d) for d in shape):
            return PH_WEIGHT
    return PH_ACT


def _needed_by(records: Sequence[Rec], top_outvars, out_labels):
    """Which output field(s) each record feeds: reverse reachability
    over the record list, crossing container boundaries by seeding each
    body's outvars from the call site's outvars (1:1 for pjit /
    shard_map / scan / while / cond). Iterated to fixpoint — one
    reverse sweep resolves one nesting level."""
    var_groups: Dict[int, frozenset] = {}
    for v, (field, _rest) in zip(top_outvars, out_labels):
        var_groups[id(v)] = var_groups.get(id(v), frozenset()) \
            | frozenset({field})
    group_of: Dict[int, frozenset] = {}
    for _ in range(8):
        changed = False
        for rec in reversed(records):
            g = frozenset()
            for ov in rec.eqn.outvars:
                g |= var_groups.get(id(ov), frozenset())
            if g != group_of.get(rec.idx, EMPTY):
                group_of[rec.idx] = g
                changed = True
            if rec.kind == "container":
                for sub in _J.sub_jaxprs(rec.eqn.params):
                    body = _J.as_open(sub)
                    if len(body.outvars) != len(rec.eqn.outvars):
                        continue
                    for bv, ov in zip(body.outvars, rec.eqn.outvars):
                        if hasattr(bv, "val"):
                            continue
                        og = var_groups.get(id(ov), frozenset())
                        nv = var_groups.get(id(bv), frozenset()) | og
                        if nv != var_groups.get(id(bv), frozenset()):
                            var_groups[id(bv)] = nv
                            changed = True
            for v in rec.eqn.invars:
                if hasattr(v, "val"):
                    continue
                nv = var_groups.get(id(v), frozenset()) | g
                if nv != var_groups.get(id(v), frozenset()):
                    var_groups[id(v)] = nv
                    changed = True
        if not changed:
            break
    return group_of


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """Traffic attribution of one traced training step."""
    granularity: str
    optimizer: str
    plan_desc: str
    n_leaves: int
    flops: float                # trips-weighted static flop count
    flops_hlo: float            # loop bodies once — cost_analysis scale
    hbm_bytes: float            # fusion-aware materialized traffic
    coll_bytes: float           # psum operand bytes
    phase_flops: Tuple[Tuple[str, float], ...]
    phase_bytes: Tuple[Tuple[str, float], ...]
    n_streams: int              # full-gradient HBM passes after the plan
    expected_streams: int       # what plan + optimizer structurally need
    forward_flops: float
    ref_forward_flops: float
    residual_sharing: float     # [0, 1]; -1 when not applicable
    findings: Tuple[Finding, ...]
    allowlisted: Tuple[Finding, ...]    # known waste, tracked not failed

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        ph = ", ".join(f"{k}={v / 1e6:.1f}MB"
                       for k, v in self.phase_bytes if v)
        head = (f"traffic[{self.granularity}/{self.optimizer}]: "
                f"{self.flops_hlo:.3g} flops, "
                f"{self.hbm_bytes / 1e6:.1f} MB materialized ({ph}); "
                f"gradient streams {self.n_streams} "
                f"(expected {self.expected_streams}, "
                f"{len(self.allowlisted)} allowlisted)")
        return "\n".join([head] + [f"  {f.render()}" for f in
                                   self.findings + self.allowlisted])

    def to_json(self) -> dict:
        return {
            "granularity": self.granularity, "optimizer": self.optimizer,
            "plan": self.plan_desc, "n_leaves": self.n_leaves,
            "flops": self.flops, "flops_hlo": self.flops_hlo,
            "hbm_bytes": self.hbm_bytes, "coll_bytes": self.coll_bytes,
            "phase_flops": dict(self.phase_flops),
            "phase_bytes": dict(self.phase_bytes),
            "n_streams": self.n_streams,
            "expected_streams": self.expected_streams,
            "forward_flops": self.forward_flops,
            "ref_forward_flops": self.ref_forward_flops,
            "residual_sharing": self.residual_sharing,
            "findings": [f.to_json() for f in self.findings],
            "allowlisted": [f.to_json() for f in self.allowlisted],
        }


#: duplicate-forward fires above this multiple of the expected forward
FWD_TOL = 1.5
#: dead-residual fires below this shared fraction of residual bytes
RESIDUAL_TOL = 0.25


#: full-gradient passes each optimizer's own update structurally makes
#: today: AdamW reads the gradient once into its fused moment/update
#: loop; Adafactor makes four — the fused row+column mean reduction
#: (one g² chain feeds both means), the update build (g · rsqrt(v̂)),
#: and the RMS clip's reduce + rescale over the leaf-sized update.
_OPT_STREAMS = {"adamw": 1, "adafactor": 4}


def expected_streams(plan, optimizer: str,
                     global_clip: Optional[float]) -> int:
    """Full-gradient HBM passes the current (unfused) apply path
    structurally requires: the optimizer's update read(s), plus one
    for its global-norm clip reduction, plus one for the DP noise add.
    The fused-apply ROADMAP item collapses these to 1."""
    if optimizer == "none" or not plan.needs_grads:
        return 0
    n = _OPT_STREAMS.get(optimizer, 1)
    if global_clip is not None:
        n += 1
    if plan.noise is not None:
        n += 1
    return n


def _walk(closed, in_taints):
    walker = _TrafficWalker()
    walker.run(closed, in_taints)
    return walker


def program_flops(closed) -> Tuple[float, float]:
    """(flops_hlo, flops_total) of a ClosedJaxpr after DCE —
    ``flops_hlo`` counts loop bodies once (XLA ``cost_analysis``
    convention), ``flops_total`` weights them by trip count."""
    jaxpr = dce(closed)
    walker = _walk(jaxpr, [EMPTY] * len(jaxpr.invars))
    once = sum(r.flops for r in walker.records)
    total = sum(r.flops * r.trips for r in walker.records)
    return once, total


def analyze_trace(trace: _J.TrainTrace, *,
                  allow_known_streams: bool = True) -> TrafficReport:
    """Run the traffic pass on one ``TrainTrace``."""
    plan = trace.plan
    jaxpr = dce(trace.closed)
    in_t = [EMPTY] * len(jaxpr.invars)
    for i in trace.param_positions:
        in_t[i] = frozenset({T_PARAM})
    for i in trace.opt_positions:
        in_t[i] = frozenset({T_OPT})
    for i in trace.batch_positions:
        in_t[i] = frozenset({T_BATCH})
    for i in trace.rng_positions:
        in_t[i] = frozenset({T_KEY})
    walker = _walk(jaxpr, in_t)
    records = walker.records
    producer, consumers = _build_graph(records)

    # -- phases ------------------------------------------------------------
    stat_elems = trace.batch_size * max(trace.seq or 1, 64)
    group_of = _needed_by(records, _J.as_open(jaxpr).outvars,
                          trace.out_labels)
    for rec in records:
        rec.phase = _phase(rec, trace.batch_size, stat_elems, group_of)

    real = [r for r in records if r.kind not in ("container", "mark")]
    phase_flops = {ph: 0.0 for ph in PHASES}
    phase_bytes = {ph: 0.0 for ph in PHASES}
    for r in real:
        phase_flops[r.phase] += r.flops * r.trips

    # -- fusion-aware materialized bytes -----------------------------------
    comp_members: Dict[int, List[Rec]] = {}
    for r in real:
        comp_members.setdefault(r.comp, []).append(r)
    hbm_bytes = 0.0
    for comp, members in comp_members.items():
        member_ids = {m.idx for m in members}
        seen_in: set = set()
        ext = 0.0
        for m in members:
            for v in m.eqn.invars:
                if hasattr(v, "val") or id(v) in seen_in:
                    continue
                p = producer.get(id(v))
                if p is not None and p.idx in member_ids:
                    continue
                seen_in.add(id(v))
                ext += _aval_bytes(v.aval)
            for ov in m.eqn.outvars:
                if type(ov).__name__ == "DropVar":
                    continue
                p_used = consumers.get(id(ov), 0)
                internal = sum(
                    1 for m2 in members for v2 in m2.eqn.invars
                    if id(v2) == id(ov))
                if p_used > internal or p_used == 0:
                    ext += _aval_bytes(ov.aval)
        trips = members[0].trips
        hbm_bytes += ext * trips
        for m in members:
            phase_bytes[m.phase] += (ext * trips) / len(members)

    coll_bytes = sum(
        sum(_aval_bytes(v.aval) for v in r.eqn.invars
            if not hasattr(v, "val")) * r.trips
        for r in real if r.name in ("psum", "ppermute", "all_gather",
                                    "psum_scatter", "all_to_all"))

    # -- gradient streams --------------------------------------------------
    leaf_sizes = walker.gleaf_sizes
    streams: Dict[int, set] = {i: set() for i in leaf_sizes}
    convert_only: Dict[int, bool] = {
        comp: all(m.name in ("convert_element_type", MARK_PRIMITIVE)
                  for m in members)
        for comp, members in comp_members.items()}
    for r in real:
        if r.phase != PH_APPLY or convert_only.get(r.comp, False):
            continue
        member_ids = {m.idx for m in comp_members[r.comp]}
        for v, t in zip(r.eqn.invars, r.in_taints):
            if hasattr(v, "val"):
                continue
            p = producer.get(id(v))
            if p is not None and p.idx in member_ids:
                continue
            sz = _aval_size(v.aval)
            for tok in t:
                if tok.startswith("g:"):
                    i = int(tok.split(":", 1)[1])
                    if leaf_sizes.get(i) == sz:
                        streams[i].add(r.comp)

    n_streams = max((len(s) for s in streams.values()), default=0)
    expected = expected_streams(plan, trace.optimizer, trace.global_clip)

    findings: List[Finding] = []
    allowlisted: List[Finding] = []
    worst = max(streams, key=lambda i: len(streams[i]), default=None)
    worst_label = trace.param_labels[worst] \
        if worst is not None and worst < len(trace.param_labels) else None
    if n_streams > expected:
        findings.append(Finding(
            PASS, ERROR, "redundant-hbm-stream",
            f"{n_streams} full-gradient HBM streams after the plan "
            f"boundary where the plan + {trace.optimizer} apply "
            f"structurally need {expected}: an extra pass over every "
            f"gradient leaf is {n_streams - expected}× more HBM "
            f"traffic than the step budget", leaf=worst_label))
    elif n_streams == expected and expected > 1:
        parts = []
        if plan.noise is not None:
            parts.append("noise add")
        if trace.global_clip is not None:
            parts.append("global-norm clip")
        parts.append(f"{trace.optimizer} update")
        f = Finding(
            PASS, ERROR, "redundant-hbm-stream",
            f"the apply path streams every gradient {n_streams}× "
            f"({', '.join(parts)} each re-read the full gradient) — "
            f"the known unfused-apply waste; see the ROADMAP fused "
            f"DP-SGD apply item (one Pallas kernel per param, one HBM "
            f"pass)", leaf=worst_label)
        (allowlisted if allow_known_streams else findings).append(f)

    # -- duplicate forward -------------------------------------------------
    fwd_flops = phase_flops[PH_FWD]
    ref_flops = 0.0
    if trace.ref_closed is not None:
        _, ref_flops = program_flops(trace.ref_closed)
        factor = 1.0
        if plan.importance is not None:
            factor += plan.importance.k / float(trace.batch_size)
        if ref_flops > 0 and fwd_flops > FWD_TOL * factor * ref_flops:
            findings.append(Finding(
                PASS, ERROR, "duplicate-forward",
                f"forward-phase flops ({fwd_flops:.3g}) are "
                f"{fwd_flops / ref_flops:.2f}× the plain forward "
                f"({ref_flops:.3g}); the fused plan owes exactly one "
                f"forward ({factor:.1f} regions expected) — a consumer "
                f"is re-running the model"))

    # -- residual sharing --------------------------------------------------
    sharing = -1.0
    if plan.n_backwards == 2 and plan.importance is None:
        fwd_out = {}
        for r in records:
            if r.phase == PH_FWD:
                for ov in r.eqn.outvars:
                    if type(ov).__name__ != "DropVar" \
                            and _aval_size(ov.aval) > trace.batch_size:
                        fwd_out[id(ov)] = _aval_bytes(ov.aval)
        norms_bytes: Dict[int, float] = {}
        wt_bytes: Dict[int, float] = {}
        for r in records:
            union = frozenset().union(*r.in_taints) if r.in_taints \
                else EMPTY
            is_n = "seed:norms" in union
            is_w = "seed:weighted" in union
            if not (is_n or is_w):
                continue
            for v in r.eqn.invars:
                b = fwd_out.get(id(v))
                if b is None:
                    continue
                if is_n:
                    norms_bytes[id(v)] = b
                if is_w:
                    wt_bytes[id(v)] = b
        total_w = sum(wt_bytes.values())
        shared = sum(b for vid, b in wt_bytes.items()
                     if vid in norms_bytes)
        has_weighted = any(
            "seed:weighted" in (frozenset().union(*r.in_taints)
                                if r.in_taints else EMPTY)
            for r in records)
        if has_weighted:
            # a weighted backward that touches NO forward residuals at
            # all is the anomaly itself — it linearized a second time
            sharing = shared / total_w if total_w > 0 else 0.0
            if sharing < RESIDUAL_TOL:
                findings.append(Finding(
                    PASS, ERROR, "dead-residual",
                    f"the reweighted backward reads "
                    f"{total_w / 1e6:.1f} MB of forward activations but "
                    f"shares only {sharing:.0%} of them with the norms "
                    f"backward — the two backwards are not running over "
                    f"one linearization's residuals (a second vjp "
                    f"doubles residual traffic)"))

    # -- upcast materialization --------------------------------------------
    leaf_size_set = set(leaf_sizes.values())
    for r in real:
        if r.phase != PH_APPLY or r.name != "convert_element_type":
            continue
        if not convert_only.get(r.comp, False):
            continue
        v = r.eqn.invars[0]
        if hasattr(v, "val"):
            continue
        out = r.eqn.outvars[0].aval
        if not (_is_float(out) and _is_float(v.aval)):
            continue
        if out.dtype.itemsize <= v.aval.dtype.itemsize:
            continue
        union = r.in_taints[0]
        if _aval_size(v.aval) in leaf_size_set \
                and any(t.startswith("g:") for t in union):
            findings.append(Finding(
                PASS, ERROR, "upcast-materialization",
                f"a {out.dtype.name} copy of a {v.aval.dtype.name} "
                f"gradient leaf is materialized as its own HBM pass "
                f"(the convert fuses into nothing) — upcast inside the "
                f"consumer loop instead of copying the tree"))
            break

    return TrafficReport(
        granularity=trace.granularity, optimizer=trace.optimizer,
        plan_desc=plan.describe(), n_leaves=len(trace.param_labels),
        flops=sum(r.flops * r.trips for r in real),
        flops_hlo=sum(r.flops for r in real),
        hbm_bytes=hbm_bytes, coll_bytes=coll_bytes,
        phase_flops=tuple(sorted(phase_flops.items())),
        phase_bytes=tuple(sorted(phase_bytes.items())),
        n_streams=n_streams, expected_streams=expected,
        forward_flops=fwd_flops, ref_forward_flops=ref_flops,
        residual_sharing=sharing,
        findings=tuple(findings), allowlisted=tuple(allowlisted))


def check_train_step(loss_fn, params, batch, consumers, **kw):
    """Convenience: trace one training step and analyze it."""
    allow = kw.pop("allow_known_streams", True)
    return analyze_trace(
        _J.trace_train_step(loss_fn, params, batch, consumers, **kw),
        allow_known_streams=allow)
