"""pexlint — static analysis over traced jaxprs and launch contracts
(DESIGN.md §10).

Three passes, none of which executes or compiles model code:

  * ``coverage`` — tap-coverage verification: walk the traced loss
    jaxpr from every trainable leaf toward the loss and prove each
    parameter is tapped, declared frozen, or explicitly allowlisted;
  * ``plan_invariants`` — the zero-overhead / one-forward-budget
    claims as reusable HLO-cost analyzers (these DO compile — the one
    opt-in exception, shared with tests and benches);
  * ``launch`` — kernel-launch validation of every Pallas schedule a
    model's tap sites imply, against the declared ``LaunchContract``s.

``verify.verify`` (surfaced as ``Engine.verify``) composes them;
``python -m repro.analysis`` lints every registered model.
"""
from repro.analysis.coverage import (AnalysisError, CoverageReport,
                                     LeafReport, TapSite, trace_coverage)
from repro.analysis.launch import (LaunchReport, contracts_for_sites,
                                   production_cases, validate_contracts,
                                   validate_sites)
from repro.analysis.verify import VerifyReport, verify

__all__ = [
    "AnalysisError", "CoverageReport", "LeafReport", "TapSite",
    "trace_coverage", "LaunchReport", "contracts_for_sites",
    "production_cases", "validate_contracts", "validate_sites",
    "VerifyReport", "verify",
]
