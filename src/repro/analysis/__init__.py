"""pexlint — static analysis over traced jaxprs and launch contracts
(DESIGN.md §10, §12).

Six passes, none of which executes or compiles model code:

  * ``coverage`` — tap-coverage verification: walk the traced loss
    jaxpr from every trainable leaf toward the loss and prove each
    parameter is tapped, declared frozen, or explicitly allowlisted;
  * ``plan_invariants`` — the zero-overhead / one-forward-budget
    claims as reusable HLO-cost analyzers (these DO compile — the one
    opt-in exception, shared with tests and benches);
  * ``launch`` — kernel-launch validation of every Pallas schedule a
    model's tap sites imply, against the declared ``LaunchContract``s;
  * ``privacy`` — dataflow proof of the DP invariants over a full
    traced step: every trained gradient scaled by the per-example clip
    coefficient before any batch sum, Gaussian noise injected exactly
    once after the cross-device psum at scale σ·C, PRNG key lineage
    single-use;
  * ``collectives`` — per-shard_map-region layout verification: psum
    axes against the mesh, per-example outputs never reduced over the
    data axes, replicated outputs psum'd exactly once (plus the
    declared 2-D DP×TP schedule);
  * ``determinism`` — AST verification that the data pipeline and the
    soak replay path are pure in (seed, step);
  * ``traffic`` + ``cost`` ("pexcost") — HBM-traffic attribution and
    analytic step-time prediction over a full traced *training* step
    (plan execution plus the optimizer apply), with the
    ``COST_BASELINE.json`` regression gate.

``verify.verify`` (surfaced as ``Engine.verify``) composes them;
``python -m repro.analysis`` lints every registered model. The flow
passes share ``_jaxpr.Walker``/``_jaxpr.trace_step`` (the abstract-
trace front end) and report ``findings.Finding`` records, which
``--json`` emits machine-readably.
"""
from repro.analysis.collectives import (CollectivesReport, ScheduleEntry,
                                        expected_schedule)
from repro.analysis.cost import (CostReport, baseline_payload, build_cost,
                                 check_baseline)
from repro.analysis.coverage import (AnalysisError, CoverageReport,
                                     LeafReport, TapSite, trace_coverage)
from repro.analysis.determinism import (DeterminismReport, check_source)
from repro.analysis.findings import ERROR, INFO, WARNING, Finding
from repro.analysis.launch import (LaunchReport, contracts_for_sites,
                                   production_cases, validate_contracts,
                                   validate_sites)
from repro.analysis.privacy import PrivacyReport
from repro.analysis.traffic import (TrafficReport, analyze_trace,
                                    check_train_step, program_flops)
from repro.analysis.verify import VerifyReport, verify

__all__ = [
    "AnalysisError", "CoverageReport", "LeafReport", "TapSite",
    "trace_coverage", "LaunchReport", "contracts_for_sites",
    "production_cases", "validate_contracts", "validate_sites",
    "VerifyReport", "verify",
    "Finding", "ERROR", "WARNING", "INFO",
    "PrivacyReport", "CollectivesReport", "ScheduleEntry",
    "expected_schedule", "DeterminismReport", "check_source",
    "TrafficReport", "analyze_trace", "check_train_step",
    "program_flops", "CostReport", "build_cost", "check_baseline",
    "baseline_payload",
]
