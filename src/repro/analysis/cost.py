"""pexcost — analytic step-time prediction from the traffic pass
(DESIGN.md §13).

``analysis.traffic`` attributes the traced training step's flops and
materialized HBM bytes; this module divides them by a named
:class:`~repro.roofline.constants.HardwareProfile` to produce a
``CostReport`` with compute / memory / collective time terms — a
"static bench" that runs on CPU in seconds. Pallas launches the step
would issue enter through their ``kernels/contract.py``
LaunchContracts (per-launch ``flops`` / ``hbm_bytes()``), collectives
through the traffic pass's psum operand bytes scaled by the ring
all-reduce wire factor 2·(chips−1)/chips.

Two gates consume the reports:

  * ``check_baseline`` — the CI regression gate against a committed
    ``COST_BASELINE.json``: a plan whose predicted flops or bytes grow
    beyond tolerance over the baseline is an ERROR
    (``cost-regression``); shrinkage beyond tolerance and key churn
    are WARNINGs (re-baseline, don't fail).
  * ``benchmarks/check_drift.py`` — cross-validation of the flop
    predictions against the measured ``#derived`` rows of the newest
    ``BENCH_PR*.json`` (within 25%).

Everything is static: the numbers are predictions from traced jaxprs
and public peak specs, not measurements — the report names the profile
so the denominators are never implicit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import ERROR, WARNING, Finding
from repro.analysis.traffic import TrafficReport
from repro.roofline.constants import DEFAULT_PROFILE, get_profile

PASS = "cost"

#: baseline metrics the regression gate compares (prediction keys of
#: one CostReport row; times are derived, so gating on the raw
#: flop/byte terms keeps the gate profile-independent)
BASELINE_METRICS = ("flops_hlo", "hbm_bytes", "coll_bytes")


@dataclasses.dataclass(frozen=True)
class CostReport:
    """Predicted step budget of one (model × granularity × plan) on one
    named hardware profile."""
    model: str
    granularity: str
    optimizer: str
    plan_desc: str
    profile: str                # HardwareProfile name — the denominators
    chips: int
    flops: float                # trips-weighted static flops
    flops_hlo: float            # loop bodies once — BENCH/cost_analysis scale
    hbm_bytes: float            # fusion-aware materialized traffic
    coll_bytes: float           # psum operand bytes (per step)
    kernel_flops: float         # Pallas LaunchContract work
    kernel_hbm_bytes: float     # Pallas LaunchContract panel traffic
    t_compute: float            # seconds
    t_memory: float
    t_collective: float
    t_step: float               # max of the three — overlap model
    bottleneck: str             # 'compute' | 'memory' | 'collective'
    phase_bytes: Tuple[Tuple[str, float], ...]
    n_streams: int
    expected_streams: int

    def summary(self) -> str:
        return (f"cost[{self.model}/{self.granularity}] on {self.profile}"
                f"×{self.chips}: {self.t_step * 1e6:.1f}us "
                f"({self.bottleneck}-bound; compute "
                f"{self.t_compute * 1e6:.1f}us, memory "
                f"{self.t_memory * 1e6:.1f}us, collective "
                f"{self.t_collective * 1e6:.1f}us) — "
                f"{self.flops_hlo:.3g} flops, "
                f"{self.hbm_bytes / 1e6:.1f} MB, "
                f"streams {self.n_streams}/{self.expected_streams}")

    def to_json(self) -> dict:
        return {
            "model": self.model, "granularity": self.granularity,
            "optimizer": self.optimizer, "plan": self.plan_desc,
            "profile": self.profile, "chips": self.chips,
            "flops": self.flops, "flops_hlo": self.flops_hlo,
            "hbm_bytes": self.hbm_bytes, "coll_bytes": self.coll_bytes,
            "kernel_flops": self.kernel_flops,
            "kernel_hbm_bytes": self.kernel_hbm_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "t_step_s": self.t_step,
            "bottleneck": self.bottleneck,
            "phase_bytes": dict(self.phase_bytes),
            "n_streams": self.n_streams,
            "expected_streams": self.expected_streams,
        }


def build_cost(traffic: TrafficReport, *, model: str = "?",
               profile: str = DEFAULT_PROFILE, chips: int = 1,
               contracts: Sequence = ()) -> CostReport:
    """Compose one TrafficReport (+ the Pallas LaunchContracts the step
    would issue) into a CostReport on a named hardware profile.

    The time model is the roofline overlap bound: each term assumes
    perfect overlap with the others, ``t_step`` is their max. The
    collective term uses the ring all-reduce wire volume —
    ``coll_bytes · 2(chips−1)/chips`` per chip over one ICI link —
    which is 0 on a single chip.
    """
    hw = get_profile(profile)
    chips = max(int(chips), 1)
    k_flops = float(sum(getattr(c, "flops", 0.0) for c in contracts))
    k_bytes = float(sum(c.hbm_bytes() for c in contracts
                        if hasattr(c, "hbm_bytes")))

    # per-chip shares: the traced step is the whole batch; data
    # parallelism divides flops and local HBM traffic evenly
    t_compute = (traffic.flops_hlo + k_flops) / (hw.peak_flops_bf16 * chips)
    t_memory = (traffic.hbm_bytes + k_bytes) / (hw.hbm_bw * chips)
    wire = traffic.coll_bytes * 2.0 * (chips - 1) / chips
    t_collective = wire / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bottleneck = max(terms, key=terms.get)

    return CostReport(
        model=model, granularity=traffic.granularity,
        optimizer=traffic.optimizer, plan_desc=traffic.plan_desc,
        profile=hw.name, chips=chips,
        flops=traffic.flops, flops_hlo=traffic.flops_hlo,
        hbm_bytes=traffic.hbm_bytes, coll_bytes=traffic.coll_bytes,
        kernel_flops=k_flops, kernel_hbm_bytes=k_bytes,
        t_compute=t_compute, t_memory=t_memory,
        t_collective=t_collective, t_step=max(terms.values()),
        bottleneck=bottleneck,
        phase_bytes=traffic.phase_bytes,
        n_streams=traffic.n_streams,
        expected_streams=traffic.expected_streams)


# ---------------------------------------------------------------------------
# baseline gate
# ---------------------------------------------------------------------------

def baseline_key(report: CostReport) -> str:
    """One baseline row per (model × granularity × plan shape) — the
    plan description keys distinct consumer sets apart so a norms-only
    pass is never compared against the DP step's budget."""
    return f"{report.model}/{report.granularity}/{report.plan_desc}"


def baseline_payload(reports: Iterable[CostReport]) -> Dict[str, dict]:
    """The committed-baseline shape: predictions only (no times — the
    gate must not depend on which profile CI picked)."""
    out: Dict[str, dict] = {}
    for r in reports:
        out[baseline_key(r)] = {m: getattr(r, m) for m in BASELINE_METRICS}
    return dict(sorted(out.items()))


def check_baseline(reports: Sequence[CostReport],
                   baseline: Dict[str, dict], *,
                   tolerance: float = 0.25,
                   full_matrix: bool = True) -> List[Finding]:
    """Regression-gate findings: growth beyond tolerance is an ERROR,
    shrinkage beyond tolerance and key churn are WARNINGs (stale
    baseline — refresh with ``--write-cost-baseline``). Key churn is
    only judged under ``full_matrix`` — a single-arch run legitimately
    leaves every other arch's baseline rows unmatched."""
    findings: List[Finding] = []
    seen = set()
    for r in reports:
        key = baseline_key(r)
        seen.add(key)
        old = baseline.get(key)
        if old is None:
            findings.append(Finding(
                PASS, WARNING, "cost-baseline-missing",
                f"no committed baseline for {key!r}; add it with "
                f"--write-cost-baseline", model=r.model,
                granularity=r.granularity))
            continue
        for metric in BASELINE_METRICS:
            if metric not in old:
                continue
            ref = float(old[metric])
            new = float(getattr(r, metric))
            if ref <= 0.0:
                if new > 0.0:
                    findings.append(Finding(
                        PASS, ERROR, "cost-regression",
                        f"{key}: predicted {metric} grew 0 -> {new:.3g}",
                        model=r.model, granularity=r.granularity))
                continue
            rel = (new - ref) / ref
            if rel > tolerance:
                findings.append(Finding(
                    PASS, ERROR, "cost-regression",
                    f"{key}: predicted {metric} grew {ref:.3g} -> "
                    f"{new:.3g} (+{rel:.0%} > {tolerance:.0%})",
                    model=r.model, granularity=r.granularity))
            elif rel < -tolerance:
                findings.append(Finding(
                    PASS, WARNING, "cost-baseline-stale",
                    f"{key}: predicted {metric} shrank {ref:.3g} -> "
                    f"{new:.3g} ({rel:.0%}); refresh the baseline to "
                    f"lock in the win", model=r.model,
                    granularity=r.granularity))
    if full_matrix:
        for key in sorted(set(baseline) - seen):
            findings.append(Finding(
                PASS, WARNING, "cost-baseline-stale",
                f"baseline entry {key!r} matched no analyzed plan"))
    return findings
