"""Collective-layout verification of sharded step traces (pexlint
pass, DESIGN.md §12).

The mesh pipeline's layout contract (``dist.pex`` docstring) is what
makes the accumulator technique free on a mesh: per-example quantities
— the (B,) loss vector, the (B, G)/(B, S) norms, the weight products —
stay batch-sharded end to end and must NEVER be reduced over the data
axes (a psum there silently averages per-example statistics across
examples on other shards), while replicated outputs (the summed
gradients) must cross devices in EXACTLY one psum over exactly the
data axes (zero ⇒ each host trains on shard-local gradients and the
replicas drift; two ⇒ gradients scaled by the shard count).

This pass checks that contract per ``shard_map`` region of a traced
step (``analysis._jaxpr.trace_step``):

  * every ``psum``'s axes name real mesh axes of the region, are
    manual (not auto) axes, and use no ``axis_index_groups``;
  * outputs whose ``out_names`` shard a dimension over a data axis are
    per-example: no data-axis psum may appear in their lineage;
  * outputs replicated over the data axes carry exactly one data-axis
    psum, covering ALL the data axes (a partial reduction leaves the
    gradient different across the unreduced axis).

``expected_schedule`` states the same contract as data — including its
2-D DP×TP form (model-axis extent > 1), where per-example norms and
losses additionally need a psum over the *model* axes (each tensor
shard holds only part of every example's norm) and gradients reduce
over data and model both. The executable pipeline still rejects big
model axes (jax 0.4.x shard_map limitation, ROADMAP), but the static
half of the DP×TP contract is pinned here and checked degenerate-form
against today's 1-D traces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.analysis import _jaxpr as _J
from repro.analysis.findings import ERROR, Finding
from repro.core import plan as plan_mod

PASS = "collectives"
_EMPTY = _J.EMPTY


# ---------------------------------------------------------------------------
# the declared contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleEntry:
    """One output of the fused region and the collectives it is owed."""
    output: str
    per_example: bool               # stays batch-sharded over data axes
    psum_axes: Tuple[str, ...]      # () = must never be reduced


def expected_schedule(plan: plan_mod.Plan, mesh,
                      data_axes: Sequence[str]) -> Tuple[ScheduleEntry, ...]:
    """The collective schedule a plan's fused region owes on ``mesh``.

    With every non-data axis at extent 1 this degenerates to today's
    executable contract: per-example outputs un-reduced, gradients
    psum'd once over the data axes. With a real model axis (DP×TP) the
    per-example entries gain a model-axis psum — each tensor shard
    holds only its slice of every example's norm and loss — and the
    gradient reduces over both; that form is what the jax-upgrade
    DP×TP pipeline must emit to pass this pass unchanged.
    """
    data = tuple(data_axes)
    model = tuple(a for a in mesh.axis_names
                  if a not in data and mesh.shape[a] > 1)
    entries: List[ScheduleEntry] = [
        ScheduleEntry("loss_vec", True, model)]
    if plan.needs_norms:
        entries.append(ScheduleEntry("sq_norms", True, model))
    if plan.weighted or plan.token_weighted:
        # weights are functions of already-complete norms: no collective
        entries.append(ScheduleEntry("weights", True, ()))
    if plan.needs_grads:
        entries.append(ScheduleEntry("grads", False, data + model))
    return tuple(entries)


# ---------------------------------------------------------------------------
# report datatypes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PsumSite:
    index: int
    axes: Tuple[str, ...]
    grouped: bool                   # axis_index_groups is not None


@dataclasses.dataclass(frozen=True)
class RegionOutput:
    position: int
    sharded_over_data: bool
    data_psums: int                 # distinct data-axis psums in lineage


@dataclasses.dataclass(frozen=True)
class RegionReport:
    index: int
    mesh_axes: Tuple[str, ...]
    manual_axes: Tuple[str, ...]
    psums: Tuple[PsumSite, ...]
    outputs: Tuple[RegionOutput, ...]


@dataclasses.dataclass(frozen=True)
class CollectivesReport:
    regions: Tuple[RegionReport, ...]
    schedule: Tuple[ScheduleEntry, ...]
    findings: Tuple[Finding, ...]

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        head = f"collectives: {len(self.regions)} sharded region(s)"
        for r in self.regions:
            n_pe = sum(o.sharded_over_data for o in r.outputs)
            head += (f"\n  region {r.index}: mesh={r.mesh_axes} "
                     f"{n_pe} per-example + "
                     f"{len(r.outputs) - n_pe} replicated outputs, "
                     f"{len(r.psums)} psum(s)")
        return "\n".join([head] + [f"  {f.render()}" for f in self.findings])


# ---------------------------------------------------------------------------
# the walker — psum-site lineage inside one region body
# ---------------------------------------------------------------------------

class _PsumWalker(_J.Walker):
    def __init__(self):
        super().__init__()
        self.sites: Dict[int, PsumSite] = {}    # id(eqn) -> site

    def hook(self, eqn, in_t):
        if eqn.primitive.name != "psum":
            return None
        key = id(eqn)
        if key not in self.sites:
            self.sites[key] = PsumSite(
                len(self.sites), tuple(eqn.params.get("axes", ())),
                eqn.params.get("axis_index_groups") is not None)
        tok = f"ps:{key}"
        return [t | {tok} for t in in_t]


def _analyze_region(eqn, data_axes: Tuple[str, ...], index: int,
                    findings: List[Finding]) -> RegionReport:
    mesh = eqn.params["mesh"]
    auto = frozenset(eqn.params.get("auto", frozenset()))
    manual = tuple(a for a in mesh.axis_names if a not in auto)
    out_names = eqn.params["out_names"]
    body = eqn.params["jaxpr"]

    walker = _PsumWalker()
    n_in = len(_J.as_open(body).invars)
    out_t = walker.run(body, [_EMPTY] * n_in)
    sites = {f"ps:{k}": s for k, s in walker.sites.items()}

    for s in sites.values():
        unknown = [a for a in s.axes if a not in mesh.axis_names]
        if unknown:
            findings.append(Finding(
                PASS, ERROR, "unknown-axis",
                f"psum over {s.axes} names axes {unknown} that are not on "
                f"the region's mesh {tuple(mesh.axis_names)}"))
        bad_auto = [a for a in s.axes if a in auto]
        if bad_auto:
            findings.append(Finding(
                PASS, ERROR, "auto-axis-psum",
                f"psum over {s.axes} reduces auto (non-manual) axes "
                f"{bad_auto}: inside this region those axes are still "
                f"compiler-managed and the reduction is ill-defined"))
        if s.grouped:
            findings.append(Finding(
                PASS, ERROR, "grouped-psum",
                f"psum over {s.axes} uses axis_index_groups: a partial "
                f"group reduction cannot implement the full data-axis "
                f"gradient sum"))

    outputs = []
    data = frozenset(data_axes)
    for pos, (names, taint) in enumerate(zip(out_names, out_t)):
        sharded = any(data & set(ax) for ax in names.values())
        dpsums = [sites[t] for t in taint
                  if t in sites and data & set(sites[t].axes)]
        outputs.append(RegionOutput(pos, sharded, len(dpsums)))
        if sharded:
            if dpsums:
                findings.append(Finding(
                    PASS, ERROR, "per-example-psum",
                    f"region output {pos} is batch-sharded over "
                    f"{tuple(sorted(data))} (a per-example quantity) but "
                    f"its lineage contains a psum over "
                    f"{dpsums[0].axes}: per-example statistics must never "
                    f"be reduced over the data axes"))
        else:
            if not dpsums:
                findings.append(Finding(
                    PASS, ERROR, "replicated-unreduced",
                    f"region output {pos} is declared replicated but no "
                    f"data-axis psum appears in its lineage: each shard "
                    f"would return shard-local values that silently "
                    f"differ across hosts"))
            elif len(dpsums) > 1:
                findings.append(Finding(
                    PASS, ERROR, "double-psum",
                    f"region output {pos} crosses {len(dpsums)} distinct "
                    f"data-axis psums: the gradient is scaled by the "
                    f"shard count once per extra reduction"))
            else:
                missing = data - set(dpsums[0].axes)
                if missing:
                    findings.append(Finding(
                        PASS, ERROR, "partial-psum",
                        f"region output {pos} is reduced over "
                        f"{dpsums[0].axes} but the data axes are "
                        f"{tuple(sorted(data))}: the axes "
                        f"{tuple(sorted(missing))} are left unreduced"))
    return RegionReport(index, tuple(mesh.axis_names), manual,
                        tuple(sorted(sites.values(),
                                     key=lambda s: s.index)),
                        tuple(outputs))


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

def analyze_trace(trace: _J.StepTrace) -> CollectivesReport:
    """Check the collective layout of every shard_map region in one
    ``StepTrace``. A mesh-less trace has no regions and passes
    trivially (the local path has no collectives to get wrong)."""
    findings: List[Finding] = []
    regions = []
    schedule: Tuple[ScheduleEntry, ...] = ()
    for eqn, _depth in _J.iter_eqns(trace.closed.jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        if not schedule:
            schedule = expected_schedule(trace.plan, eqn.params["mesh"],
                                         trace.data_axes)
        regions.append(_analyze_region(eqn, trace.data_axes,
                                       len(regions), findings))
    if trace.meshed and not regions:
        findings.append(Finding(
            PASS, ERROR, "missing-region",
            "the step was traced through the mesh path but contains no "
            "shard_map region: the fused core is not actually sharded"))
    return CollectivesReport(tuple(regions), schedule, tuple(findings))


def check_step(loss_fn, params, batch, consumers, **trace_kw):
    """Convenience: trace ``Engine.step`` and analyze its collectives."""
    return analyze_trace(_J.trace_step(loss_fn, params, batch, consumers,
                                       **trace_kw))
