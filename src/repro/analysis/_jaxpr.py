"""Shared abstract-trace front end for the pexlint passes
(DESIGN.md §10, §12).

Two pieces every trace-only analyzer needs:

  * ``Walker`` — forward dataflow over a (closed) jaxpr on the union
    semilattice of frozensets. The base class owns the structural
    recursion — ``pjit``, ``scan`` (carry fixpoint), ``while``
    (fixpoint), ``cond`` (branch join), ``shard_map`` (with region-
    depth tracking), single-sub-jaxpr generic calls, and a
    conservative everything-flows-everywhere fallback — so a pass only
    overrides ``hook`` for the equations it gives meaning to
    (coverage: the pex ``custom_vjp`` taps; privacy: ``pex_mark`` and
    the random primitives; collectives: ``psum``).

  * ``trace_step`` — trace one full ``Engine.step`` program (local or
    mesh path) on abstract inputs and return the closed jaxpr together
    with the maps the passes need: which invars are consumer PRNG
    keys, and which flat outvars are which result field (gradient
    leaves keep their parameter paths). Consumer rng keys are rebound
    as explicit arguments of the traced function so key lineage starts
    at an invar instead of vanishing into a constant.

Everything here is ``jax.make_jaxpr`` — no compilation, no execution;
``ShapeDtypeStruct`` params, batches, and keys all work.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax

from repro.core import plan as plan_mod

EMPTY = frozenset()


class AnalysisError(RuntimeError):
    """The jaxpr walker met a structure it cannot soundly propagate
    through (a sub-jaxpr whose arity disagrees with its equation)."""


def as_open(j):
    """Jaxpr of a possibly-Closed jaxpr."""
    return j.jaxpr if hasattr(j, "jaxpr") else j


def is_jaxpr(v) -> bool:
    return hasattr(v, "eqns") or (hasattr(v, "jaxpr")
                                  and hasattr(as_open(v), "eqns"))


def sub_jaxprs(params: dict):
    """Every (Closed)Jaxpr value in an equation's params."""
    found = []
    for v in params.values():
        if is_jaxpr(v):
            found.append(v)
        elif isinstance(v, (tuple, list)):
            found.extend(w for w in v if is_jaxpr(w))
    return found


def read(env, atom):
    if hasattr(atom, "val"):            # Literal
        return EMPTY
    return env.get(atom, EMPTY)


def write(env, var, taint):
    # DropVars are placeholders for unused outputs
    if type(var).__name__ == "DropVar":
        return
    env[var] = env.get(var, EMPTY) | taint


def iter_eqns(jaxpr, depth: int = 0):
    """Yield ``(eqn, depth)`` for every equation, recursing into every
    sub-jaxpr; ``depth`` counts enclosing ``shard_map`` regions."""
    for eqn in as_open(jaxpr).eqns:
        yield eqn, depth
        d = depth + (1 if eqn.primitive.name == "shard_map" else 0)
        for sub in sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, d)


class Walker:
    """Forward taint propagation with pluggable equation semantics.

    Override ``hook(eqn, in_taints)``: return a list of output taints
    to take over the equation, or None to fall through to the
    structural default. ``self.recording`` is False during fixpoint
    warm-up runs — hooks that record sites must check it so scan/while
    bodies are not double-counted. ``self.region_depth`` counts the
    ``shard_map`` regions enclosing the current equation.
    """

    def __init__(self):
        self.recording = True
        self.region_depth = 0

    # -- override points --------------------------------------------------
    def hook(self, eqn, in_taints) -> Optional[List[frozenset]]:
        return None

    def const_taint(self, var) -> frozenset:
        return EMPTY

    # -- the walk ---------------------------------------------------------
    def run(self, jaxpr, in_taints: Sequence[frozenset]) -> List[frozenset]:
        jaxpr = as_open(jaxpr)
        if len(jaxpr.invars) != len(in_taints):
            raise AnalysisError(
                f"sub-jaxpr arity mismatch: {len(jaxpr.invars)} invars vs "
                f"{len(in_taints)} operand taints")
        env = {}
        for v in jaxpr.constvars:
            write(env, v, self.const_taint(v))
        for v, t in zip(jaxpr.invars, in_taints):
            write(env, v, t)
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env)
        return [read(env, v) for v in jaxpr.outvars]

    def _quiet_run(self, jaxpr, in_taints):
        rec, self.recording = self.recording, False
        try:
            return self.run(jaxpr, in_taints)
        finally:
            self.recording = rec

    def _eqn(self, eqn, env) -> None:
        in_t = [read(env, v) for v in eqn.invars]
        outs = self.hook(eqn, in_t)
        if outs is not None:
            for ov, t in zip(eqn.outvars, outs):
                write(env, ov, t)
            return

        name = eqn.primitive.name
        if name == "pjit":
            outs = self.run(eqn.params["jaxpr"], in_t)
            for ov, t in zip(eqn.outvars, outs):
                write(env, ov, t)
            return

        if name == "shard_map":
            self.region_depth += 1
            try:
                outs = self.run(eqn.params["jaxpr"], in_t)
            finally:
                self.region_depth -= 1
            for ov, t in zip(eqn.outvars, outs):
                write(env, ov, t)
            return

        if name == "scan":
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            body = eqn.params["jaxpr"]
            consts_t, carry_t = in_t[:nc], list(in_t[nc:nc + ncar])
            xs_t = in_t[nc + ncar:]
            while True:                  # carry-taint fixpoint
                outs = self._quiet_run(body, consts_t + carry_t + xs_t)
                new_carry = [c | o for c, o in zip(carry_t, outs[:ncar])]
                if new_carry == carry_t:
                    break
                carry_t = new_carry
            outs = self.run(body, consts_t + carry_t + xs_t)
            final = [c | o for c, o in zip(carry_t, outs[:ncar])] \
                + outs[ncar:]
            for ov, t in zip(eqn.outvars, final):
                write(env, ov, t)
            return

        if name == "while":
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            body = eqn.params["body_jaxpr"]
            cond_t = in_t[:cn]
            body_c = in_t[cn:cn + bn]
            carry_t = list(in_t[cn + bn:])
            while True:
                outs = self._quiet_run(body, body_c + carry_t)
                new_carry = [c | o for c, o in zip(carry_t, outs)]
                if new_carry == carry_t:
                    break
                carry_t = new_carry
            self.run(body, body_c + carry_t)
            pred = frozenset().union(*cond_t) if cond_t else EMPTY
            for ov, t in zip(eqn.outvars, carry_t):
                write(env, ov, t | pred)
            return

        if name == "cond":
            pred_t = in_t[0]
            for branch in eqn.params["branches"]:
                outs = self.run(branch, in_t[1:])
                for ov, t in zip(eqn.outvars, outs):
                    write(env, ov, t | pred_t)
            return

        subs = sub_jaxprs(eqn.params)
        if len(subs) == 1 and len(as_open(subs[0]).invars) == len(in_t):
            outs = self.run(subs[0], in_t)
            for ov, t in zip(eqn.outvars, outs):
                write(env, ov, t)
            return

        # conservative fallback: everything flows everywhere
        union = frozenset().union(*in_t) if in_t else EMPTY
        for ov in eqn.outvars:
            write(env, ov, union)


# ---------------------------------------------------------------------------
# full-step tracing
# ---------------------------------------------------------------------------

_KEYED = (plan_mod.Noise, plan_mod.Importance)


@dataclasses.dataclass(frozen=True)
class StepTrace:
    """One traced ``Engine.step`` program plus the index maps the
    privacy and collective passes anchor on."""
    closed: Any                         # ClosedJaxpr of the step
    plan: plan_mod.Plan
    granularity: str
    batch_size: int
    data_axes: Tuple[str, ...]
    meshed: bool                        # traced through dist.pex.plan_step
    out_labels: Tuple[Tuple[str, str], ...]   # (field, leaf path) per outvar
    rng_positions: Tuple[int, ...]      # invar indices holding consumer keys
    rng_purposes: Tuple[str, ...]       # parallel to rng_positions

    @property
    def jaxpr(self):
        return self.closed.jaxpr

    def grad_outvars(self):
        """(outvar, leaf path) for every gradient leaf output."""
        jx = self.closed.jaxpr
        return [(jx.outvars[i], rest)
                for i, (head, rest) in enumerate(self.out_labels)
                if head == "grads"]


def _label(path) -> Tuple[str, str]:
    head = getattr(path[0], "key", str(path[0]))
    return str(head), jax.tree_util.keystr(path[1:])


@dataclasses.dataclass(frozen=True)
class TrainTrace:
    """One traced *training* step — ``Engine.step`` plus the optimizer
    apply — with the index maps the traffic/cost passes anchor on
    (DESIGN.md §13). ``ref_closed`` is the plain forward
    (``consumers=[]``) of the same model/batch, the duplicate-forward
    baseline."""
    closed: Any                         # ClosedJaxpr of step + apply
    plan: plan_mod.Plan
    granularity: str
    batch_size: int
    seq: Optional[int]
    optimizer: str                      # 'adamw' | 'adafactor' | 'none'
    global_clip: Optional[float]        # optimizer global-norm clip
    meshed: bool
    out_labels: Tuple[Tuple[str, str], ...]   # (field, leaf path) per outvar
    param_labels: Tuple[str, ...]       # leaf path per parameter position
    param_positions: Tuple[int, ...]    # invar indices of the param leaves
    opt_positions: Tuple[int, ...]      # invar indices of optimizer state
    batch_positions: Tuple[int, ...]
    rng_positions: Tuple[int, ...]
    ref_closed: Optional[Any] = None    # plain-forward ClosedJaxpr

    @property
    def jaxpr(self):
        return self.closed.jaxpr


def trace_train_step(loss_fn: Callable, params, batch,
                     consumers: Sequence, *, optimizer: str = "adamw",
                     opt_cfg=None, spec=None, granularity: str = "example",
                     mesh=None, data_axes: Sequence[str] = ("data",),
                     batch_size: Optional[int] = None,
                     seq: Optional[int] = None, loss_weights=None,
                     with_reference: bool = True) -> TrainTrace:
    """Trace one whole training step on abstract inputs: the plan
    execution (``Engine.step``, local or mesh path) *and* the optimizer
    apply — clip-scale, moment updates, parameter write — which no
    other pass covers. Gradient leaves cross the plan/apply boundary
    through the ``grad_leaf`` markers ``plan.execute`` plants, so the
    traffic pass can attribute every downstream HBM pass to a named
    parameter leaf. Optimizer state enters as explicit invars
    (abstract, from ``eval_shape`` over ``init``)."""
    from repro.core.engine import Engine, infer_batch_size

    eng = Engine(spec, mesh=mesh, data_axes=data_axes,
                 granularity=granularity)
    plan = plan_mod.analyze(consumers, engine_granularity=granularity)
    bs = batch_size if batch_size is not None else infer_batch_size(batch)

    keys = [c.rng for c in consumers
            if isinstance(c, _KEYED) and c.rng is not None]

    if optimizer == "adamw":
        from repro.optim import adamw as opt_mod
        cfg = opt_cfg if opt_cfg is not None else opt_mod.AdamWConfig()
        global_clip = cfg.global_clip
    elif optimizer == "adafactor":
        from repro.optim import adafactor as opt_mod
        cfg = opt_cfg if opt_cfg is not None else opt_mod.AdafactorConfig()
        global_clip = getattr(cfg, "global_clip", None)
    elif optimizer == "none":
        opt_mod = cfg = global_clip = None
    else:
        raise ValueError(f"unknown optimizer {optimizer!r}; expected "
                         f"'adamw', 'adafactor', or 'none'")

    apply = opt_mod is not None and plan.needs_grads
    opt_state = jax.eval_shape(opt_mod.init, params) if apply else ()

    def run(p, opt, b, *ks):
        it = iter(ks)
        cs = [dataclasses.replace(c, rng=next(it))
              if isinstance(c, _KEYED) and c.rng is not None else c
              for c in consumers]
        r = eng.step(loss_fn, p, b, cs, batch_size=bs, seq=seq,
                     loss_weights=loss_weights)
        out = {"loss_vec": r.loss_vec}
        if r.sq_norms is not None:
            out["sq_norms"] = r.sq_norms
        if r.gns is not None:
            out["gns"] = r.gns
        if r.grads is not None and apply:
            new_p, new_opt = opt_mod.update(cfg, opt, p, r.grads)
            out["new_params"] = new_p
            out["opt_state"] = new_opt
        elif r.grads is not None:
            out["grads"] = r.grads
        return out

    closed, out_shape = jax.make_jaxpr(run, return_shape=True)(
        params, opt_state, batch, *keys)
    flat_out, _ = jax.tree_util.tree_flatten_with_path(out_shape)
    labels = tuple(_label(p) for p, _ in flat_out)

    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    param_labels = tuple(jax.tree_util.keystr(p) for p, _ in flat_p)
    n_p = len(flat_p)
    n_o = len(jax.tree_util.tree_leaves(opt_state))
    n_b = len(jax.tree_util.tree_leaves(batch))

    ref = None
    if with_reference:
        # the duplicate-forward baseline must NOT share the plan path —
        # a mutant that doubles the plan's forward would double the
        # reference too and cancel out. Trace the raw loss instead.
        from repro.core.taps import NULL
        ref = jax.make_jaxpr(
            lambda p, b: loss_fn(p, b, NULL)[0])(params, batch)

    return TrainTrace(
        closed=closed, plan=plan, granularity=granularity, batch_size=bs,
        seq=seq, optimizer=optimizer if apply else "none",
        global_clip=global_clip if apply else None,
        meshed=mesh is not None, out_labels=labels,
        param_labels=param_labels,
        param_positions=tuple(range(n_p)),
        opt_positions=tuple(range(n_p, n_p + n_o)),
        batch_positions=tuple(range(n_p + n_o, n_p + n_o + n_b)),
        rng_positions=tuple(range(n_p + n_o + n_b,
                                  n_p + n_o + n_b + len(keys))),
        ref_closed=ref)


def trace_step(loss_fn: Callable, params, batch, consumers: Sequence, *,
               spec=None, granularity: str = "example", mesh=None,
               data_axes: Sequence[str] = ("data",),
               batch_size: Optional[int] = None, seq: Optional[int] = None,
               loss_weights=None) -> StepTrace:
    """Trace ``Engine.step`` for one consumer list on abstract inputs.

    Consumer PRNG keys (``Noise.rng`` / ``Importance.rng``) are lifted
    to explicit invars of the traced function — ``dataclasses.replace``
    rebinds each consumer to its argument inside the trace — so the
    privacy pass sees key lineage start at a named input. Keys may be
    ``ShapeDtypeStruct``s of a real key's aval.
    """
    from repro.core.engine import Engine, infer_batch_size

    eng = Engine(spec, mesh=mesh, data_axes=data_axes,
                 granularity=granularity)
    plan = plan_mod.analyze(consumers, engine_granularity=granularity)
    bs = batch_size if batch_size is not None else infer_batch_size(batch)

    keys, purposes = [], []
    for c in consumers:
        if isinstance(c, _KEYED) and c.rng is not None:
            keys.append(c.rng)
            purposes.append("noise" if isinstance(c, plan_mod.Noise)
                            else "importance")

    def run(p, b, *ks):
        it = iter(ks)
        cs = [dataclasses.replace(c, rng=next(it))
              if isinstance(c, _KEYED) and c.rng is not None else c
              for c in consumers]
        r = eng.step(loss_fn, p, b, cs, batch_size=bs, seq=seq,
                     loss_weights=loss_weights)
        out = {"loss_vec": r.loss_vec}
        if r.sq_norms is not None:
            out["sq_norms"] = r.sq_norms
        if r.grads is not None:
            out["grads"] = r.grads
        if r.gns is not None:
            out["gns"] = r.gns
        return out

    closed, out_shape = jax.make_jaxpr(run, return_shape=True)(
        params, batch, *keys)
    flat_out, _ = jax.tree_util.tree_flatten_with_path(out_shape)
    labels = tuple(_label(p) for p, _ in flat_out)

    n_p = len(jax.tree_util.tree_leaves(params))
    n_b = len(jax.tree_util.tree_leaves(batch))
    positions = tuple(range(n_p + n_b, n_p + n_b + len(keys)))
    return StepTrace(closed=closed, plan=plan, granularity=granularity,
                     batch_size=bs,
                     data_axes=(data_axes,) if isinstance(data_axes, str)
                     else tuple(data_axes),
                     meshed=mesh is not None, out_labels=labels,
                     rng_positions=positions, rng_purposes=tuple(purposes))
