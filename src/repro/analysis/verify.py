"""``Engine.verify`` backend — one call that runs every trace-only
pexlint pass against a model (DESIGN.md §10, §12).

Composes the analyzers:

  * plan analysis (``core.plan.analyze``) validates the consumer list
    and yields the static cost shape (``Plan.describe()``);
  * tap coverage (``analysis.coverage``) proves every trained
    parameter's gradient is reachable by a tap, modulo the declared
    allowlist;
  * launch validation (``analysis.launch``) checks every Pallas
    schedule the trace's tap sites imply, plus the config-derived
    production geometries;
  * privacy flow (``analysis.privacy``) walks a full traced step per
    consumer set and proves the DP dataflow invariants —
    clip-before-sum, noise-once-after-psum, σ·C scale, single-use
    keys;
  * collective layout (``analysis.collectives``) checks the shard_map
    regions of a mesh trace against the per-example/replicated psum
    contract;
  * determinism (``analysis.determinism``) statically verifies the
    data pipeline and soak replay path are (seed, step)-pure.

Everything here operates on traced jaxprs and static contracts — no
XLA compilation, no kernel execution — so it is safe to run on
abstract ``ShapeDtypeStruct`` params/batches and cheap enough for CI
on every registered model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.analysis import _jaxpr as _J
from repro.analysis import collectives as _col
from repro.analysis import cost as _cost
from repro.analysis import coverage as _cov
from repro.analysis import determinism as _det
from repro.analysis import launch as _launch
from repro.analysis import privacy as _priv
from repro.analysis import traffic as _traf
from repro.analysis.findings import ERROR, Finding
from repro.core import plan as plan_mod
from repro.core.taps import ExampleLayout, PexSpec, TokenLayout


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """Combined result of one ``Engine.verify`` run."""
    plans: Tuple[plan_mod.Plan, ...]
    coverage: _cov.CoverageReport
    launch: _launch.LaunchReport
    privacy: Tuple[_priv.PrivacyReport, ...] = ()
    collectives: Tuple[_col.CollectivesReport, ...] = ()
    determinism: Optional[_det.DeterminismReport] = None
    traffic: Tuple[_traf.TrafficReport, ...] = ()
    cost: Tuple[_cost.CostReport, ...] = ()

    @property
    def findings(self) -> Tuple[Finding, ...]:
        """Every Finding from the flow passes (privacy, collectives,
        determinism, traffic); coverage/launch keep their own report
        shapes, and allowlisted traffic findings stay on the
        TrafficReport."""
        out: Tuple[Finding, ...] = ()
        for r in self.privacy + self.collectives:
            out += r.findings
        if self.determinism is not None:
            out += self.determinism.findings
        for t in self.traffic:
            out += t.findings
        return out

    @property
    def ok(self) -> bool:
        return (self.coverage.ok and self.launch.ok
                and all(r.ok for r in self.privacy)
                and all(r.ok for r in self.collectives)
                and all(t.ok for t in self.traffic)
                and (self.determinism is None or self.determinism.ok))

    @property
    def errors(self) -> Tuple[str, ...]:
        cov = tuple(f"coverage: {l.path} is {l.status}"
                    for l in self.coverage.errors)
        flow = tuple(f.render() for f in self.findings
                     if f.severity == ERROR)
        return cov + tuple(f"launch: {e}" for e in self.launch.errors) \
            + flow

    def summary(self) -> str:
        lines = [f"plan[{i}]: {p.describe()}"
                 for i, p in enumerate(self.plans)]
        lines.append(self.coverage.summary())
        lines.append(self.launch.summary())
        for r in self.privacy:
            lines.append(r.summary())
        for r in self.collectives:
            lines.append(r.summary())
        if self.determinism is not None:
            lines.append(self.determinism.summary())
        for t in self.traffic:
            lines.append(t.summary())
        for c in self.cost:
            lines.append(c.summary())
        return "\n".join(lines)

    def raise_if_errors(self) -> "VerifyReport":
        self.coverage.raise_if_errors()
        self.launch.raise_if_errors()
        flow = [f.render() for f in self.findings
                if f.severity == ERROR]
        if flow:
            raise _cov.AnalysisError("\n".join(flow))
        return self


def verify(loss_fn, params, batch, consumers: Sequence = (), *,
           spec: Optional[PexSpec] = None, granularity: str = "example",
           allow: Sequence[str] = (), batch_size: Optional[int] = None,
           seq: Optional[int] = None, cfg=None, backend: str = "tpu",
           production: bool = True, mesh=None,
           data_axes: Sequence[str] = ("data",),
           deep: bool = True, determinism: bool = True,
           cost: bool = False, optimizer: str = "adamw",
           profile: Optional[str] = None, chips: int = 1,
           model: Optional[str] = None) -> VerifyReport:
    """Run all trace-only static checks for one model.

    ``consumers`` may be one consumer list or a sequence of lists —
    each is folded through plan analysis (raising on invalid
    compositions) without affecting the coverage trace; the tap sites
    a model emits do not depend on who consumes the stats. With
    ``deep`` (default), each non-empty consumer set is additionally
    traced as a full ``Engine.step`` and run through the privacy-flow
    pass — against ``mesh`` when one is given (which also enables the
    collective-layout pass on its shard_map regions) — and the data
    pipeline's determinism contract is checked once.

    With ``cost`` (independent of ``deep``), each non-empty consumer
    set is traced as a full *training* step — plan execution plus the
    ``optimizer`` apply — and run through the traffic pass
    (``analysis.traffic``); each TrafficReport is composed into a
    ``CostReport`` on the named hardware ``profile`` (default
    tpu-v5e). Traffic findings gate ``.ok`` like every flow pass;
    allowlisted ones (today's known 3-stream apply) do not.
    """
    spec = spec if spec is not None else PexSpec(enabled=True)
    if consumers and not isinstance(consumers[0], (list, tuple)):
        consumer_sets = [list(consumers)]
    else:
        consumer_sets = [list(c) for c in consumers] or [[]]
    plans = tuple(plan_mod.analyze(c, engine_granularity=granularity)
                  for c in consumer_sets)

    if granularity == "token":
        from repro.core.engine import infer_seq_len
        layout = TokenLayout(seq if seq is not None
                             else infer_seq_len(batch))
    else:
        layout = ExampleLayout(spec.n_groups)
    cov = _cov.trace_coverage(loss_fn, params, batch, spec=spec,
                              layout=layout, batch_size=batch_size,
                              allow=allow)
    lr = _launch.validate_sites(cov.sites, cfg, backend=backend,
                                production=production)

    privacy: Tuple[_priv.PrivacyReport, ...] = ()
    collectives: Tuple[_col.CollectivesReport, ...] = ()
    det = None
    if deep:
        for cs in consumer_sets:
            if not cs:
                continue
            tr = _J.trace_step(loss_fn, params, batch, cs, spec=spec,
                               granularity=granularity, mesh=mesh,
                               data_axes=data_axes,
                               batch_size=batch_size, seq=seq)
            privacy += (_priv.analyze_trace(tr),)
            if mesh is not None:
                collectives += (_col.analyze_trace(tr),)
        if determinism:
            # the data-pipeline purity contract is model-independent;
            # batch drivers (the CLI) check it once and pass False here
            det = _det.analyze()

    traffic: Tuple[_traf.TrafficReport, ...] = ()
    cost_reps: Tuple[_cost.CostReport, ...] = ()
    if cost:
        for cs in consumer_sets:
            if not cs:
                continue
            tt = _J.trace_train_step(
                loss_fn, params, batch, cs, optimizer=optimizer,
                spec=spec, granularity=granularity, mesh=mesh,
                data_axes=data_axes, batch_size=batch_size, seq=seq)
            tr = _traf.analyze_trace(tt)
            traffic += (tr,)
            cost_reps += (_cost.build_cost(
                tr, model=model if model is not None else "model",
                profile=profile if profile is not None
                else _cost.DEFAULT_PROFILE, chips=chips),)

    return VerifyReport(plans, cov, lr, privacy, collectives, det,
                        traffic, cost_reps)
