"""``Engine.verify`` backend — one call that runs every trace-only
pexlint pass against a model (DESIGN.md §10).

Composes the three analyzers:

  * plan analysis (``core.plan.analyze``) validates the consumer list
    and yields the static cost shape (``Plan.describe()``);
  * tap coverage (``analysis.coverage``) proves every trained
    parameter's gradient is reachable by a tap, modulo the declared
    allowlist;
  * launch validation (``analysis.launch``) checks every Pallas
    schedule the trace's tap sites imply, plus the config-derived
    production geometries.

Everything here operates on traced jaxprs and static contracts — no
XLA compilation, no kernel execution — so it is safe to run on
abstract ``ShapeDtypeStruct`` params/batches and cheap enough for CI
on every registered model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.analysis import coverage as _cov
from repro.analysis import launch as _launch
from repro.core import plan as plan_mod
from repro.core.taps import ExampleLayout, PexSpec, TokenLayout


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """Combined result of one ``Engine.verify`` run."""
    plans: Tuple[plan_mod.Plan, ...]
    coverage: _cov.CoverageReport
    launch: _launch.LaunchReport

    @property
    def ok(self) -> bool:
        return self.coverage.ok and self.launch.ok

    @property
    def errors(self) -> Tuple[str, ...]:
        cov = tuple(f"coverage: {l.path} is {l.status}"
                    for l in self.coverage.errors)
        return cov + tuple(f"launch: {e}" for e in self.launch.errors)

    def summary(self) -> str:
        lines = [f"plan[{i}]: {p.describe()}"
                 for i, p in enumerate(self.plans)]
        lines.append(self.coverage.summary())
        lines.append(self.launch.summary())
        return "\n".join(lines)

    def raise_if_errors(self) -> "VerifyReport":
        self.coverage.raise_if_errors()
        self.launch.raise_if_errors()
        return self


def verify(loss_fn, params, batch, consumers: Sequence = (), *,
           spec: Optional[PexSpec] = None, granularity: str = "example",
           allow: Sequence[str] = (), batch_size: Optional[int] = None,
           seq: Optional[int] = None, cfg=None, backend: str = "tpu",
           production: bool = True) -> VerifyReport:
    """Run all trace-only static checks for one model.

    ``consumers`` may be one consumer list or a sequence of lists —
    each is folded through plan analysis (raising on invalid
    compositions) without affecting the trace; the tap sites a model
    emits do not depend on who consumes the stats.
    """
    spec = spec if spec is not None else PexSpec(enabled=True)
    if consumers and not isinstance(consumers[0], (list, tuple)):
        consumer_sets = [list(consumers)]
    else:
        consumer_sets = [list(c) for c in consumers] or [[]]
    plans = tuple(plan_mod.analyze(c, engine_granularity=granularity)
                  for c in consumer_sets)

    if granularity == "token":
        from repro.core.engine import infer_seq_len
        layout = TokenLayout(seq if seq is not None
                             else infer_seq_len(batch))
    else:
        layout = ExampleLayout(spec.n_groups)
    cov = _cov.trace_coverage(loss_fn, params, batch, spec=spec,
                              layout=layout, batch_size=batch_size,
                              allow=allow)
    lr = _launch.validate_sites(cov.sites, cfg, backend=backend,
                                production=production)
    return VerifyReport(plans, cov, lr)
