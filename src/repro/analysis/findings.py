"""Structured lint findings — the machine-readable unit every pexlint
pass reports in (DESIGN.md §12).

A ``Finding`` is one verdict from one pass about one place: severity,
a stable ``code`` (what rule fired), the model/granularity the trace
came from, and — when the rule is about a specific gradient leaf — the
parameter path. ``python -m repro.analysis --json`` emits these
verbatim for CI annotation; the human CLI renders them as lines.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"
INFO = "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verdict from one static pass."""
    pass_: str                      # coverage | launch | privacy | ...
    severity: str                   # error | warning | info
    code: str                       # stable rule id, kebab-case
    message: str
    model: Optional[str] = None
    granularity: Optional[str] = None
    leaf: Optional[str] = None      # parameter-leaf path, when leaf-scoped

    def to_json(self) -> dict:
        d = {"pass": self.pass_, "severity": self.severity,
             "code": self.code, "message": self.message}
        for k in ("model", "granularity", "leaf"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d

    def render(self) -> str:
        where = "".join(
            f" [{v}]" for v in (self.model, self.granularity, self.leaf)
            if v is not None)
        return f"{self.severity.upper()} {self.pass_}/{self.code}" \
               f"{where}: {self.message}"


def errors(findings: Sequence[Finding]) -> Tuple[Finding, ...]:
    return tuple(f for f in findings if f.severity == ERROR)


def warnings_(findings: Sequence[Finding]) -> Tuple[Finding, ...]:
    return tuple(f for f in findings if f.severity == WARNING)


def tag(findings: Sequence[Finding], *, model: Optional[str] = None,
        granularity: Optional[str] = None) -> Tuple[Finding, ...]:
    """Fill in model/granularity on findings that lack them (passes
    report location-agnostically; the driver knows the trace's
    provenance)."""
    return tuple(
        dataclasses.replace(
            f,
            model=f.model if f.model is not None else model,
            granularity=(f.granularity if f.granularity is not None
                         else granularity))
        for f in findings)
