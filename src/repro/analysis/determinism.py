"""Determinism verification of the data pipeline and replay path
(pexlint pass, DESIGN.md §12).

The fault-tolerance story (launch/ft.py, launch/soak.py) rests on one
property of the data layer: every batch is a pure function of
``(seed, step)``. Resume-from-checkpoint replays the exact token
stream because the only persisted cursor IS the step counter; the soak
harness's INV2 replay check and the elastic renumbering proof both
assume it. The property is easy to break silently — a cached iterator,
a wall-clock-salted seed, one call to the legacy global numpy RNG —
and nothing fails until a recovery trains on different data than the
uninterrupted run would have seen.

This pass checks the property statically, over the *source* of the
stream functions (``ast``, no execution):

  * **forbidden-call** — wall-clock (``time.time``/``datetime.now``),
    process entropy (``os.urandom``, ``secrets``, ``uuid``), the
    stdlib ``random`` module, and numpy's legacy global-state RNG
    (``np.random.<anything>`` except the seeded constructor family
    ``default_rng``/``Generator``/``SeedSequence``/bit generators);
  * **unseeded-rng** — ``default_rng()`` with no seed argument draws
    OS entropy;
  * **unstable-hash** — the ``hash()`` builtin is salted per process
    (PYTHONHASHSEED) and must never feed a seed;
  * **iterator-state** — stream classes may not mutate ``self``
    outside ``__init__``/``__post_init__``: any per-call cursor makes
    ``batch_at(step)`` depend on call history, not on ``step``;
  * **global-state** — ``global``/``nonlocal`` writes are call-history
    by another name;
  * **seed-ignores-step** — a function taking a ``step`` parameter
    that constructs an RNG (``default_rng``/``PRNGKey``/``fold_in``)
    must feed ``step`` into that construction, else every step replays
    the same stream position (or worse, an ambient one).

``check_source`` is the unit (mutation-testable on source snippets);
``analyze`` applies it to the shipping targets: the whole
``repro.data.pipeline`` module and the soak harness's replay probe
``SoakWorld._probe_batch``.
"""
from __future__ import annotations

import ast
import dataclasses
import inspect
import textwrap
from typing import List, Optional, Sequence, Tuple

from repro.analysis.findings import ERROR, Finding

PASS = "determinism"

#: dotted-call prefixes that are never (seed, step)-pure. Matched
#: textually against the source's attribute chain — the repo's idiom
#: (``import numpy as np``, stdlib modules by name) makes this exact.
_FORBIDDEN_PREFIXES: Tuple[Tuple[str, str], ...] = (
    ("time.time", "wall-clock"),
    ("time.time_ns", "wall-clock"),
    ("time.monotonic", "wall-clock"),
    ("time.perf_counter", "wall-clock"),
    ("datetime.datetime.now", "wall-clock"),
    ("datetime.datetime.utcnow", "wall-clock"),
    ("datetime.date.today", "wall-clock"),
    ("os.urandom", "process entropy"),
    ("os.getrandom", "process entropy"),
    ("secrets.", "process entropy"),
    ("uuid.uuid1", "process entropy"),
    ("uuid.uuid4", "process entropy"),
    ("random.", "stdlib global RNG"),
)

#: the seeded-constructor family under np.random that IS allowed;
#: everything else there is the legacy global-state RNG
_NP_RANDOM_ALLOWED = frozenset({
    "default_rng", "Generator", "SeedSequence",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64",
})

#: RNG constructors whose seed must involve ``step`` inside a
#: step-indexed stream function
_RNG_CONSTRUCTORS = frozenset({"default_rng", "PRNGKey", "fold_in"})

#: methods allowed to write ``self`` — one-time setup, not per-call
_SETUP_METHODS = frozenset({"__init__", "__post_init__", "__new__"})


def _dotted(node: ast.AST) -> Optional[str]:
    """``np.random.default_rng`` → that string; None for non-chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _names_in(node: ast.AST) -> frozenset:
    return frozenset(n.id for n in ast.walk(node)
                     if isinstance(n, ast.Name))


class _Checker(ast.NodeVisitor):
    def __init__(self, target: str):
        self.target = target
        self.findings: List[Finding] = []
        self._class: Optional[str] = None
        self._func: List[str] = []

    def _err(self, code: str, node: ast.AST, msg: str) -> None:
        where = ".".join(filter(None, [self._class] + self._func)) \
            or "<module>"
        self.findings.append(Finding(
            PASS, ERROR, code,
            f"{self.target}:{node.lineno} ({where}): {msg}"))

    # -- calls ------------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)
        if name:
            for prefix, why in _FORBIDDEN_PREFIXES:
                if name == prefix or name.startswith(prefix + ".") \
                        or (prefix.endswith(".")
                            and name.startswith(prefix)):
                    self._err("forbidden-call", node,
                              f"call to {name} ({why}) — batches must "
                              f"be pure in (seed, step)")
                    break
            else:
                self._np_random(name, node)
        if isinstance(node.func, ast.Name) and node.func.id == "hash":
            self._err("unstable-hash", node,
                      "hash() is salted per process (PYTHONHASHSEED); "
                      "seed material must use a stable mix")
        if name and name.split(".")[-1] == "default_rng" \
                and not node.args:
            self._err("unseeded-rng", node,
                      "default_rng() with no seed draws OS entropy; "
                      "pass a (seed, step, ...) tuple")
        self.generic_visit(node)

    def _np_random(self, name: Optional[str], node: ast.Call) -> None:
        if not name:
            return
        for root in ("np.random.", "numpy.random.", "jnp.random."):
            if name.startswith(root):
                tail = name[len(root):].split(".")[0]
                if tail not in _NP_RANDOM_ALLOWED:
                    self._err("forbidden-call", node,
                              f"call to {name} uses numpy's legacy "
                              f"global-state RNG; use a "
                              f"default_rng((seed, step, ...)) stream")
                return

    # -- state ------------------------------------------------------------
    def _self_write(self, target: ast.AST) -> bool:
        return (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self")

    def _check_store(self, targets: Sequence[ast.AST],
                     node: ast.AST) -> None:
        if not self._func or self._func[-1] in _SETUP_METHODS:
            return
        for t in targets:
            if self._self_write(t):
                self._err("iterator-state", node,
                          f"stream method mutates self.{t.attr}: a "
                          f"per-call cursor makes output depend on "
                          f"call history, not on step")

    def visit_Assign(self, node: ast.Assign) -> None:
        self._check_store(node.targets, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store([node.target], node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_store([node.target], node)
        self.generic_visit(node)

    def visit_Global(self, node: ast.Global) -> None:
        self._err("global-state", node,
                  f"global {', '.join(node.names)}: module state is "
                  f"call history by another name")

    def visit_Nonlocal(self, node: ast.Nonlocal) -> None:
        self._err("global-state", node,
                  f"nonlocal {', '.join(node.names)} in a stream "
                  f"function")

    # -- structure --------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        prev, self._class = self._class, node.name
        self.generic_visit(node)
        self._class = prev

    def _function(self, node) -> None:
        self._func.append(node.name)
        self.generic_visit(node)
        self._func.pop()
        self._step_purity(node)

    visit_FunctionDef = _function
    visit_AsyncFunctionDef = _function

    def _step_purity(self, fn) -> None:
        """A step-indexed function that builds an RNG must feed
        ``step`` into the construction."""
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        if "step" not in params:
            return
        ctors = [c for c in ast.walk(fn)
                 if isinstance(c, ast.Call)
                 and (_dotted(c.func) or "").split(".")[-1]
                 in _RNG_CONSTRUCTORS]
        seeded = [c for c in ctors if any(
            "step" in _names_in(a)
            for a in list(c.args) + [kw.value for kw in c.keywords])]
        if ctors and not seeded:
            self._err("seed-ignores-step", ctors[0],
                      f"{fn.name}(step) constructs an RNG whose seed "
                      f"never references step: every step would replay "
                      f"the same stream position")


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def check_source(src: str, name: str) -> Tuple[Finding, ...]:
    """Run the purity checks over one source blob (a module or a
    dedented function). The unit the mutation corpus drives."""
    tree = ast.parse(textwrap.dedent(src))
    checker = _Checker(name)
    checker.visit(tree)
    return tuple(checker.findings)


@dataclasses.dataclass(frozen=True)
class DeterminismTarget:
    name: str
    lines: int


@dataclasses.dataclass(frozen=True)
class DeterminismReport:
    targets: Tuple[DeterminismTarget, ...]
    findings: Tuple[Finding, ...]

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        head = (f"determinism: {len(self.targets)} target(s), "
                f"{sum(t.lines for t in self.targets)} lines checked")
        return "\n".join([head] + [f"  {f.render()}"
                                   for f in self.findings])


def _default_targets() -> List[Tuple[str, str]]:
    import repro.data.pipeline as pipeline
    from repro.launch.soak import SoakWorld
    return [
        ("data/pipeline.py", inspect.getsource(pipeline)),
        ("launch/soak.py::SoakWorld._probe_batch",
         inspect.getsource(SoakWorld._probe_batch)),
    ]


def analyze(targets: Optional[Sequence[Tuple[str, str]]] = None
            ) -> DeterminismReport:
    """Check the shipping replay surface: the whole data pipeline plus
    the soak harness's probe-batch path (the function INV2 replays
    through). ``targets`` overrides as ``(name, source)`` pairs."""
    pairs = list(targets) if targets is not None else _default_targets()
    findings: List[Finding] = []
    checked = []
    for name, src in pairs:
        findings.extend(check_source(src, name))
        checked.append(DeterminismTarget(name, src.count("\n") + 1))
    return DeterminismReport(tuple(checked), tuple(findings))
