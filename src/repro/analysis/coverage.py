"""Tap-coverage verifier: prove, at trace time, that the taps cover
the parameter tree (pexlint pass 1, DESIGN.md §10).

The paper's exactness claim is only as good as the instrumentation: a
parameter whose gradient path bypasses every ``pex`` custom_vjp
contributes to training but not to the per-example norms — DP clipping
silently under-clips and GNS/importance estimates bias. Nothing about
running the model can catch that (the norms are merely *smaller*), but
the traced jaxpr can: this pass walks the closed jaxpr of
``loss_fn(params, batch, tap)`` with a live tap and classifies every
parameter leaf by taint analysis.

**Taint propagation.** Each jaxpr variable carries the set of parameter
leaves it (transitively) depends on. Ordinary equations union their
operands' taint into their outputs — the structural recursion into
``pjit``, ``scan``, ``while``/``cond``, and foreign ``custom_vjp``
calls lives in the shared front end (``analysis._jaxpr.Walker``). A
pex equation (identified by its registered backward rule —
``taps.PEX_OPS``) is the one place taint is *blocked*: the weight-slot
operand's taint is captured as a tap site and does NOT flow into the
op's output, while data-slot taint flows through. After propagation:

  * leaf taint reaches the loss        ⇒ **untapped-but-trained**: some
    gradient path avoids every tap (ERROR unless allowlisted);
  * leaf captured at a tap site only   ⇒ **tapped** (OK);
  * leaf taint reaches nothing          ⇒ **frozen/unused** (OK).

A leaf that is both captured *and* reaches the loss (e.g. a weight
used through ``tap.dense`` in one layer and a plain einsum in another)
is still an error — its norm undercounts the plain path.

Everything here is ``jax.make_jaxpr`` — no XLA compilation, no
execution; abstract (``ShapeDtypeStruct``) params and batches work.

**Allowlist.** Intentionally untapped parameters (DESIGN.md §5: the
weight-shared zamba2 block, ssm conv/decay tensors, rwkv mix/decay
bases — and the upcoming scoped-tap modes) must be *declared*, not
accidental: ``allow`` entries are substrings matched against the
leaf's key path (``models.registry.UNTAPPED_ALLOWLIST`` holds the
per-arch declarations; ``tests/helpers.py`` derives its oracle scope
filter from the same table, so the analyzer and the exactness tests
can never disagree about scope). The converse is checked too: an
``allow`` entry that matches NO parameter path is *stale* — the
parameter it once declared was renamed or removed, and the entry now
silently waits to mask a future regression — reported in
``CoverageReport.stale_allow`` and surfaced as a pexlint WARNING.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import _jaxpr as _J
from repro.analysis._jaxpr import AnalysisError  # re-export  # noqa: F401
from repro.core.taps import (ExampleLayout, PexSpec, Tap, identify_pex_bwd)

_EMPTY = _J.EMPTY


# ---------------------------------------------------------------------------
# report datatypes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TapSite:
    """One instrumented op in the traced program."""
    index: int
    op: str                         # dense | bias_add | scale | ...
    param_leaves: frozenset         # leaf ids captured in the weight slot
    operand_avals: Tuple            # (shape, dtype name) per operand


#: classification outcomes
TAPPED = "tapped"
UNTAPPED = "untapped-but-trained"
FROZEN = "frozen/unused"


@dataclasses.dataclass(frozen=True)
class LeafReport:
    path: str                       # display path (keystr)
    shape: Tuple[int, ...]
    status: str                     # TAPPED | UNTAPPED | FROZEN
    allowlisted: bool
    sites: Tuple[int, ...]          # TapSite indices capturing this leaf

    @property
    def is_error(self) -> bool:
        return self.status == UNTAPPED and not self.allowlisted


@dataclasses.dataclass(frozen=True)
class CoverageReport:
    leaves: Tuple[LeafReport, ...]
    sites: Tuple[TapSite, ...]
    token_loss_registered: bool
    stale_allow: Tuple[str, ...] = ()   # allow entries matching no leaf

    @property
    def errors(self) -> Tuple[LeafReport, ...]:
        return tuple(l for l in self.leaves if l.is_error)

    @property
    def ok(self) -> bool:
        return not self.errors

    def counts(self) -> dict:
        out = {TAPPED: 0, UNTAPPED: 0, FROZEN: 0, "allowlisted": 0}
        for l in self.leaves:
            if l.status == UNTAPPED and l.allowlisted:
                out["allowlisted"] += 1
            else:
                out[l.status] += 1
        return out

    def summary(self) -> str:
        c = self.counts()
        head = (f"{len(self.sites)} tap sites; {c[TAPPED]} tapped, "
                f"{c['allowlisted']} allowlisted-untapped, "
                f"{c[FROZEN]} frozen, {c[UNTAPPED]} ERROR")
        lines = [head]
        for l in self.errors:
            lines.append(
                f"  ERROR untapped-but-trained: {l.path} {l.shape} — its "
                f"gradient path reaches the loss without crossing any pex "
                f"op, so per-example norms undercount it; tap it or add "
                f"it to the allowlist")
        for a in self.stale_allow:
            lines.append(
                f"  WARNING stale allowlist entry {a!r}: matches no "
                f"parameter path in this model — remove it, or it will "
                f"silently mask the next parameter named like it")
        return "\n".join(lines)

    def raise_if_errors(self) -> "CoverageReport":
        if not self.ok:
            raise AnalysisError("tap coverage failed:\n" + self.summary())
        return self


# ---------------------------------------------------------------------------
# the jaxpr walker — pex tap semantics over the shared front end
# ---------------------------------------------------------------------------

class _CoverageWalker(_J.Walker):
    """Union-taint walker that blocks weight taint at pex tap sites."""

    def __init__(self):
        super().__init__()
        self.sites: list = []

    def hook(self, eqn, in_t):
        if eqn.primitive.name == "stop_gradient":
            # no gradient flows back through stop_gradient, so taint
            # must not flow forward: a frozen LoRA base weight
            # (nn.linear's stop_grad(W) path) is FROZEN, not
            # untapped-ERROR — its value reaches the loss, its
            # gradient path does not
            return [_EMPTY for _ in eqn.outvars]
        if eqn.primitive.name not in ("custom_vjp_call_jaxpr",
                                      "custom_vjp_call"):
            return None
        info = identify_pex_bwd(eqn.params.get("bwd"))
        num_consts = eqn.params.get("num_consts", 0)
        if info is not None and \
                len(eqn.invars) - num_consts == info.n_operands:
            ops_t = in_t[num_consts:]
            ops_v = eqn.invars[num_consts:]
            captured = _EMPTY
            for ws in info.weight_slots:
                captured = captured | ops_t[ws]
            if self.recording:
                avals = tuple(
                    (tuple(v.aval.shape), jnp.dtype(v.aval.dtype).name)
                    for v in ops_v)
                self.sites.append(
                    TapSite(len(self.sites), info.name, captured, avals))
            data = _EMPTY
            for ds in info.data_slots:
                data = data | ops_t[ds]
            # outputs are (z, acc): weight taint is *blocked* — covered
            # gradient paths end at the tap
            return [data] + [ops_t[-1]] * (len(eqn.outvars) - 1)
        # foreign custom_vjp (e.g. flash attention): recurse
        fun = eqn.params.get("fun_jaxpr") or eqn.params.get("call_jaxpr")
        if fun is not None and len(_J.as_open(fun).invars) == len(in_t):
            return self.run(fun, in_t)
        return None


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

def _leading_dim(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("cannot infer batch size from an empty batch")
    return leaves[0].shape[0]


def stale_allow_entries(allow: Sequence[str],
                        paths: Sequence[Tuple[str, str]]) -> Tuple[str, ...]:
    """``allow`` entries that match no leaf path. ``paths`` holds
    (raw, pretty) path pairs — the same two forms the allowlist is
    matched against, so an entry is stale iff it can never fire."""
    return tuple(a for a in allow
                 if not any(a in raw or a in pretty for raw, pretty in paths))


def trace_coverage(loss_fn: Callable, params, batch, *,
                   spec: Optional[PexSpec] = None, layout=None,
                   batch_size: Optional[int] = None,
                   allow: Sequence[str] = (),
                   tap_factory: Optional[Callable] = None) -> CoverageReport:
    """Classify every parameter leaf of ``loss_fn(params, batch, tap)``
    as tapped / untapped-but-trained / frozen. Trace-only: works on
    concrete arrays or ``ShapeDtypeStruct`` trees, never compiles.

    ``allow`` entries are substrings matched against the leaf's key
    path (both the raw ``(DictKey(...), ...)`` form — compatible with
    the historical scope filters — and the ``keystr`` form).
    ``tap_factory(spec, acc=..., layout=...)`` substitutes a custom
    collector (the mutation-corpus tests inject site-deleting taps)."""
    spec = spec if spec is not None else PexSpec(enabled=True)
    if not spec.enabled:
        raise ValueError(
            "tap coverage needs a live tap: spec.enabled=False would "
            "classify every trained parameter as untapped")
    layout = layout if layout is not None else ExampleLayout(spec.n_groups)
    b = batch_size if batch_size is not None else _leading_dim(batch)
    factory = tap_factory if tap_factory is not None else Tap

    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    n_leaves = len(flat)
    state = {}

    def run(p, bt):
        tap = factory(spec, acc=layout.init(b), layout=layout)
        loss_vec, _aux = loss_fn(p, bt, tap)
        state["token"] = tap.token_losses() is not None
        return jnp.sum(loss_vec)

    closed = jax.make_jaxpr(run)(params, batch)
    jaxpr = closed.jaxpr

    walker = _CoverageWalker()
    in_taints = [frozenset((i,)) if i < n_leaves else _EMPTY
                 for i in range(len(jaxpr.invars))]
    out_taints = walker.run(jaxpr, in_taints)
    sites = walker.sites
    loss_taint = out_taints[0] if out_taints else _EMPTY

    leaves = []
    path_pairs = []
    for i, (path, leaf) in enumerate(flat):
        raw, pretty = str(path), jax.tree_util.keystr(path)
        path_pairs.append((raw, pretty))
        captured = tuple(s.index for s in sites if i in s.param_leaves)
        if i in loss_taint:
            status = UNTAPPED
        elif captured:
            status = TAPPED
        else:
            status = FROZEN
        allowed = status == UNTAPPED and any(
            a in raw or a in pretty for a in allow)
        leaves.append(LeafReport(pretty, tuple(leaf.shape), status,
                                 allowed, captured))
    return CoverageReport(tuple(leaves), tuple(sites),
                          bool(state.get("token")),
                          stale_allow_entries(allow, path_pairs))
