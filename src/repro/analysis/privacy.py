"""Privacy-flow verification of a traced DP step (pexlint pass,
DESIGN.md §12).

The DP-SGD guarantees of the plan layer are *program* properties: every
trained leaf's gradient must be scaled by the per-example clip
coefficient before any batch sum, Gaussian noise must enter exactly
once — after the cross-device psum — at stddev σ·C, and no PRNG key
may be consumed twice. This pass proves them on the closed jaxpr of a
full ``Engine.step`` (``analysis._jaxpr.trace_step``), anchored on the
``pex_mark`` provenance markers production code plants on every
privacy-critical value (``core.provenance``):

**Lineage lattice.** A forward taint walk labels every variable with
the subset of {``seed:plain``, ``seed:norms``, ``seed:weighted``,
``clip``, ``noise:<site>``, ``key``} it derives from. Markers are the
semantics: a ``clip_coef`` marker *replaces* its input taint with
{``clip``} (the coefficient is a function of the norms, but values
scaled by it are clipped — the laundering is what distinguishes
"derived from the norms backward" from "an unweighted seed reached the
gradient"); a ``grad_seed`` marker keeps only the clip evidence and
adds its kind; a ``noise`` marker replaces taint with its own site
token (each leaf's noise is one marker — counting tokens per gradient
leaf is the exactly-once proof); ``rng_use`` markers and the consumer
key invars carry ``key``.

**Checks** (conditional on what the plan declares):

  * clip ⇒ every gradient leaf carries ``clip`` and ``seed:weighted``
    and no plain/norms seed — i.e. the only backward that built it was
    the clip-weighted one, which scales per example *before* the batch
    sum by AD linearity; the clip marker's own input must carry
    ``seed:norms`` (coefficients computed from the per-example norms
    backward) and its meta must match the plan's C;
  * noise ⇒ exactly one noise token per leaf, one marker per leaf
    overall, every marker at shard_map depth 0 (outside the region ⇒
    after the gradient psum), meta (σ, scale) equal to the plan's σ
    and sensitivity (C when defaulted);
  * keys: every ``rng_use`` marker's lineage is resolved backwards
    (through splits, folds, slices) to an origin — two markers sharing
    an origin is a key reuse; ``random_bits`` fed by anything not
    key-tainted, or a ``random_seed`` born inside the step, is unkeyed
    randomness (irreproducible under replay).

Trace-only; abstract params/batches/keys all work.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis import _jaxpr as _J
from repro.analysis.findings import ERROR, WARNING, Finding
from repro.core.provenance import (KNOWN_TAGS, MARK_PRIMITIVE, TAG_CLIP,
                                   TAG_GLEAF, TAG_NOISE, TAG_RNG, TAG_SAMPLE,
                                   TAG_SEED, meta_dict)

PASS = "privacy"
_EMPTY = _J.EMPTY

#: taint tokens
T_CLIP = "clip"
T_KEY = "key"


def _seed_tok(kind: str) -> str:
    return f"seed:{kind}"


@dataclasses.dataclass(frozen=True)
class MarkSite:
    """One provenance marker met during the walk."""
    index: int
    tag: str
    meta: dict
    depth: int                      # enclosing shard_map regions
    in_taint: frozenset
    token: Optional[str] = None     # the taint token this site emits


@dataclasses.dataclass(frozen=True)
class LeafLineage:
    """What one gradient leaf's value derives from."""
    path: str
    taint: frozenset

    @property
    def noise_tokens(self) -> Tuple[str, ...]:
        return tuple(sorted(t for t in self.taint if t.startswith("noise:")))


@dataclasses.dataclass(frozen=True)
class PrivacyReport:
    marks: Tuple[MarkSite, ...]
    leaves: Tuple[LeafLineage, ...]
    findings: Tuple[Finding, ...]

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == ERROR)

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        by_tag: Dict[str, int] = {}
        for m in self.marks:
            by_tag[m.tag] = by_tag.get(m.tag, 0) + 1
        head = (f"privacy: {len(self.leaves)} gradient leaves, markers "
                + (", ".join(f"{k}×{v}" for k, v in sorted(by_tag.items()))
                   or "none"))
        return "\n".join([head] + [f"  {f.render()}" for f in self.findings])


# ---------------------------------------------------------------------------
# the walker
# ---------------------------------------------------------------------------

class _PrivacyWalker(_J.Walker):
    """Marker-anchored taint propagation + random-primitive audit."""

    def __init__(self):
        super().__init__()
        self.marks: List[MarkSite] = []
        self.findings: List[Finding] = []
        self._noise_tok: Dict[int, str] = {}   # id(eqn) -> stable token

    def hook(self, eqn, in_t):
        name = eqn.primitive.name
        if name == MARK_PRIMITIVE:
            return self._mark(eqn, in_t)
        if name == "random_bits" and self.recording:
            if not any(T_KEY in t for t in in_t):
                self.findings.append(Finding(
                    PASS, ERROR, "unkeyed-randomness",
                    "a random_bits draw is fed by no consumer-supplied "
                    "PRNG key: its output is not a function of the step's "
                    "declared keys, so the step is not replayable"))
            return None
        if name == "random_seed" and self.recording:
            self.findings.append(Finding(
                PASS, ERROR, "unkeyed-randomness",
                "a PRNG key is created from a raw seed inside the traced "
                "step; keys must enter as consumer arguments "
                "(Noise/Importance rng) so replay and the single-use "
                "check can see their lineage"))
            return None
        return None

    def _mark(self, eqn, in_t):
        tag = eqn.params["tag"]
        meta = meta_dict(eqn.params["meta"])
        t_in = in_t[0]
        if tag == TAG_CLIP:
            token = T_CLIP
            out = frozenset({T_CLIP})
        elif tag == TAG_SEED:
            token = _seed_tok(meta.get("kind", "?"))
            # keep only the clip evidence: a weighted seed built from
            # clip coefficients proves per-example scaling; the rest of
            # its data lineage (norms, batch) is not seed lineage
            out = (t_in & frozenset({T_CLIP})) | {token}
        elif tag == TAG_NOISE:
            token = self._noise_tok.setdefault(
                id(eqn), f"noise:{len(self._noise_tok)}")
            out = frozenset({token})
        elif tag == TAG_RNG:
            token = T_KEY
            out = t_in | {T_KEY}
        elif tag == TAG_GLEAF:
            # the plan/apply boundary marker (traffic pass anchor) —
            # pure identity for privacy lineage: the leaf's taint is
            # whatever the plan built it from
            token = None
            out = t_in
        elif tag == TAG_SAMPLE:
            # selection boundary: which examples were drawn depends on
            # the norms, but a gather does not *scale* anything — seed
            # lineage is laundered so norm-guided sampling is not
            # mistaken for an unclipped contribution
            token = None
            out = _EMPTY
        else:
            token = None
            out = t_in
            if self.recording:
                self.findings.append(Finding(
                    PASS, ERROR, "unknown-marker",
                    f"pex_mark tag {tag!r} is not one of {sorted(KNOWN_TAGS)}"
                    f"; a marker was added without teaching the privacy "
                    f"pass its semantics"))
        if self.recording:
            self.marks.append(MarkSite(len(self.marks), tag, meta,
                                       self.region_depth, t_in, token))
        return [out]


# ---------------------------------------------------------------------------
# rng key lineage — single-use resolution
# ---------------------------------------------------------------------------

#: primitives a key passes through unchanged (lineage-transparent)
_PASSTHROUGH = frozenset({
    MARK_PRIMITIVE, "squeeze", "reshape", "broadcast_in_dim",
    "convert_element_type", "copy", "random_wrap", "random_unwrap",
})
#: primitives that *derive* a fresh key — lineage stops here; distinct
#: outputs of one derivation are told apart by the slice path
_DERIVE = frozenset({"random_split", "random_fold_in", "random_seed",
                     "threefry2x32"})


def _origin(var, producer, path):
    """Resolve a key variable back to (origin, slice-path)."""
    while True:
        eqn = producer.get(var)
        if eqn is None:                      # invar / constvar
            return ("free", id(var)), tuple(path)
        name = eqn.primitive.name
        if name in _PASSTHROUGH:
            var = eqn.invars[0]
            continue
        if name == "slice":
            path.append(("slice", tuple(eqn.params["start_indices"])))
            var = eqn.invars[0]
            continue
        if name == "dynamic_slice":
            starts = tuple(
                v.val.item() if hasattr(v, "val") and hasattr(v.val, "item")
                else None
                for v in eqn.invars[1:])
            if any(s is None for s in starts):
                return ("opaque", id(eqn)), tuple(path)
            path.append(("slice", starts))
            var = eqn.invars[0]
            continue
        if name == "random_fold_in":
            # fold_in with a *literal* datum is a pure function of its
            # input key — treat it like a slice so two separate
            # fold_in(master, 7) calls resolve to the SAME origin (true
            # reuse, detected) while fold_in(master, 7) vs
            # fold_in(master, 8) stay distinct (the per-tenant key
            # derivation the multi-tenant Noise path relies on). A
            # traced datum (e.g. a vmapped tenant-id array) stays an
            # opaque derivation point below.
            datum = eqn.invars[1]
            if hasattr(datum, "val"):
                try:
                    path.append(("fold", int(datum.val)))
                    var = eqn.invars[0]
                    continue
                except (TypeError, ValueError):
                    pass
            return (name, id(eqn)), tuple(path)
        if name in _DERIVE:
            return (name, id(eqn)), tuple(path)
        return ("opaque", id(eqn)), tuple(path)


def _rng_use_origins(jaxpr, out, _depth=0):
    """Collect (origin, purpose-meta) for every rng_use marker, per
    jaxpr level (lineage is resolved within the marker's own jaxpr;
    keys entering a sub-jaxpr stop at its invar — two markers on the
    same sub-jaxpr invar still collide, which is the sound direction)."""
    jaxpr = _J.as_open(jaxpr)
    producer = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            if type(ov).__name__ != "DropVar":
                producer[ov] = eqn
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == MARK_PRIMITIVE and \
                eqn.params["tag"] == TAG_RNG:
            origin = _origin(eqn.invars[0], producer, [])
            out.append((origin, meta_dict(eqn.params["meta"])))
        for sub in _J.sub_jaxprs(eqn.params):
            _rng_use_origins(sub, out, _depth + 1)
    return out


# ---------------------------------------------------------------------------
# the pass
# ---------------------------------------------------------------------------

def analyze_trace(trace: _J.StepTrace) -> PrivacyReport:
    """Run the privacy-flow checks on one ``StepTrace``."""
    plan = trace.plan
    jaxpr = trace.closed.jaxpr
    walker = _PrivacyWalker()
    in_t = [_EMPTY] * len(jaxpr.invars)
    for pos in trace.rng_positions:
        in_t[pos] = frozenset({T_KEY})
    out_t = walker.run(jaxpr, in_t)

    findings = list(walker.findings)
    marks = walker.marks
    clip_marks = [m for m in marks if m.tag == TAG_CLIP]
    noise_marks = [m for m in marks if m.tag == TAG_NOISE]
    rng_marks = [m for m in marks if m.tag == TAG_RNG]

    # -- leaf lineage ------------------------------------------------------
    leaves = [LeafLineage(rest, out_t[i])
              for i, (head, rest) in enumerate(trace.out_labels)
              if head == "grads"]

    # -- clip: per-example scaling before the batch sum --------------------
    if plan.clip is not None:
        gran = plan.clip.granularity
        if not clip_marks:
            findings.append(Finding(
                PASS, ERROR, "clip-missing",
                f"plan declares Clip({plan.clip.clip_norm}) but the trace "
                f"contains no clip_coef marker: no per-example clip "
                f"coefficient was ever computed"))
        for m in clip_marks:
            if m.meta.get("clip_norm") != plan.clip.clip_norm:
                findings.append(Finding(
                    PASS, ERROR, "clip-norm-mismatch",
                    f"clip coefficients use C={m.meta.get('clip_norm')} "
                    f"but the plan declares C={plan.clip.clip_norm}"))
            if m.meta.get("granularity") != gran:
                findings.append(Finding(
                    PASS, ERROR, "clip-granularity-mismatch",
                    f"clip coefficients are "
                    f"{m.meta.get('granularity')}-granular but the plan "
                    f"declares {gran} clipping"))
            if _seed_tok("norms") not in m.in_taint:
                findings.append(Finding(
                    PASS, ERROR, "clip-not-from-norms",
                    "clip coefficients are not derived from the "
                    "norms-seeded backward: min(1, C/‖g‖) must be a "
                    "function of the per-example gradient norms"))
        for lf in leaves:
            # frozen leaf: constant-zero gradient — no backward seed
            # ever reaches it, so its only lineage (if any) is the
            # noise sample added to every leaf (a frozen LoRA base
            # under stop_gradient). Nothing per-example flows into the
            # batch sum through it; skip the clip requirement.
            if not any(t == T_CLIP or t.startswith("seed:")
                       for t in lf.taint):
                continue
            if T_CLIP not in lf.taint or \
                    _seed_tok("weighted") not in lf.taint:
                findings.append(Finding(
                    PASS, ERROR, "unclipped-leaf",
                    "gradient is not scaled by the per-example clip "
                    "coefficient before the batch sum (no clip-weighted "
                    "seed in its lineage) — DP sensitivity is unbounded "
                    "for this leaf", leaf=lf.path))
            stray = {_seed_tok("plain"), _seed_tok("norms")} & lf.taint
            if stray:
                findings.append(Finding(
                    PASS, ERROR, "unclipped-leaf",
                    f"an unweighted backward seed ({', '.join(sorted(stray))}"
                    f") reaches this gradient: some per-example "
                    f"contribution enters the batch sum unclipped",
                    leaf=lf.path))
    elif clip_marks:
        findings.append(Finding(
            PASS, WARNING, "unexpected-clip",
            f"{len(clip_marks)} clip_coef marker(s) in a plan that "
            f"declares no Clip consumer"))

    # -- noise: exactly once, after the psum, at σ·C -----------------------
    want_noise = plan.noise is not None and plan.needs_grads
    if want_noise:
        sens = plan.noise.scale if plan.noise.scale is not None \
            else plan.clip.clip_norm
        if len(noise_marks) != len(leaves):
            findings.append(Finding(
                PASS, ERROR, "noise-count",
                f"{len(noise_marks)} noise marker(s) for {len(leaves)} "
                f"gradient leaves; DP-SGD noises every leaf exactly once"))
        for m in noise_marks:
            if m.depth > 0:
                findings.append(Finding(
                    PASS, ERROR, "noise-before-psum",
                    "noise is injected inside a shard_map region — i.e. "
                    "per shard, BEFORE the cross-device gradient psum: "
                    "summing shard-local noise inflates the variance by "
                    "the shard count and breaks the σ·C calibration"))
            if m.meta.get("noise_std") != plan.noise.noise_std:
                findings.append(Finding(
                    PASS, ERROR, "noise-scale-mismatch",
                    f"noise marker carries σ={m.meta.get('noise_std')} but "
                    f"the plan declares σ={plan.noise.noise_std}"))
            if m.meta.get("scale") != sens:
                findings.append(Finding(
                    PASS, ERROR, "noise-scale-mismatch",
                    f"noise marker carries sensitivity "
                    f"{m.meta.get('scale')} but the plan's sensitivity is "
                    f"{sens} (σ·C calibration)"))
        for lf in leaves:
            n = len(lf.noise_tokens)
            if n == 0 and lf.taint:
                findings.append(Finding(
                    PASS, ERROR, "noise-missing",
                    "no noise sample reaches this gradient leaf",
                    leaf=lf.path))
            elif n > 1:
                findings.append(Finding(
                    PASS, ERROR, "double-noise",
                    f"{n} independent noise samples reach this gradient "
                    f"leaf; noising twice doubles the variance while the "
                    f"accountant assumes σ·C", leaf=lf.path))
    else:
        if noise_marks:
            findings.append(Finding(
                PASS, ERROR, "unexpected-noise",
                f"{len(noise_marks)} noise marker(s) in a plan that "
                f"declares no Noise consumer"))
        for lf in leaves:
            if lf.noise_tokens:
                findings.append(Finding(
                    PASS, ERROR, "unexpected-noise",
                    "a noise sample reaches this gradient leaf but the "
                    "plan declares no Noise consumer", leaf=lf.path))

    # -- keys: single use --------------------------------------------------
    origins: list = []
    _rng_use_origins(jaxpr, origins)
    by_origin: Dict[tuple, list] = {}
    for origin, meta in origins:
        by_origin.setdefault(origin, []).append(meta)
    for origin, metas in by_origin.items():
        if len(metas) > 1:
            uses = ", ".join(
                f"{m.get('purpose')}[{m.get('index')}]" for m in metas)
            findings.append(Finding(
                PASS, ERROR, "key-reuse",
                f"one PRNG key lineage is consumed {len(metas)} times "
                f"({uses}): reusing a key correlates draws that DP "
                f"accounting assumes independent"))
    if want_noise and not any(m.meta.get("purpose") == "noise"
                              for m in rng_marks):
        findings.append(Finding(
            PASS, ERROR, "unkeyed-randomness",
            "the plan declares Noise but no rng_use(purpose=noise) marker "
            "appears: the noise key is consumed outside the audited path"))
    if plan.importance is not None and not any(
            m.meta.get("purpose") == "importance" for m in rng_marks):
        findings.append(Finding(
            PASS, ERROR, "unkeyed-randomness",
            "the plan declares Importance but no "
            "rng_use(purpose=importance) marker appears"))

    return PrivacyReport(tuple(marks), tuple(leaves), tuple(findings))


def check_step(loss_fn, params, batch, consumers, **trace_kw):
    """Convenience: trace ``Engine.step`` and analyze it."""
    return analyze_trace(_J.trace_step(loss_fn, params, batch, consumers,
                                       **trace_kw))
