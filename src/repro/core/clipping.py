"""Clipping — the coefficient math behind the ``Clip`` consumer, plus
the paper's §6 one-pass form kept as the faithful MLP-era oracle.

Production clipping is the ``Clip`` consumer of the plan layer
(``core.plan``): per-example coefficients ``min(1, C/‖g_j‖)`` — or
per-token coefficients from the (B, S) ``TokenLayout`` map — become
cotangent seeds of ONE reweighted backward shared with every other
gradient-demanding consumer (DESIGN.md §9). This module holds the
coefficient helpers that consumer folds into the weight product
(``core.passes.clip_coefficients`` for the example form,
``token_clip_coefficients`` below for the token form).

The rest of the file is paper §6's *one-pass* form: modify Z̄ and
re-run only the last step. This is the faithful rendering of the
paper's extension: after the norms are known, each example's Z̄ rows
are rescaled in place and the final backprop step
W̄⁽ⁱ⁾' = X⁽ⁱ⁾ᵀ Z̄⁽ⁱ⁾'  is recomputed — no second backward pass. It
requires materializing every (H, Z̄) pair, which is exactly what the
paper's MLP setting affords; the production path for deep scanned LMs
is the two-pass plan form (same result, O(batch) memory — see
DESIGN.md §2).

Mechanism: "perturbation taps". The model forward is written as

    forward(params, taps, batch) -> (loss_vec, hs)

where each dense layer computes ``z = h @ W + taps[name]`` with
``taps[name]`` a zeros array of z's shape, and ``hs[name]`` is the
layer input it returns. ``jax.vjp`` w.r.t. ``taps`` then yields the
per-example Z̄ for every layer in one backward pass — the quantity the
paper says "standard backpropagation values allow us to compute".
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.passes import clip_coefficients
from repro.dist.sharding import shard


def token_clip_coefficients(sq_norms: jax.Array, clip_norm: float,
                            eps: float = 1e-6) -> jax.Array:
    """c_{j,t} = min(1, C / ‖g_{j,t}‖) elementwise on the (B, S)
    ``TokenLayout`` norm map — the per-token analogue of
    ``passes.clip_coefficients`` (which sums group columns; the token
    map has none to sum)."""
    from repro.core.provenance import mark_clip
    c = jnp.minimum(1.0, clip_norm /
                    (jnp.sqrt(sq_norms.astype(jnp.float32)) + eps))
    return mark_clip(c, clip_norm=clip_norm, eps=eps, granularity="token")


def zero_taps(shapes: Dict[str, Tuple[int, ...]], dtype=jnp.float32):
    """Zero perturbation taps, batch-sharded under an active mesh: each
    tap (and its cotangent Z̄) leads with the example axis, so the whole
    one-pass pipeline stays shard-local like the accumulator form."""
    return {k: shard(jnp.zeros(s, dtype), "batch", *([None] * (len(s) - 1)))
            for k, s in shapes.items()}


def norms_from_taps(hs: Dict[str, jax.Array],
                    zbars: Dict[str, jax.Array]) -> jax.Array:
    """Paper §4: s_j = Σ_i ||z̄_j⁽ⁱ⁾||²·||h_j⁽ⁱ⁻¹⁾||² (rank-1 / MLP case)."""
    total = None
    for name, zb in zbars.items():
        h = hs[name]
        s = (jnp.sum(jnp.square(zb.astype(jnp.float32)), axis=-1) *
             jnp.sum(jnp.square(h.astype(jnp.float32)), axis=-1))
        while s.ndim > 1:  # fold any extra shared axes (exactness: MLP only)
            s = jnp.sum(s, axis=-1)
        total = s if total is None else total + s
    return total


def norms_from_taps_seq(hs: Dict[str, jax.Array],
                        zbars: Dict[str, jax.Array]) -> jax.Array:
    """Exact per-example norms from (H, Z̄) under sequence weight
    sharing: Σ_i ||H_i^(j)ᵀ Z̄_i^(j)||²_F via the Gram identity — the
    generalization of §4 this framework contributes (DESIGN.md §2)."""
    from repro.core import norms as N
    total = None
    for name, zb in zbars.items():
        s = N.stat_dense(hs[name], zb, method="auto")
        total = s if total is None else total + s
    return total


def onepass_clipped_weight_grads_seq(forward: Callable, params, batch,
                                     tap_shapes: Dict[str, Tuple[int, ...]],
                                     clip_norm: float):
    """§6 one-pass for sequence models: identical flow to the MLP form,
    but norms use the exact Gram estimator and the final step is
    W̄⁽ⁱ⁾' = Σ_t X_tᵀ (c ⊙ Z̄_t). One backward pass; the re-run is only
    the dW einsums (cheaper than the two-pass form, at the cost of
    storing every (H, Z̄) — the memory/compute trade both forms of §6
    offer; core.passes.clipped_value_and_grads is the O(batch)-memory
    alternative)."""
    taps = zero_taps(tap_shapes)

    def f(tp):
        loss_vec, hs = forward(params, tp, batch)
        return jnp.sum(loss_vec), (loss_vec, hs)

    total, vjp, (loss_vec, hs) = jax.vjp(f, taps, has_aux=True)
    (zbars,) = vjp(jnp.ones_like(total))
    sq_norms = norms_from_taps_seq(hs, zbars)
    c = clip_coefficients(sq_norms, clip_norm)

    wbar = {}
    for name, zb in zbars.items():
        zb_scaled = zb * c.reshape((-1,) + (1,) * (zb.ndim - 1))
        wbar[name] = jnp.einsum("b...i,b...o->io", hs[name], zb_scaled)
    return loss_vec, sq_norms, wbar


def onepass_clipped_weight_grads(forward: Callable, params, batch,
                                 tap_shapes: Dict[str, Tuple[int, ...]],
                                 clip_norm: float):
    """Run the full §6 pipeline once.

    Returns (loss_vec, sq_norms, wbar_prime) where ``wbar_prime`` maps
    layer name -> clipped-sum weight gradient  X⁽ⁱ⁾ᵀ (c ⊙ Z̄⁽ⁱ⁾).
    """
    taps = zero_taps(tap_shapes)

    def f(tp):
        loss_vec, hs = forward(params, tp, batch)
        return jnp.sum(loss_vec), (loss_vec, hs)

    total, vjp, (loss_vec, hs) = jax.vjp(f, taps, has_aux=True)
    (zbars,) = vjp(jnp.ones_like(total))
    sq_norms = norms_from_taps(hs, zbars)
    c = clip_coefficients(sq_norms, clip_norm)

    wbar = {}
    for name, zb in zbars.items():
        zb_scaled = zb * c.reshape((-1,) + (1,) * (zb.ndim - 1))
        wbar[name] = jnp.einsum("...i,...o->io", hs[name], zb_scaled)
    return loss_vec, sq_norms, wbar
