"""Composable consumer plans — one fused pass for everything the
norms already pay for (DESIGN.md §9).

The paper's point is that per-example (and per-token) gradient norms
are a cheap *byproduct* of one backward pass. The fixed-function
surface (``value_and_norms`` / ``value_grads_and_norms`` /
``clipped_step`` / ``gradient_noise_scale``) hid that: a run wanting
clipping *and* GNS telemetry paid multiple compiled forwards and
backwards for statistics one pass already produces. This module makes
the consumer side declarative instead:

    res = eng.step(loss_fn, params, batch,
                   consumers=[Clip(1.0), Noise(0.5, rng), GNS()])

``analyze`` folds the consumer list into a ``Plan`` (does anything
need norms? a gradient at all? which weights reweight the backward?),
and ``execute`` compiles it into the minimal program:

  * one tapped forward (``jax.vjp`` — residuals shared by every
    backward application);
  * one activation-only backward seeded with ones when any consumer
    needs norms (the ``dW`` chains are dead code, paper §5);
  * at most one reweighted backward, seeded with the **product** of
    clip coefficients, importance weights, and user loss weights — so
    every gradient-demanding consumer shares it. With no weights the
    norms and gradients fold into a single backward (paper §4/§5);
    with no consumers the taps are never created and the program is
    the plain forward.

Per-example weights enter as the cotangent seed of the (B,) loss
vector. Per-token weights (``Clip(C, granularity="token")``) enter as
the seed of the **per-token loss map** the loss registers via
``tap.token_loss`` — the token-weighted loss reweighting pass: the
resulting gradient is exactly ``Σ_{j,t} w_{j,t} · ∂ℓ_{j,t}/∂θ`` by
linearity, with ``w`` derived from the ``TokenLayout`` (B, S) norm map
(exact for every tap, including MoE expert taps — DESIGN.md §8; the
token granularity follows Rochette et al. 2019's per-example
extensions, the telemetry use case Gray et al. 2024 / PAPERS.md).

``Importance(k, ...)`` splits the plan into norms-on-pool → sample →
gather → the *same* plan continuing on the sub-batch: the gathered
pool norms drive the clip coefficients, so the sub-batch pays no
second norms pass.

The mesh path (``dist.pex.plan_step``) wraps the same fused core in
``shard_map``: weights are shard-local (an example's clip coefficient
depends only on its own norm), gradients cross devices in one psum,
and DP-SGD noise is added once after it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import importance as imp
from repro.core.clipping import token_clip_coefficients
from repro.core.passes import (add_grad_noise, add_grad_noise_segmented,
                               check_noise_args, clip_coefficients)
from repro.core.provenance import mark_grad_tree, mark_seed


# ---------------------------------------------------------------------------
# consumers — the declarative surface
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Norms:
    """Demand the per-example (B, G) — or per-token (B, S) — squared
    norms in the result. Free whenever any other consumer already
    needs them."""


@dataclasses.dataclass(frozen=True)
class Grads:
    """Demand the summed gradient. If the plan carries weights (clip /
    importance / user loss weights) this is the *reweighted* gradient —
    a plan produces exactly one."""


@dataclasses.dataclass(frozen=True)
class Clip:
    """Per-example (or per-token) gradient clipping, two-pass ghost
    form (paper §6): contribute ``min(1, C/‖g‖)`` factors to the
    reweighted backward. ``granularity="token"`` clips every token's
    loss term by its (B, S) contribution norm — the token-weighted
    loss reweighting pass (DESIGN.md §9)."""
    clip_norm: float
    granularity: str = "example"
    eps: float = 1e-6

    def __post_init__(self):
        if self.granularity not in ("example", "token"):
            raise ValueError(f"Clip granularity must be 'example' or "
                             f"'token', got {self.granularity!r}")


@dataclasses.dataclass(frozen=True, eq=False)
class Noise:
    """Gaussian DP-SGD noise σ·scale added once to the summed gradient
    (after the psum on a mesh). ``scale`` defaults to the plan's Clip
    threshold C — standalone Noise needs it explicitly.

    ``segments`` (optional, int (S,) array) switches to *per-segment*
    noise for gradient trees stacked on a leading segment axis — the
    multi-tenant adapter case: row s of every leaf gets an independent
    draw keyed by ``fold_in(rng, segments[s])``, bit-identical to
    noising each tenant's tree alone with its folded key. That makes
    each tenant's DP guarantee independent of co-batched tenants."""
    noise_std: float
    rng: Any = None
    scale: Optional[float] = None
    segments: Any = None


@dataclasses.dataclass(frozen=True, eq=False)
class Importance:
    """Importance-sampled sub-batch (Zhao & Zhang; paper §1): norms on
    the candidate pool, sample ``k`` examples ∝ ‖∇L_j‖, continue the
    plan on the gathered sub-batch with unbiased 1/(k·p_j) weights
    folded into the reweighted backward."""
    k: int
    smoothing: float = 0.1
    rng: Any = None
    replace: bool = True


@dataclasses.dataclass(frozen=True)
class GNS:
    """Gradient-noise-scale telemetry B_simple = tr(Σ)/‖G‖² (Gray et
    al. 2024 / McCandlish et al. 2018) of the gradient estimator the
    plan actually produces: with weights active the per-example norms
    are ``w_j²·s_j`` and G is the reweighted sum."""


_KNOWN = (Norms, Grads, Clip, Noise, Importance, GNS)


# ---------------------------------------------------------------------------
# plan analysis
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """Static description of the fused pass a consumer list compiles
    to. ``token_norms`` selects the (B, S) accumulator for the norms
    backward; ``token_weighted`` seeds the gradient backward through
    the registered per-token loss map."""
    clip: Optional[Clip] = None
    noise: Optional[Noise] = None
    importance: Optional[Importance] = None
    gns: bool = False
    wants_norms: bool = False
    wants_grads: bool = False
    needs_norms: bool = False
    needs_grads: bool = False
    token_norms: bool = False
    token_weighted: bool = False

    @property
    def weighted(self) -> bool:
        """Does any consumer reweight the backward? (User loss_weights
        add to this at execute time.)"""
        return self.clip is not None or self.importance is not None

    @property
    def n_backwards(self) -> int:
        """Backward applications over the fused region's shared
        residuals (without user loss_weights): 0 for the plain
        forward, 1 when norms and the unweighted gradient fold into
        one seed, 2 when a reweighted backward follows the norms pass.
        Never more — the structural claim the one-forward budget
        (``analysis.plan_invariants``) pins on compiled HLO."""
        if not self.needs_norms and not self.needs_grads:
            return 0
        if not self.needs_norms:
            return 1
        if self.needs_grads and (self.weighted or self.token_weighted):
            return 2
        return 1

    def static_cost(self, *, fwd_flops: Optional[float] = None,
                    param_bytes: Optional[float] = None) -> dict:
        """Structural step-budget estimate from the plan shape alone —
        no trace. Flops follow the classic 1-forward/2-backward rule
        per region; gradient HBM reads count the plan-side full-tree
        passes (write + noise add; the optimizer apply adds its own —
        ``analysis.traffic.expected_streams`` has the full count).
        The traced numbers live on ``analysis.cost.CostReport``; this
        is the zero-trace approximation ``describe()`` renders."""
        regions = 1 if self.importance is None else 2
        grad_reads = int(self.needs_grads) * (
            1 + (1 if self.noise is not None else 0))
        out = {"regions": regions, "backwards": self.n_backwards,
               "grad_stream_reads": grad_reads}
        if fwd_flops is not None:
            out["flops_est"] = float(fwd_flops) * (
                regions + 2.0 * self.n_backwards)
        if param_bytes is not None:
            out["grad_bytes_est"] = float(param_bytes) * (1 + grad_reads)
        return out

    def describe(self, *, fwd_flops: Optional[float] = None,
                 param_bytes: Optional[float] = None) -> str:
        """One-line static cost shape of the pass this plan compiles
        to — consumed by ``Engine.verify`` and the pexlint CLI. With
        ``fwd_flops``/``param_bytes`` the line carries the
        ``static_cost`` flop/byte estimates too."""
        regions = 1 if self.importance is None else 2
        parts = [f"regions={regions}", f"backwards={self.n_backwards}",
                 "acc=(B,S)" if self.token_norms else
                 ("acc=(B,G)" if self.needs_norms else "acc=none")]
        if self.clip is not None:
            parts.append(f"clip[{self.clip.granularity}]")
        if self.noise is not None:
            parts.append("noise")
        if self.gns:
            parts.append("gns")
        if self.importance is not None:
            parts.append(f"importance(k={self.importance.k})")
        est = self.static_cost(fwd_flops=fwd_flops,
                               param_bytes=param_bytes)
        if "flops_est" in est:
            parts.append(f"flops≈{est['flops_est']:.3g}")
        if "grad_bytes_est" in est:
            parts.append(f"grad_bytes≈{est['grad_bytes_est']:.3g}")
        return " ".join(parts)


def analyze(consumers: Sequence, *,
            engine_granularity: str = "example") -> Plan:
    """Fold a consumer list into a validated Plan."""
    seen = {}
    for c in consumers:
        if not isinstance(c, _KNOWN):
            raise TypeError(
                f"unknown consumer {c!r}; expected instances of "
                f"{', '.join(k.__name__ for k in _KNOWN)}")
        if type(c) in seen:
            raise ValueError(f"duplicate consumer {type(c).__name__}; a "
                             f"plan carries at most one of each kind")
        seen[type(c)] = c

    clip: Optional[Clip] = seen.get(Clip)
    noise: Optional[Noise] = seen.get(Noise)
    importance: Optional[Importance] = seen.get(Importance)
    gns = GNS in seen

    token_norms = engine_granularity == "token" or (
        clip is not None and clip.granularity == "token")
    token_weighted = clip is not None and clip.granularity == "token"
    if clip is not None and clip.granularity == "example" \
            and engine_granularity == "token":
        raise ValueError(
            "Clip(granularity='example') needs per-example norms, but the "
            "engine runs at token granularity; use Clip(C, "
            "granularity='token') or an example-granularity engine")
    if token_norms and gns:
        raise NotImplementedError(
            "GNS needs per-example ‖g_j‖²; the (B, S) token map does not "
            "sum to them (cross-token terms) — run GNS at example "
            "granularity")
    if token_norms and importance is not None:
        raise NotImplementedError(
            "Importance samples examples from per-example norms; it does "
            "not compose with token-granularity norms in one plan — run "
            "the token pass on the selected sub-batch instead")
    if noise is not None:
        check_noise_args(noise.noise_std, noise.rng)
        if noise.scale is None and clip is None:
            raise ValueError(
                "Noise without Clip needs an explicit sensitivity: pass "
                "Noise(σ, rng, scale=...) — σ·scale is the noise stddev")
        if noise.scale is None and token_weighted:
            raise ValueError(
                "Noise cannot default its sensitivity to a token-"
                "granularity Clip's C: per-token clipping bounds each of "
                "the S token terms by C, so an example's total "
                "contribution is bounded by S·C, not C — pass "
                "Noise(σ, rng, scale=...) with the sensitivity your "
                "accounting assumes")

    if importance is not None and importance.rng is None:
        raise ValueError(
            "Importance needs an rng key: pass Importance(k, rng=...) — "
            "or run through the Trainer, which injects step keys into "
            "rng=None consumers")

    needs_grads = (Grads in seen or clip is not None or noise is not None
                   or gns)
    needs_norms = (Norms in seen or clip is not None or gns
                   or importance is not None)
    return Plan(clip=clip, noise=noise, importance=importance, gns=gns,
                wants_norms=Norms in seen, wants_grads=Grads in seen,
                needs_norms=needs_norms, needs_grads=needs_grads,
                token_norms=token_norms, token_weighted=token_weighted)


class StepResult(NamedTuple):
    """Everything a fused plan produced. Fields a plan did not demand
    are None — ``grads`` when no consumer needed a gradient,
    ``sq_norms`` when none needed norms, etc."""
    loss: jax.Array                 # Σ_j loss_vec (the full input batch)
    loss_vec: jax.Array             # (B,) per-example losses
    aux: Any = None
    sq_norms: Optional[jax.Array] = None    # (B, G) or (B, S)
    grads: Any = None
    weights: Optional[jax.Array] = None     # per-example seed actually used
    token_weights: Optional[jax.Array] = None   # (B, S) token seed
    clip_coef: Optional[jax.Array] = None   # (B,) or (B, S)
    gns: Optional[jax.Array] = None
    sample: Optional[imp.ImportanceSample] = None
    sub_sq_norms: Optional[jax.Array] = None  # pool norms on the sub-batch


# ---------------------------------------------------------------------------
# the fused single-region core
# ---------------------------------------------------------------------------

def _vjp(acc_loss: Callable, params, batch, acc0, token_weighted: bool):
    """One tapped forward via ``jax.vjp``; the returned vjp_fn is
    applied up to twice (norms seed / weight seed) over the shared
    residuals, each application DCE'd to its live outputs."""
    if token_weighted:
        def f(p, acc):
            lv, tok, acc_out, aux = acc_loss(p, acc, batch)
            if tok is None:
                raise ValueError(
                    "per-token reweighting needs the per-token loss map: "
                    "the loss function never called tap.token_loss(...) "
                    "on its (B, S) token losses")
            return (lv, tok), (acc_out, aux)

        (lv, tok), vjp_fn, (_, aux) = jax.vjp(f, params, acc0, has_aux=True)
        return lv, tok, aux, vjp_fn

    def f(p, acc):
        lv, _tok, acc_out, aux = acc_loss(p, acc, batch)
        return lv, (acc_out, aux)

    lv, vjp_fn, (_, aux) = jax.vjp(f, params, acc0, has_aux=True)
    return lv, None, aux, vjp_fn


def run_fused(plan: Plan, acc_loss: Callable, params, batch,
              batch_size: int, layout, *, loss_weights=None):
    """Execute the single-region part of a plan (no importance split,
    no noise, no GNS — drivers add those): one forward, ≤ 2 backward
    applications. Returns ``(loss_vec, aux, sq_norms, grads, weights,
    token_weights, clip_coef)`` with undemanded entries None.

    Usable directly inside a ``shard_map`` body (``dist.pex``) — all
    per-example quantities are computed shard-locally.
    """
    if not plan.needs_norms and not plan.needs_grads:
        # plain forward: the tap is never live, the program is the model
        lv, _, _, aux = acc_loss(params, None, batch)
        return lv, aux, None, None, None, None, None

    if not plan.needs_norms:
        # gradient pass only (possibly user-weighted): no instrumentation
        def f(p):
            lv, _tok, _acc, aux = acc_loss(p, None, batch)
            return lv, aux

        lv, vjp_fn, aux = jax.vjp(f, params, has_aux=True)
        seed = mark_seed(loss_weights.astype(lv.dtype), kind="weighted") \
            if loss_weights is not None \
            else mark_seed(jnp.ones_like(lv), kind="plain")
        (grads,) = vjp_fn(seed)
        return (lv, aux, None, grads, loss_weights, None, None)

    acc0 = layout.init(batch_size)
    lv, tok, aux, vjp_fn = _vjp(acc_loss, params, batch, acc0,
                                plan.token_weighted)
    if plan.token_weighted and tok.shape[:2] != (lv.shape[0], layout.seq):
        raise ValueError(
            f"the registered per-token loss map has shape {tok.shape}, "
            f"which does not lead with (B, S)=({lv.shape[0]}, "
            f"{layout.seq}) of the TokenLayout accumulator")

    ones = jnp.ones_like(lv)

    def seeds(lv_seed, tok_seed=None):
        if not plan.token_weighted:
            return (lv_seed,)
        return ((lv_seed, tok_seed if tok_seed is not None
                 else jnp.zeros_like(tok)),)

    grads = None
    if plan.needs_grads and not plan.weighted and loss_weights is None:
        # norms and gradients fold into ONE backward (paper §4/§5)
        grads, sq = vjp_fn(*seeds(mark_seed(ones, kind="plain")))
    else:
        # dW chains dead → DCE
        _, sq = vjp_fn(*seeds(mark_seed(ones, kind="norms")))

    w, tw, cc = _compose_weights(plan, sq, loss_weights)
    if plan.needs_grads and grads is None:
        if tw is not None:
            tok_seed = tw if w is None else tw * w[:, None]
            grads, _ = vjp_fn((jnp.zeros_like(lv),
                               mark_seed(tok_seed.astype(tok.dtype),
                                         kind="weighted")))
        else:
            seed = mark_seed(ones, kind="plain") if w is None \
                else mark_seed(w.astype(lv.dtype), kind="weighted")
            grads, _ = vjp_fn(*seeds(seed))
    return lv, aux, sq, grads, w, tw, cc


def _compose_weights(plan: Plan, sq_norms, loss_weights,
                     extra_weights=None):
    """Product of clip coefficients × importance weights × user loss
    weights. Returns (per-example w | None, token w | None,
    clip_coef | None)."""
    w = None

    def mul(a, b):
        return b if a is None else a * b

    if loss_weights is not None:
        w = mul(w, loss_weights)
    if extra_weights is not None:
        w = mul(w, extra_weights)
    cc = tw = None
    if plan.clip is not None:
        if plan.clip.granularity == "token":
            cc = tw = token_clip_coefficients(sq_norms, plan.clip.clip_norm,
                                              plan.clip.eps)
        else:
            cc = clip_coefficients(sq_norms, plan.clip.clip_norm,
                                   plan.clip.eps)
            w = mul(w, cc)
    return w, tw, cc


# ---------------------------------------------------------------------------
# the driver: importance split, noise, GNS
# ---------------------------------------------------------------------------

#: a fused_fn: (sub-plan, batch, batch_size, loss_weights) -> the
#: run_fused tuple. ``execute`` defaults to the local one; dist.pex
#: passes a shard_map-wrapping one so the same driver serves the mesh.
FusedFn = Callable[[Plan, Any, int, Optional[jax.Array]], Tuple]

_NORMS_ONLY = Plan(needs_norms=True, wants_norms=True)
_GRADS_ONLY = Plan(needs_grads=True, wants_grads=True)


def execute(plan: Plan, acc_loss: Callable, params, batch,
            batch_size: int, layout, *, loss_weights=None,
            fused_fn: Optional[FusedFn] = None) -> StepResult:
    """Run a full plan: the fused region(s), then noise and GNS."""
    if fused_fn is None:
        def fused_fn(sub, b, bs, lw):
            return run_fused(sub, acc_loss, params, b, bs, layout,
                             loss_weights=lw)

    samp = sub_sq = None
    if plan.importance is None:
        lv, aux, sq, grads, w, tw, cc = fused_fn(plan, batch, batch_size,
                                                 loss_weights)
    else:
        ip = plan.importance
        lv, aux, sq, _, _, _, _ = fused_fn(_NORMS_ONLY, batch, batch_size,
                                           None)
        samp = imp.sample(ip.rng, sq, ip.k, smoothing=ip.smoothing,
                          replace=ip.replace)
        sub_batch = imp.gather_batch(batch, samp.indices,
                                     batch_size=batch_size)
        sub_sq = jnp.take(sq, samp.indices, axis=0)
        w, tw, cc = _compose_weights(
            plan, sub_sq,
            None if loss_weights is None
            else jnp.take(loss_weights, samp.indices, axis=0),
            extra_weights=samp.weights)
        grads = None
        if plan.needs_grads:
            _, _, _, grads, w, _, _ = fused_fn(_GRADS_ONLY, sub_batch,
                                               ip.k, w)

    gns = None
    if plan.gns:
        gns = gradient_noise_scale(
            sq if sub_sq is None else sub_sq, grads,
            batch_size=batch_size if samp is None else plan.importance.k,
            weights=w)
    if grads is not None:
        # the plan/apply boundary: everything downstream (noise add,
        # optimizer moments) re-reads the summed gradient from HBM —
        # the traffic pass counts those passes per leaf off these
        # identity markers (they vanish in lowering)
        grads = mark_grad_tree(grads)
    if plan.noise is not None and grads is not None:
        scale = plan.noise.scale if plan.noise.scale is not None \
            else plan.clip.clip_norm
        if plan.noise.segments is not None:
            grads = add_grad_noise_segmented(grads, plan.noise.noise_std,
                                             scale, plan.noise.rng,
                                             plan.noise.segments)
        else:
            grads = add_grad_noise(grads, plan.noise.noise_std, scale,
                                   plan.noise.rng)
    return StepResult(jnp.sum(lv), lv, aux, sq, grads, w, tw, cc, gns,
                      samp, sub_sq)


# ---------------------------------------------------------------------------
# GNS — a thin consumer over quantities the plan already has
# ---------------------------------------------------------------------------

def gradient_noise_scale(sq_norms: jax.Array, grads,
                         batch_size: Optional[int] = None,
                         weights: Optional[jax.Array] = None) -> jax.Array:
    """Critical-batch diagnostic B_simple = tr(Σ) / ‖G‖² from the
    per-example squared norms the pipeline already computes.

    With s̄ = mean_j ‖g_j‖² and the batch gradient G_B (= mean of the
    per-example gradients): E[s̄] = tr(Σ) + ‖G‖² and
    E[‖G_B‖²] = ‖G‖² + tr(Σ)/B, so both moments are recovered
    unbiasedly from one step — the large-batch monitoring quantity of
    Gray et al. (2024) / McCandlish et al. (2018). ``grads`` is the
    *summed* gradient pytree; pass ``batch_size`` when it differs from
    ``len(sq_norms)``. ``weights`` (per-example reweighting active in
    the plan) scales the norms by w² so the estimate describes the
    reweighted estimator.
    """
    if sq_norms.ndim == 2:
        sq_norms = jnp.sum(sq_norms, axis=-1)
    if weights is not None:
        sq_norms = sq_norms * jnp.square(weights.astype(jnp.float32))
    b = batch_size if batch_size is not None else sq_norms.shape[0]
    if b < 2:
        raise ValueError(f"gradient_noise_scale needs batch >= 2 to "
                         f"separate the two moments (got {b})")
    s_bar = jnp.mean(sq_norms.astype(jnp.float32))
    g_mean_sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree_util.tree_leaves(grads)) / (b * b)
    tr_sigma = (s_bar - g_mean_sq) * b / (b - 1)
    norm_g_sq = (b * g_mean_sq - s_bar) / (b - 1)
    return tr_sigma / jnp.maximum(norm_g_sq, 1e-20)
