"""Cotangent-accumulator taps — the paper's mechanism, JAX-native.

The paper observes that backprop already computes, for every dense
layer, the pair ``(H, Z̄)`` from which per-example gradient norms follow
for free. JAX (like the frameworks the paper complains about) does not
expose ``Z̄``, so each instrumented op here is a ``jax.custom_vjp``
whose backward pass computes the standard cotangents *and* adds the
layer's per-example stat to the cotangent of a ``(batch, n_groups)``
accumulator threaded through the forward pass:

    z, acc = pex.dense(h, w, acc, spec=spec, group="mlp")

``jax.grad`` w.r.t. the initial accumulator then recovers
``Σ_i s⁽ⁱ⁾`` in the same single backward pass that produces the
parameter gradients (paper §4–§5). The accumulator is ``(B, G)`` and
lives on the data axis, so the technique adds no collective traffic.

Key properties:
  * works under ``jit``, ``lax.scan`` (acc in the carry), ``jax.checkpoint``
    (remat), ``vmap`` and ``pjit`` — it is just a custom_vjp op;
  * when gradients w.r.t. the accumulator are *not* requested, the stat
    computation is dead code and is removed by jaxpr/XLA DCE — the
    instrumented model costs the same as the plain one;
  * when only norms are requested (importance sampling), the ``dW``
    chains are dead code instead — the pass costs forward +
    activation-backprop + O(mnp), as in paper §5.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms as N
from repro.dist.sharding import shard as _shard

_ACC_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class PexSpec:
    """Static instrumentation policy (hashable; safe to close over in jit).

    enabled:     master switch. Off ⇒ every op is its plain counterpart.
    method:      'auto' | 'gram' | 'direct' | 'factorized' for dense taps.
                 'factorized' applies the paper's formula mechanically to
                 flattened (S·p) rows — exact only when S==1 (kept as the
                 paper-faithful baseline mode; see DESIGN.md §2).
    use_pallas:  route dense stats through the Pallas kernels — the
                 triangular tile-pair gram kernel or the blocked HᵀZ̄
                 direct kernel, whichever the backend-aware cost model
                 picks (``method='auto'`` covers both regimes).
    groups:      acc column names; per-group norms (e.g. attn/mlp/embed).
    tap_embeddings / tap_head: include embedding / lm-head params in the
                 norm (exact but vocab-sized work; cf. DESIGN.md §5).
    """
    enabled: bool = True
    method: str = "auto"
    use_pallas: bool = False
    groups: Tuple[str, ...] = ("all",)
    tap_embeddings: bool = True
    tap_head: bool = True

    def group_index(self, group: Optional[str]) -> int:
        if group is None or group not in self.groups:
            return 0
        return self.groups.index(group)

    @property
    def n_groups(self) -> int:
        return len(self.groups)


DISABLED = PexSpec(enabled=False)


def init_acc(batch: int, spec: PexSpec) -> jax.Array:
    """Fresh accumulator for one instrumented forward pass.

    Constrained to the batch axis under an active mesh (dist.sharding
    rules): the accumulator — and hence its cotangent, the (B, G) norm
    vector — lives wherever the examples live, keeping the technique
    collective-free under data parallelism."""
    return _shard(jnp.zeros((batch, spec.n_groups), _ACC_DTYPE),
                  "batch", None)


def _int_zero_cotangent(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# dense: z = h @ w        (the paper's layer; h (B,[S,]p_in), w (p_in,p_out))
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _pex_dense(method: str, use_pallas: bool, group: int,
               h: jax.Array, w: jax.Array, acc: jax.Array):
    return jnp.einsum("...i,io->...o", h, w), acc


def _pex_dense_fwd(method, use_pallas, group, h, w, acc):
    z = jnp.einsum("...i,io->...o", h, w)
    return (z, acc), (h, w)


def _pex_dense_bwd(method, use_pallas, group, res, cts):
    h, w = res
    zbar, acc_bar = cts
    dh = jnp.einsum("...o,io->...i", zbar, w).astype(h.dtype)
    dw = jnp.einsum("...i,...o->io", h, zbar).astype(w.dtype)
    stat = N.stat_dense(h, zbar, method=method, use_pallas=use_pallas)
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return dh, dw, dacc


_pex_dense.defvjp(_pex_dense_fwd, _pex_dense_bwd)


def dense(h: jax.Array, w: jax.Array, acc: jax.Array, *,
          spec: PexSpec, group: str = "all",
          method: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """Instrumented matmul. Plain einsum when spec.enabled is False."""
    if not spec.enabled:
        return jnp.einsum("...i,io->...o", h, w), acc
    m = method or spec.method
    return _pex_dense(m, spec.use_pallas, spec.group_index(group), h, w, acc)


# ---------------------------------------------------------------------------
# dense_expert: z = einsum('ecd,edf->ecf')  (MoE expert matmuls; rows of the
#   (E, C) capacity buffer belong to arbitrary examples, so stats use the
#   segmented-direct estimator with per-row example ids)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pex_dense_expert(group: int, n_examples: int,
                      x: jax.Array, w: jax.Array, seg: jax.Array,
                      acc: jax.Array):
    return jnp.einsum("ecd,edf->ecf", x, w), acc


def _pex_dense_expert_fwd(group, n_examples, x, w, seg, acc):
    return (jnp.einsum("ecd,edf->ecf", x, w), acc), (x, w, seg)


def _pex_dense_expert_bwd(group, n_examples, res, cts):
    x, w, seg = res
    zbar, acc_bar = cts
    dx = jnp.einsum("ecf,edf->ecd", zbar, w).astype(x.dtype)
    dw = jnp.einsum("ecd,ecf->edf", x, zbar).astype(w.dtype)
    e, c, d = x.shape
    # per-(expert, example) segments: example j's gradient for expert e is
    # a separate d×f block of the stacked weight — cross-expert outer
    # products must NOT merge before squaring
    composite = (jnp.arange(e, dtype=seg.dtype)[:, None] * (n_examples + 1)
                 + jnp.minimum(seg, n_examples))
    stat_ec = N.stat_direct_segmented(
        x.reshape(e * c, d), zbar.reshape(e * c, -1),
        composite.reshape(e * c), e * (n_examples + 1))
    stat = stat_ec.reshape(e, n_examples + 1)[:, :n_examples].sum(axis=0)
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return dx, dw, _int_zero_cotangent(seg), dacc


_pex_dense_expert.defvjp(_pex_dense_expert_fwd, _pex_dense_expert_bwd)


def dense_expert(x: jax.Array, w: jax.Array, seg: jax.Array, acc: jax.Array,
                 *, spec: PexSpec, group: str = "moe"):
    """Instrumented per-expert matmul. x (E,C,d), w (E,d,f), seg (E,C) int
    example ids (>= batch ⇒ padding row, excluded from stats)."""
    if not spec.enabled:
        return jnp.einsum("ecd,edf->ecf", x, w), acc
    return _pex_dense_expert(spec.group_index(group), acc.shape[0],
                             x, w, seg, acc)


# ---------------------------------------------------------------------------
# dense_expert_grouped: z = einsum('gecd,edf->gecf') — grouped local MoE
#   dispatch (groups aligned with data shards). seg holds GROUP-LOCAL
#   example ids, so the stat segment-sums stay device-local; group g's
#   stats land at acc rows [g·bg, (g+1)·bg).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pex_dense_expert_grouped(group: int, bg: int,
                              x: jax.Array, w: jax.Array, seg: jax.Array,
                              acc: jax.Array):
    return jnp.einsum("gecd,edf->gecf", x, w), acc


def _pex_dense_expert_grouped_fwd(group, bg, x, w, seg, acc):
    return (jnp.einsum("gecd,edf->gecf", x, w), acc), (x, w, seg)


def _pex_dense_expert_grouped_bwd(group, bg, res, cts):
    x, w, seg = res
    zbar, acc_bar = cts
    dx = jnp.einsum("gecf,edf->gecd", zbar, w).astype(x.dtype)
    dw = jnp.einsum("gecd,gecf->edf", x, zbar).astype(w.dtype)
    ng, e, c, d = x.shape
    f = zbar.shape[-1]

    def one_group(xg, zg, sg):
        composite = (jnp.arange(e, dtype=sg.dtype)[:, None] * (bg + 1)
                     + jnp.minimum(sg, bg))
        stat_ec = N.stat_direct_segmented(
            xg.reshape(e * c, d), zg.reshape(e * c, f),
            composite.reshape(e * c), e * (bg + 1))
        return stat_ec.reshape(e, bg + 1)[:, :bg].sum(axis=0)  # (bg,)

    stat = jax.vmap(one_group)(x, zbar, seg).reshape(ng * bg)
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return dx, dw, _int_zero_cotangent(seg), dacc


_pex_dense_expert_grouped.defvjp(_pex_dense_expert_grouped_fwd,
                                 _pex_dense_expert_grouped_bwd)


def dense_expert_grouped(x: jax.Array, w: jax.Array, seg: jax.Array,
                         acc: jax.Array, bg: int, *, spec: PexSpec,
                         group: str = "moe"):
    """Grouped instrumented expert matmul. x (G,E,C,d), w (E,d,f),
    seg (G,E,C) group-local example ids (>= bg ⇒ padding row)."""
    if not spec.enabled:
        return jnp.einsum("gecd,edf->gecf", x, w), acc
    return _pex_dense_expert_grouped(spec.group_index(group), bg,
                                     x, w, seg, acc)


# ---------------------------------------------------------------------------
# bias_add: z = x + b      (paper folds b into W as a ones-column; same math)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pex_bias(group: int, x: jax.Array, b: jax.Array, acc: jax.Array):
    return x + b, acc


def _pex_bias_fwd(group, x, b, acc):
    return (x + b, acc), None


def _pex_bias_bwd(group, _, cts):
    zbar, acc_bar = cts
    reduce_axes = tuple(range(zbar.ndim - 1))
    db = jnp.sum(zbar, axis=reduce_axes).astype(zbar.dtype)
    stat = N.stat_bias(zbar)
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return zbar, db, dacc


_pex_bias.defvjp(_pex_bias_fwd, _pex_bias_bwd)


def bias_add(x, b, acc, *, spec: PexSpec, group: str = "all"):
    if not spec.enabled:
        return x + b, acc
    return _pex_bias(spec.group_index(group), x, b, acc)


# ---------------------------------------------------------------------------
# scale: z = g ⊙ h         (elementwise params: RMSNorm gains, decays, ...)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pex_scale(group: int, h: jax.Array, g: jax.Array, acc: jax.Array):
    return h * g, acc


def _pex_scale_fwd(group, h, g, acc):
    return (h * g, acc), (h, g)


def _pex_scale_bwd(group, res, cts):
    h, g = res
    zbar, acc_bar = cts
    dh = (zbar * g).astype(h.dtype)
    reduce_axes = tuple(range(zbar.ndim - 1))
    dg = jnp.sum(zbar * h, axis=reduce_axes).astype(g.dtype)
    stat = N.stat_elementwise(h, zbar)
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return dh, dg, dacc


_pex_scale.defvjp(_pex_scale_fwd, _pex_scale_bwd)


def scale(h, g, acc, *, spec: PexSpec, group: str = "all"):
    if not spec.enabled:
        return h * g, acc
    return _pex_scale(spec.group_index(group), h, g, acc)


# ---------------------------------------------------------------------------
# embedding: z = table[ids]   (one-hot H ⇒ Gram is an equality matrix;
#                              exact via sort + segment-sum, O(S·d))
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pex_embed(group: int, table: jax.Array, ids: jax.Array, acc: jax.Array):
    return jnp.take(table, ids, axis=0), acc


def _pex_embed_fwd(group, table, ids, acc):
    # `table` rides along only as a shape/dtype reference for the scatter;
    # it is a live parameter anyway, so this costs no extra memory.
    return (jnp.take(table, ids, axis=0), acc), (ids, table)


def _pex_embed_bwd(group, res, cts):
    ids, table = res
    zbar, acc_bar = cts
    flat_ids = ids.reshape(-1)
    flat_z = zbar.reshape(-1, zbar.shape[-1])
    dtable = jnp.zeros_like(table).at[flat_ids].add(flat_z.astype(table.dtype))
    stat = N.stat_embedding(ids.reshape(ids.shape[0], -1),
                            zbar.reshape(zbar.shape[0], -1, zbar.shape[-1]))
    dacc = acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))
    return dtable, _int_zero_cotangent(ids), dacc


_pex_embed.defvjp(_pex_embed_fwd, _pex_embed_bwd)


def embedding(table, ids, acc, *, spec: PexSpec, group: str = "embed"):
    if not (spec.enabled and spec.tap_embeddings):
        return jnp.take(table, ids, axis=0), acc
    return _pex_embed(spec.group_index(group), table, ids, acc)
