"""Cotangent-accumulator taps — the paper's mechanism, JAX-native.

The paper observes that backprop already computes, for every dense
layer, the pair ``(H, Z̄)`` from which per-example gradient norms follow
for free. JAX (like the frameworks the paper complains about) does not
expose ``Z̄``, so each instrumented op here is a ``jax.custom_vjp``
whose backward pass computes the standard cotangents *and* adds the
layer's per-example stat to the cotangent of an accumulator threaded
through the forward pass.

pex v2 (DESIGN.md §7): the accumulator is owned by a trace-time ``Tap``
collector created once per traced function (by ``core.engine.Engine``
or directly) and handed to the model — layers call

    z = tap.dense(h, w, group="mlp")

and never see the accumulator. ``jax.grad`` w.r.t. the tap's initial
accumulator then recovers ``Σ_i s⁽ⁱ⁾`` in the same single backward
pass that produces the parameter gradients (paper §4–§5).

How each op's stat lands in the accumulator is pluggable via the tap's
**layout**:

  * ``ExampleLayout(n_groups)`` — a ``(B, G)`` accumulator; each op's
    per-example stat is scattered into its group's column (v1
    semantics, the paper's per-example norms);
  * ``TokenLayout(seq)`` — a ``(B, S)`` accumulator; the paper's §4
    factorization applied at token granularity, where it is exact for
    *every* dense/bias/scale/embedding op (token t's contribution to
    ``∂L/∂W`` is the rank-1 outer product ``h_t z̄_tᵀ``), replacing the
    old parallel ``core.token_norms`` stack.

Key properties (unchanged from v1):
  * works under ``jit``, ``lax.scan`` (via ``pex.scan`` / the tap
    ``carry()`` contract), ``jax.checkpoint`` (via ``pex.checkpoint``),
    ``vmap`` and ``pjit`` — each op is just a custom_vjp;
  * when gradients w.r.t. the accumulator are *not* requested, the stat
    computation is dead code and is removed by jaxpr/XLA DCE — the
    instrumented model costs the same as the plain one (asserted in
    tests/test_dce.py);
  * when only norms are requested (importance sampling), the ``dW``
    chains are dead code instead — the pass costs forward +
    activation-backprop + O(mnp), as in paper §5.

The v1 explicit-accumulator functions (``dense(h, w, acc, *, spec)``
etc.) and their one-release deprecation shims are gone; all code goes
through ``Tap`` / ``repro.pex``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import norms as N
from repro.dist.sharding import shard as _shard

_ACC_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class PexSpec:
    """Static instrumentation policy (hashable; safe to close over in jit).

    enabled:     master switch. Off ⇒ every op is its plain counterpart.
    method:      'auto' | 'gram' | 'direct' | 'factorized' for dense taps.
                 'factorized' applies the paper's formula mechanically to
                 flattened (S·p) rows — exact only when S==1 (kept as the
                 paper-faithful baseline mode; see DESIGN.md §2).
    use_pallas:  route dense stats through the Pallas kernels — the
                 triangular tile-pair gram kernel or the blocked HᵀZ̄
                 direct kernel, whichever the backend-aware cost model
                 picks (``method='auto'`` covers both regimes). Expert
                 taps consult the same flag: their segmented-direct stat
                 runs the sort-based Pallas kernel when the segmented
                 cost model favours it.
    seg_method:  'auto' | 'xla' | 'pallas' for the MoE expert taps'
                 segmented-direct stat. 'auto' (default) defers to the
                 two-sided segmented cost model under ``use_pallas``;
                 the explicit values pin a backend (regression tests).
    groups:      acc column names; per-group norms (e.g. attn/mlp/embed).
                 ``"all"`` / ``"other"`` act as catch-all columns; an op
                 tapping a group not in ``groups`` (and with no catch-all
                 present) raises at trace time — a typo'd group name must
                 not silently corrupt another column's stats.
    tap_embeddings / tap_head: include embedding / lm-head params in the
                 norm (exact but vocab-sized work; cf. DESIGN.md §5).
    """
    enabled: bool = True
    method: str = "auto"
    use_pallas: bool = False
    seg_method: str = "auto"
    groups: Tuple[str, ...] = ("all",)
    tap_embeddings: bool = True
    tap_head: bool = True

    def __post_init__(self):
        """Group patterns resolve by first match, so a duplicate or a
        shadowed catch-all would silently merge two groups' stats into
        one column — reject at construction, naming the conflict."""
        seen = {}
        dups = []
        for i, g in enumerate(self.groups):
            if g in seen:
                dups.append(f"{g!r} (columns {seen[g]} and {i})")
            else:
                seen[g] = i
        if dups:
            raise ValueError(
                f"duplicate pex group pattern(s): {', '.join(dups)}; "
                f"each entry of groups={self.groups} must name a distinct "
                f"accumulator column — stats for a repeated name would all "
                f"land in the first occurrence")
        catch_alls = [g for g in ("all", "other") if g in seen]
        if len(catch_alls) > 1:
            raise ValueError(
                f"shadowing catch-all group patterns {catch_alls} in "
                f"groups={self.groups}: 'all' always wins the catch-all "
                f"lookup, so the 'other' column could never receive a "
                f"stat — keep exactly one catch-all")

    def group_index(self, group: Optional[str]) -> int:
        if group is None:
            return 0
        if group in self.groups:
            return self.groups.index(group)
        for catch_all in ("all", "other"):
            if catch_all in self.groups:
                return self.groups.index(catch_all)
        raise ValueError(
            f"unknown pex group {group!r}: spec.groups={self.groups} has "
            f"no catch-all column ('all' or 'other'); add {group!r} to "
            f"groups or include a catch-all")

    @property
    def n_groups(self) -> int:
        return len(self.groups)


DISABLED = PexSpec(enabled=False)


# ---------------------------------------------------------------------------
# accumulator layouts — where a tap's stat lands
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExampleLayout:
    """(B, n_groups) accumulator: per-example, per-group squared norms
    (the paper's object). Dense stats go through the estimator zoo
    (core.norms) and scatter into the op's group column."""
    n_groups: int = 1

    def init(self, batch: int) -> jax.Array:
        """Fresh accumulator, constrained to the batch axis under an
        active mesh (dist.sharding rules) so the (B, G) norm vector
        lives wherever the examples live — collective-free under DP."""
        return _shard(jnp.zeros((batch, self.n_groups), _ACC_DTYPE),
                      "batch", None)

    def add_dense(self, acc_bar, h, zbar, group, method, use_pallas):
        stat = N.stat_dense(h, zbar, method=method, use_pallas=use_pallas)
        return acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))

    def add_bias(self, acc_bar, zbar, group):
        return acc_bar.at[:, group].add(
            N.stat_bias(zbar).astype(acc_bar.dtype))

    def add_scale(self, acc_bar, h, zbar, group):
        return acc_bar.at[:, group].add(
            N.stat_elementwise(h, zbar).astype(acc_bar.dtype))

    def add_embedding(self, acc_bar, ids, zbar, group):
        stat = N.stat_embedding(ids.reshape(ids.shape[0], -1),
                                zbar.reshape(zbar.shape[0], -1,
                                             zbar.shape[-1]))
        return acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))

    def add_example_stat(self, acc_bar, stat, group):
        """Scatter an already-(B,)-shaped stat into a group column."""
        return acc_bar.at[:, group].add(stat.astype(acc_bar.dtype))

    def add_dense_batched(self, acc_bar, h, zbar, group, method,
                          use_pallas):
        """Per-example-weight dense stat: h (B,[S,]p_in) against a
        batched weight (B,p_in,p_out) — the multi-tenant LoRA form,
        where example j owns weight slice j (its tenant's gathered
        adapter factor). Example j's gradient is h_jᵀ z̄_j, the same
        outer product as the shared-weight case, so:

          * 2-D h — one token-row per example ⇒ the paper's §4
            factorization is EXACT: s_j = ‖h_j‖²‖z̄_j‖².
          * 3-D h — flatten tokens and run the segmented-direct
            estimator with example ids as segments (ONE launch across
            all examples/tenants; sorted runs since ids are
            repeat(arange(B), S))."""
        if h.ndim == 2:
            stat = N.stat_factorized(h, zbar)
            return self.add_example_stat(acc_bar, stat, group)
        b, s = h.shape[0], h.shape[1]
        h2 = h.reshape(b * s, h.shape[-1])
        z2 = zbar.reshape(b * s, zbar.shape[-1])
        seg = jnp.repeat(jnp.arange(b, dtype=jnp.int32), s)
        stat = N.stat_direct_segmented(h2, z2, seg, b, method=method,
                                       use_pallas=use_pallas)
        return self.add_example_stat(acc_bar, stat, group)

    def add_expert(self, acc_bar, x, zbar, seg, tok, group, n_examples,
                   method, use_pallas):
        """MoE expert-buffer stat: x (E,C,d), zbar (E,C,f), seg (E,C)
        example ids (≥ n_examples ⇒ dropped). Example j's gradient for
        expert e is a separate d×f block of the stacked weight — cross-
        expert outer products must NOT merge before squaring, hence the
        (expert, example) composite segments. ``tok`` (slot → token
        position) is not needed at example granularity."""
        e, c, d = x.shape
        composite = (jnp.arange(e, dtype=seg.dtype)[:, None]
                     * (n_examples + 1) + jnp.minimum(seg, n_examples))
        stat_ec = N.stat_direct_segmented(
            x.reshape(e * c, d), zbar.reshape(e * c, -1),
            composite.reshape(e * c), e * (n_examples + 1),
            method=method, use_pallas=use_pallas)
        stat = stat_ec.reshape(e, n_examples + 1)[:, :n_examples].sum(axis=0)
        return self.add_example_stat(acc_bar, stat, group)

    def add_expert_grouped(self, acc_bar, x, zbar, seg, tok, group, bg,
                           method, use_pallas):
        """Grouped (GShard-local) expert stat: x (G,E,C,d), seg (G,E,C)
        GROUP-LOCAL example ids (≥ bg ⇒ padding row); group g's stats
        land at acc rows [g·bg, (g+1)·bg). An example's rows live only
        in its own group, so (group, expert, example) composite segments
        are exact. The Pallas route flattens all groups into ONE kernel
        launch (vmapping a scalar-prefetched pallas_call is not
        supported); the XLA oracle keeps its per-group vmap form."""
        ng, e, c, d = x.shape
        f = zbar.shape[-1]
        if method == "auto":
            # price the candidates as they would actually launch: the
            # Pallas route is ONE flattened kernel over all groups, the
            # XLA route ng vmapped per-group scans
            if not use_pallas:
                method = "xla"
            else:
                c_pl = N.segmented_cost(ng * e * c, d, f,
                                        ng * e * (bg + 1), use_pallas=True)
                c_xla = ng * N.segmented_cost(e * c, d, f, e * (bg + 1))
                method = "pallas" if c_pl <= c_xla else "xla"
        if method == "pallas":
            ge = (jnp.arange(ng, dtype=seg.dtype)[:, None, None] * e
                  + jnp.arange(e, dtype=seg.dtype)[None, :, None])
            composite = ge * (bg + 1) + jnp.minimum(seg, bg)
            stat_all = N.stat_direct_segmented(
                x.reshape(ng * e * c, d), zbar.reshape(ng * e * c, f),
                composite.reshape(ng * e * c), ng * e * (bg + 1),
                method="pallas")
            stat = stat_all.reshape(ng, e, bg + 1)[:, :, :bg].sum(axis=1)
            stat = stat.reshape(ng * bg)
            return self.add_example_stat(acc_bar, stat, group)

        def one_group(xg, zg, sg):
            composite = (jnp.arange(e, dtype=sg.dtype)[:, None] * (bg + 1)
                         + jnp.minimum(sg, bg))
            stat_ec = N.stat_direct_segmented(
                xg.reshape(e * c, d), zg.reshape(e * c, f),
                composite.reshape(e * c), e * (bg + 1), method="xla")
            return stat_ec.reshape(e, bg + 1)[:, :bg].sum(axis=0)  # (bg,)

        stat = jax.vmap(one_group)(x, zbar, seg).reshape(ng * bg)
        return self.add_example_stat(acc_bar, stat, group)


def _sumsq_tail(x, keep: int = 2):
    """Σ x² over all axes past the first ``keep`` (f32)."""
    axes = tuple(range(keep, x.ndim))
    return jnp.sum(jnp.square(x.astype(_ACC_DTYPE)), axis=axes)


@dataclasses.dataclass(frozen=True)
class TokenLayout:
    """(B, S) accumulator: the paper's §4 factorization at token
    granularity, where it is exact for every dense layer in every
    sequence model — token t's contribution to ``∂L/∂W`` is the rank-1
    outer product ``h_t z̄_tᵀ``, so ``s_{j,t} = ‖h_{j,t}‖²·‖z̄_{j,t}‖²``.
    Group columns do not apply; all taps fold into the one (B, S) map.
    Uses: token-level data filtering / curriculum, influence
    diagnostics, per-token clipping."""
    seq: int

    def init(self, batch: int) -> jax.Array:
        return _shard(jnp.zeros((batch, self.seq), _ACC_DTYPE),
                      "batch", None)

    def add_dense(self, acc_bar, h, zbar, group, method, use_pallas):
        if h.ndim != 3:
            raise ValueError(
                f"TokenLayout dense tap needs (B, S, p) activations, got "
                f"shape {h.shape}; per-token factorization is only exact "
                f"when each token is one row of the matmul")
        return acc_bar + _sumsq_tail(h) * _sumsq_tail(zbar)

    def add_dense_batched(self, acc_bar, h, zbar, group, method,
                          use_pallas):
        """Batched-weight dense stat at token granularity: token t's
        contribution to its example's weight slice is still the rank-1
        outer product h_t z̄_tᵀ — the §4 factorization is exact per
        token, regardless of which example owns the weight."""
        if h.ndim != 3:
            raise ValueError(
                f"TokenLayout dense_batched tap needs (B, S, p) "
                f"activations, got shape {h.shape}")
        return acc_bar + _sumsq_tail(h) * _sumsq_tail(zbar)

    def add_bias(self, acc_bar, zbar, group):
        # token t's bias contribution is z̄_t itself
        self._check_rank(zbar, "bias_add")
        return acc_bar + _sumsq_tail(zbar)

    def add_scale(self, acc_bar, h, zbar, group):
        # token t's gain contribution is h_t ⊙ z̄_t
        self._check_rank(zbar, "scale")
        return acc_bar + _sumsq_tail(h.astype(_ACC_DTYPE) *
                                     zbar.astype(_ACC_DTYPE))

    def add_embedding(self, acc_bar, ids, zbar, group):
        # one-hot row ⇒ ‖h_t‖² = 1 ⇒ stat is ‖z̄_t‖²
        self._check_rank(zbar, "embedding")
        return acc_bar + _sumsq_tail(zbar)

    def _check_rank(self, zbar, op: str) -> None:
        if zbar.ndim < 3:
            raise ValueError(
                f"TokenLayout {op} tap needs (B, S, ...) activations, got "
                f"shape {zbar.shape}; a rank-2 stat would silently "
                f"broadcast into the (B, S) accumulator")

    def _scatter_slot_stats(self, acc_bar, stat, target, valid):
        """Scatter per-slot stats into the flat (B·S) token map; invalid
        slots (capacity padding) are masked AND redirected out of bounds
        so ``mode="drop"`` discards them explicitly."""
        b, s = acc_bar.shape
        tgt = jnp.where(valid, target, b * s).reshape(-1)
        upd = jnp.where(valid, stat, 0.0).reshape(-1)
        flat = acc_bar.reshape(-1).at[tgt].add(upd.astype(acc_bar.dtype),
                                               mode="drop")
        return flat.reshape(b, s)

    def add_expert(self, acc_bar, x, zbar, seg, tok, group, n_examples,
                   method, use_pallas):
        """Token-granularity expert stat. Every capacity slot holds ONE
        token's row, so token t's contribution to the expert weight is
        the rank-1 outer product x_slot z̄_slotᵀ — the §4 factorization
        is *exact* per slot, and a token's slots land in distinct expert
        matrices (top-k experts are distinct), so summing its slot stats
        into the (B, S) map is exact too. ``tok`` is the slot → flat
        token position table from the dispatch sort (tok ∈ [0, B·S);
        out-of-range ⇒ padding slot); no segmented estimator needed —
        per-token expert norms are O(T·p)."""
        b, s = acc_bar.shape
        stat = _sumsq_tail(x, 2) * _sumsq_tail(zbar, 2)          # (E, C)
        valid = jnp.logical_and(tok >= 0, tok < b * s)
        return self._scatter_slot_stats(acc_bar, stat, tok, valid)

    def add_expert_grouped(self, acc_bar, x, zbar, seg, tok, group, bg,
                           method, use_pallas):
        """Grouped-dispatch variant: ``tok`` (G,E,C) carries GROUP-LOCAL
        flat token ids (∈ [0, bg·S); ≥ bg·S ⇒ padding slot); group g
        covers the flat tokens [g·bg·S, (g+1)·bg·S)."""
        b, s = acc_bar.shape
        ng = x.shape[0]
        tg = bg * s
        stat = _sumsq_tail(x, 3) * _sumsq_tail(zbar, 3)          # (G, E, C)
        valid = jnp.logical_and(tok >= 0, tok < tg)
        glob = jnp.arange(ng, dtype=jnp.int32)[:, None, None] * tg + tok
        return self._scatter_slot_stats(acc_bar, stat, glob, valid)


def _int_zero_cotangent(x):
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---------------------------------------------------------------------------
# custom_vjp op registry (layout-parameterized)
# dense: z = h @ w        (the paper's layer; h (B,[S,]p_in), w (p_in,p_out))
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _pex_dense(method: str, use_pallas: bool, group: int, layout,
               h: jax.Array, w: jax.Array, acc: jax.Array):
    return jnp.einsum("...i,io->...o", h, w), acc


def _pex_dense_fwd(method, use_pallas, group, layout, h, w, acc):
    z = jnp.einsum("...i,io->...o", h, w)
    return (z, acc), (h, w)


def _pex_dense_bwd(method, use_pallas, group, layout, res, cts):
    h, w = res
    zbar, acc_bar = cts
    dh = jnp.einsum("...o,io->...i", zbar, w).astype(h.dtype)
    dw = jnp.einsum("...i,...o->io", h, zbar).astype(w.dtype)
    dacc = layout.add_dense(acc_bar, h, zbar, group, method, use_pallas)
    return dh, dw, dacc


_pex_dense.defvjp(_pex_dense_fwd, _pex_dense_bwd)


# ---------------------------------------------------------------------------
# dense_batched: z = einsum('b...i,bio->b...o')  (per-example weights —
#   the multi-tenant LoRA form, where example j's matmul uses weight
#   slice j, the gather of its tenant's adapter factor)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _pex_dense_batched(method: str, use_pallas: bool, group: int, layout,
                       h: jax.Array, w: jax.Array, acc: jax.Array):
    return jnp.einsum("b...i,bio->b...o", h, w), acc


def _pex_dense_batched_fwd(method, use_pallas, group, layout, h, w, acc):
    z = jnp.einsum("b...i,bio->b...o", h, w)
    return (z, acc), (h, w)


def _pex_dense_batched_bwd(method, use_pallas, group, layout, res, cts):
    h, w = res
    zbar, acc_bar = cts
    dh = jnp.einsum("b...o,bio->b...i", zbar, w).astype(h.dtype)
    dw = jnp.einsum("b...i,b...o->bio", h, zbar).astype(w.dtype)
    dacc = layout.add_dense_batched(acc_bar, h, zbar, group, method,
                                    use_pallas)
    return dh, dw, dacc


_pex_dense_batched.defvjp(_pex_dense_batched_fwd, _pex_dense_batched_bwd)


# ---------------------------------------------------------------------------
# dense_expert: z = einsum('ecd,edf->ecf')  (MoE expert matmuls; rows of the
#   (E, C) capacity buffer belong to arbitrary examples, so stats use the
#   segmented-direct estimator with per-row example ids — or, at token
#   granularity, the per-slot factorization scattered by token position)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _pex_dense_expert(group: int, n_examples: int, method: str,
                      use_pallas: bool, layout,
                      x: jax.Array, w: jax.Array, seg: jax.Array,
                      tok: jax.Array, acc: jax.Array):
    return jnp.einsum("ecd,edf->ecf", x, w), acc


def _pex_dense_expert_fwd(group, n_examples, method, use_pallas, layout,
                          x, w, seg, tok, acc):
    return (jnp.einsum("ecd,edf->ecf", x, w), acc), (x, w, seg, tok)


def _pex_dense_expert_bwd(group, n_examples, method, use_pallas, layout,
                          res, cts):
    x, w, seg, tok = res
    zbar, acc_bar = cts
    dx = jnp.einsum("ecf,edf->ecd", zbar, w).astype(x.dtype)
    dw = jnp.einsum("ecd,ecf->edf", x, zbar).astype(w.dtype)
    dacc = layout.add_expert(acc_bar, x, zbar, seg, tok, group, n_examples,
                             method, use_pallas)
    return (dx, dw, _int_zero_cotangent(seg), _int_zero_cotangent(tok),
            dacc)


_pex_dense_expert.defvjp(_pex_dense_expert_fwd, _pex_dense_expert_bwd)


# ---------------------------------------------------------------------------
# dense_expert_grouped: z = einsum('gecd,edf->gecf') — grouped local MoE
#   dispatch (groups aligned with data shards). seg holds GROUP-LOCAL
#   example ids (and tok group-local token ids), so the stats stay
#   device-local; group g's stats land at acc rows [g·bg, (g+1)·bg).
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _pex_dense_expert_grouped(group: int, bg: int, method: str,
                              use_pallas: bool, layout,
                              x: jax.Array, w: jax.Array, seg: jax.Array,
                              tok: jax.Array, acc: jax.Array):
    return jnp.einsum("gecd,edf->gecf", x, w), acc


def _pex_dense_expert_grouped_fwd(group, bg, method, use_pallas, layout,
                                  x, w, seg, tok, acc):
    return (jnp.einsum("gecd,edf->gecf", x, w), acc), (x, w, seg, tok)


def _pex_dense_expert_grouped_bwd(group, bg, method, use_pallas, layout,
                                  res, cts):
    x, w, seg, tok = res
    zbar, acc_bar = cts
    dx = jnp.einsum("gecf,edf->gecd", zbar, w).astype(x.dtype)
    dw = jnp.einsum("gecd,gecf->edf", x, zbar).astype(w.dtype)
    dacc = layout.add_expert_grouped(acc_bar, x, zbar, seg, tok, group, bg,
                                     method, use_pallas)
    return (dx, dw, _int_zero_cotangent(seg), _int_zero_cotangent(tok),
            dacc)


_pex_dense_expert_grouped.defvjp(_pex_dense_expert_grouped_fwd,
                                 _pex_dense_expert_grouped_bwd)


# ---------------------------------------------------------------------------
# bias_add: z = x + b      (paper folds b into W as a ones-column; same math)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pex_bias(group: int, layout, x: jax.Array, b: jax.Array,
              acc: jax.Array):
    return x + b, acc


def _pex_bias_fwd(group, layout, x, b, acc):
    return (x + b, acc), None


def _pex_bias_bwd(group, layout, _, cts):
    zbar, acc_bar = cts
    reduce_axes = tuple(range(zbar.ndim - 1))
    db = jnp.sum(zbar, axis=reduce_axes).astype(zbar.dtype)
    dacc = layout.add_bias(acc_bar, zbar, group)
    return zbar, db, dacc


_pex_bias.defvjp(_pex_bias_fwd, _pex_bias_bwd)


# ---------------------------------------------------------------------------
# scale: z = g ⊙ h         (elementwise params: RMSNorm gains, decays, ...)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pex_scale(group: int, layout, h: jax.Array, g: jax.Array,
               acc: jax.Array):
    return h * g, acc


def _pex_scale_fwd(group, layout, h, g, acc):
    return (h * g, acc), (h, g)


def _pex_scale_bwd(group, layout, res, cts):
    h, g = res
    zbar, acc_bar = cts
    dh = (zbar * g).astype(h.dtype)
    reduce_axes = tuple(range(zbar.ndim - 1))
    dg = jnp.sum(zbar * h, axis=reduce_axes).astype(g.dtype)
    dacc = layout.add_scale(acc_bar, h, zbar, group)
    return dh, dg, dacc


_pex_scale.defvjp(_pex_scale_fwd, _pex_scale_bwd)


# ---------------------------------------------------------------------------
# embedding: z = table[ids]   (one-hot H ⇒ Gram is an equality matrix;
#                              exact via sort + segment-sum, O(S·d))
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _pex_embed(group: int, layout, table: jax.Array, ids: jax.Array,
               acc: jax.Array):
    return jnp.take(table, ids, axis=0), acc


def _pex_embed_fwd(group, layout, table, ids, acc):
    # `table` rides along only as a shape/dtype reference for the scatter;
    # it is a live parameter anyway, so this costs no extra memory.
    return (jnp.take(table, ids, axis=0), acc), (ids, table)


def _pex_embed_bwd(group, layout, res, cts):
    ids, table = res
    zbar, acc_bar = cts
    flat_ids = ids.reshape(-1)
    flat_z = zbar.reshape(-1, zbar.shape[-1])
    dtable = jnp.zeros_like(table).at[flat_ids].add(flat_z.astype(table.dtype))
    dacc = layout.add_embedding(acc_bar, ids, zbar, group)
    return dtable, _int_zero_cotangent(ids), dacc


_pex_embed.defvjp(_pex_embed_fwd, _pex_embed_bwd)


# ---------------------------------------------------------------------------
# tap-site provenance — the metadata the static analyzer consumes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PexOpInfo:
    """Shape of one instrumented op as it appears in a traced jaxpr.

    Slots index the op's *differentiable* operands, i.e. the
    ``custom_vjp_call_jaxpr`` equation's invars after its ``num_consts``
    prefix. ``weight_slots`` hold the parameter whose per-example stat
    this op registers (the gradient path the tap covers);
    ``data_slots`` are the operands whose gradient flows *through* the
    op to earlier layers; the last operand is always the accumulator.
    ``analysis.coverage`` uses this to decide which taint survives an
    op: a parameter reaching the loss only via weight slots is tapped,
    one that also reaches it through any plain op is undercounted.
    """
    name: str
    weight_slots: Tuple[int, ...]
    data_slots: Tuple[int, ...]
    n_operands: int


#: registered backward rule → op provenance. Keyed by the *function
#: object* jax stores in the equation's ``bwd`` parameter, which is
#: robust to renaming and wrapping (``identify_pex_bwd`` unwraps it).
PEX_OPS = {
    _pex_dense_bwd: PexOpInfo("dense", (1,), (0,), 3),
    _pex_dense_batched_bwd: PexOpInfo("dense_batched", (1,), (0,), 3),
    _pex_dense_expert_bwd: PexOpInfo("dense_expert", (1,), (0, 2, 3), 5),
    _pex_dense_expert_grouped_bwd: PexOpInfo(
        "dense_expert_grouped", (1,), (0, 2, 3), 5),
    _pex_bias_bwd: PexOpInfo("bias_add", (1,), (0,), 3),
    _pex_scale_bwd: PexOpInfo("scale", (1,), (0,), 3),
    _pex_embed_bwd: PexOpInfo("embedding", (0,), (1,), 3),
}

_PEX_OPS_BY_NAME = {fn.__name__: info for fn, info in PEX_OPS.items()}


def identify_pex_bwd(bwd) -> Optional[PexOpInfo]:
    """Resolve a ``custom_vjp_call_jaxpr`` equation's ``bwd`` parameter
    to the pex op it belongs to (None for foreign custom_vjps, e.g. the
    flash-attention kernel). jax stores ``bwd`` as a bound method of a
    ``WrappedFun`` holding the raw registered function in ``.f``; fall
    back to matching the wrapped repr against the registered names so
    a jax-internal relayering doesn't silently blind the analyzer."""
    raw = getattr(getattr(bwd, "__self__", None), "f", None)
    if raw is None:
        raw = getattr(bwd, "f", None)
    info = PEX_OPS.get(raw)
    if info is not None:
        return info
    label = repr(raw) if raw is not None else repr(bwd)
    for name, info in _PEX_OPS_BY_NAME.items():
        if name in label:
            return info
    return None


# ---------------------------------------------------------------------------
# the trace-time collector (pex v2)
# ---------------------------------------------------------------------------

class Tap:
    """Trace-time tap collector: owns the accumulator so models never
    thread it. Create one per traced function (``Engine`` does this),
    pass it down; every op mutates ``tap``'s held accumulator at trace
    time, which keeps the value chain explicit in the jaxpr:

        tap = Tap(spec, acc=layout.init(batch))
        z = tap.dense(h, w, group="mlp")
        ...
        acc_out = tap.carry()     # return so the chain stays live

    Crossing a transform boundary (``lax.scan`` body, ``jax.checkpoint``)
    requires the accumulator to be an explicit input/output of the inner
    function — use ``pex.scan`` / ``pex.checkpoint`` (this module's
    ``scan`` / ``checkpoint``), or ``carry()`` / ``set_carry()`` by hand.

    A tap with ``spec.enabled=False`` or no accumulator is inert: every
    op is its plain counterpart (``NULL`` is the shared inert tap), so
    the same model code serves uninstrumented.
    """
    __slots__ = ("spec", "layout", "_acc", "_token_losses")

    def __init__(self, spec: PexSpec, acc: Optional[jax.Array] = None,
                 layout=None):
        self.spec = spec
        self.layout = layout if layout is not None \
            else ExampleLayout(spec.n_groups)
        self._acc = acc
        self._token_losses = None

    @property
    def live(self) -> bool:
        """True when ops actually register stats."""
        return self.spec.enabled and self._acc is not None

    # -- accumulator plumbing (transform boundaries) --------------------
    def carry(self):
        """Current accumulator value (to return / put in a scan carry)."""
        return self._acc

    def set_carry(self, acc) -> None:
        """Rebind the accumulator (entering a scan body / after a scan)."""
        self._acc = acc

    # -- per-token loss registration (plan layer) ------------------------
    def token_loss(self, token_losses: jax.Array) -> jax.Array:
        """Register the per-token loss map ℓ_{j,t} (shape (B, S, ...))
        — an identity op. Canonical losses call this on the token
        losses *before* reducing them to the (B,) loss vector; the
        plan layer (``core.plan``) then seeds its token-weighted
        reweighting backward through this output, which is what makes
        ``Clip(C, granularity="token")`` one fused pass. Inert taps
        record nothing (``NULL`` stays stateless across traces)."""
        if self.live:
            self._token_losses = token_losses if self._token_losses is None \
                else self._token_losses + token_losses
        return token_losses

    def token_losses(self) -> Optional[jax.Array]:
        """The registered per-token loss map for this trace (or None)."""
        return self._token_losses

    # -- ops -------------------------------------------------------------
    def dense(self, h, w, *, group: str = "all",
              method: Optional[str] = None) -> jax.Array:
        """Instrumented matmul. Plain einsum when the tap is inert."""
        if not self.live:
            return jnp.einsum("...i,io->...o", h, w)
        m = method or self.spec.method
        z, self._acc = _pex_dense(m, self.spec.use_pallas,
                                  self.spec.group_index(group), self.layout,
                                  h, w, self._acc)
        return z

    def dense_batched(self, h, w, *, group: str = "all",
                      method: Optional[str] = None) -> jax.Array:
        """Instrumented per-example-weight matmul: h (B,[S,]p_in),
        w (B,p_in,p_out) — example j multiplies weight slice j (its
        tenant's gathered LoRA factor). Stats default to the spec's
        ``seg_method`` (the 3-D path runs the segmented-direct
        estimator, same knob as the MoE expert taps)."""
        if not self.live:
            return jnp.einsum("b...i,bio->b...o", h, w)
        m = method or self.spec.seg_method
        z, self._acc = _pex_dense_batched(
            m, self.spec.use_pallas, self.spec.group_index(group),
            self.layout, h, w, self._acc)
        return z

    def bias_add(self, x, b, *, group: str = "all") -> jax.Array:
        if not self.live:
            return x + b
        z, self._acc = _pex_bias(self.spec.group_index(group), self.layout,
                                 x, b, self._acc)
        return z

    def scale(self, h, g, *, group: str = "all") -> jax.Array:
        if not self.live:
            return h * g
        z, self._acc = _pex_scale(self.spec.group_index(group), self.layout,
                                  h, g, self._acc)
        return z

    def embedding(self, table, ids, *, group: str = "embed") -> jax.Array:
        if not (self.live and self.spec.tap_embeddings):
            return jnp.take(table, ids, axis=0)
        z, self._acc = _pex_embed(self.spec.group_index(group), self.layout,
                                  table, ids, self._acc)
        return z

    def _expert_tok(self, seg, tok):
        """Validate/default the slot→token-position table: required at
        token granularity (the capacity shuffle loses positions without
        it — nn.moe threads its dispatch sort's table automatically);
        at example granularity an absent table becomes an inert
        sentinel so legacy call sites keep working."""
        if tok is not None:
            return tok
        if isinstance(self.layout, TokenLayout):
            raise ValueError(
                "granularity='token' expert taps need token positions: "
                "pass tok= (slot → flat token id, as produced by "
                "nn.moe's dispatch sort); without it the (B, S) map "
                "cannot be scattered")
        return jnp.full_like(seg, -1)

    def dense_expert(self, x, w, seg, tok=None, *,
                     group: str = "moe") -> jax.Array:
        """Instrumented per-expert matmul. x (E,C,d), w (E,d,f), seg (E,C)
        int example ids (>= batch ⇒ padding row, excluded from stats);
        tok (E,C) flat token positions (>= B·S ⇒ padding row), required
        for TokenLayout."""
        if not self.live:
            return jnp.einsum("ecd,edf->ecf", x, w)
        tok = self._expert_tok(seg, tok)
        z, self._acc = _pex_dense_expert(
            self.spec.group_index(group), self._acc.shape[0],
            self.spec.seg_method, self.spec.use_pallas, self.layout,
            x, w, seg, tok, self._acc)
        return z

    def dense_expert_grouped(self, x, w, seg, bg: int, tok=None, *,
                             group: str = "moe") -> jax.Array:
        """Grouped instrumented expert matmul. x (G,E,C,d), w (E,d,f),
        seg (G,E,C) group-local example ids (>= bg ⇒ padding row); tok
        (G,E,C) group-local flat token ids (>= bg·S ⇒ padding row),
        required for TokenLayout."""
        if not self.live:
            return jnp.einsum("gecd,edf->gecf", x, w)
        tok = self._expert_tok(seg, tok)
        z, self._acc = _pex_dense_expert_grouped(
            self.spec.group_index(group), bg,
            self.spec.seg_method, self.spec.use_pallas, self.layout,
            x, w, seg, tok, self._acc)
        return z


#: Shared inert tap: every op is its plain counterpart. Serving /
#: oracle paths pass this instead of constructing a disabled Tap.
NULL = Tap(DISABLED)


def scan(body, init, xs, *, tap: Optional[Tap] = None, length=None,
         reverse: bool = False, unroll=1, remat: bool = False, policy=None):
    """``lax.scan`` with the tap's accumulator threaded through the
    carry — the v2 replacement for hand-carrying ``acc``.

    ``body(carry, x) -> (carry, y)`` uses ``tap`` from its enclosing
    scope exactly like straight-line code; this wrapper makes the
    accumulator an explicit carry element so the tap chain survives the
    scan boundary. ``remat=True`` applies ``jax.checkpoint`` (with
    ``policy``) to the (acc-explicit) body. With an inert/absent tap
    this is a plain ``lax.scan`` — the DCE property is untouched.
    """
    if tap is None or not tap.live:
        fn = jax.checkpoint(body, policy=policy) if remat else body
        return jax.lax.scan(fn, init, xs, length=length, reverse=reverse,
                            unroll=unroll)

    def threaded(carry, x):
        c, acc = carry
        tap.set_carry(acc)
        c, y = body(c, x)
        return (c, tap.carry()), y

    fn = jax.checkpoint(threaded, policy=policy) if remat else threaded
    (c, acc), ys = jax.lax.scan(fn, (init, tap.carry()), xs, length=length,
                                reverse=reverse, unroll=unroll)
    tap.set_carry(acc)
    return c, ys


def checkpoint(fn, *, tap: Optional[Tap] = None, policy=None):
    """``jax.checkpoint`` with the tap's accumulator made explicit, so a
    rematerialized block neither leaks tracers nor severs the tap chain.
    Returns a function with ``fn``'s signature."""
    if tap is None or not tap.live:
        return jax.checkpoint(fn, policy=policy)

    def explicit(acc, *args, **kw):
        tap.set_carry(acc)
        out = fn(*args, **kw)
        return out, tap.carry()

    inner = jax.checkpoint(explicit, policy=policy)

    def outer(*args, **kw):
        out, acc = inner(tap.carry(), *args, **kw)
        tap.set_carry(acc)
        return out

    return outer


