"""Core: the paper's per-example gradient-norm technique as a composable
JAX transform family.

  taps       — custom_vjp cotangent-accumulator ops (dense/bias/scale/embedding)
  norms      — the estimator zoo (factorized = paper §4, gram, direct, ...)
  api        — value_and_norms / value_grads_and_norms / clipped grads (§6, 2-pass)
  clipping   — one-pass §6 (perturbation taps; faithful MLP form)
  importance — Zhao & Zhang importance sampling on top of the norms
  naive      — paper §3 oracle (vmap-of-grad), used by tests & benchmarks
"""
from repro.core.taps import (PexSpec, DISABLED, init_acc, dense, bias_add,
                             scale, embedding)
from repro.core.api import (PexResult, value_and_norms, value_grads_and_norms,
                            clip_coefficients, clipped_value_and_grads)

__all__ = [
    "PexSpec", "DISABLED", "init_acc", "dense", "bias_add", "scale",
    "embedding", "PexResult", "value_and_norms", "value_grads_and_norms",
    "clip_coefficients", "clipped_value_and_grads",
]
