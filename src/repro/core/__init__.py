"""Core: the paper's per-example gradient-norm technique as a composable
JAX transform family.

  taps       — custom_vjp cotangent-accumulator ops + the pex v2 Tap
               collector and accumulator layouts (example / token)
  engine     — pex v2 Engine: one entry point for local, sharded, and
               token-level runs (see also the repro.pex namespace)
  norms      — the estimator zoo (factorized = paper §4, gram, direct, ...)
  api        — v1 explicit-acc transforms (Engine builds on these)
  clipping   — one-pass §6 (perturbation taps; faithful MLP form)
  importance — Zhao & Zhang importance sampling on top of the norms
  naive      — paper §3 oracle (vmap-of-grad), used by tests & benchmarks
"""
from repro.core.taps import (PexSpec, DISABLED, NULL, Tap, ExampleLayout,
                             TokenLayout, init_acc, scan, checkpoint,
                             dense, bias_add, scale, embedding)
from repro.core.api import (PexResult, value_and_norms, value_grads_and_norms,
                            clip_coefficients, clipped_value_and_grads)
from repro.core.engine import Engine, plain_engine

__all__ = [
    "PexSpec", "DISABLED", "NULL", "Tap", "ExampleLayout", "TokenLayout",
    "init_acc", "scan", "checkpoint", "dense", "bias_add", "scale",
    "embedding", "PexResult", "value_and_norms", "value_grads_and_norms",
    "clip_coefficients", "clipped_value_and_grads", "Engine", "plain_engine",
]
