"""Core: the paper's per-example gradient-norm technique as a composable
JAX transform family.

  taps       — custom_vjp cotangent-accumulator ops + the pex v2 Tap
               collector and accumulator layouts (example / token)
  plan       — declarative consumer plans (Norms/Grads/Clip/Noise/
               Importance/GNS) fused into one pass (DESIGN.md §9)
  engine     — pex v2 Engine: `step(consumers=[...])` for local,
               sharded, and token-level runs (see also repro.pex)
  norms      — the estimator zoo (factorized = paper §4, gram, direct,
               segmented-direct for MoE expert buffers, ...)
  passes     — internal explicit-acc transforms the plan layer builds on
  clipping   — Clip-consumer coefficient math + one-pass §6 oracle
  importance — Zhao & Zhang sampling math behind the Importance consumer
  naive      — paper §3 oracle (vmap-of-grad), used by tests & benchmarks
"""
from repro.core.taps import (PexSpec, DISABLED, NULL, Tap, ExampleLayout,
                             TokenLayout, scan, checkpoint)
from repro.core.passes import PexResult, clip_coefficients
from repro.core.plan import (GNS, Clip, Grads, Importance, Noise, Norms,
                             StepResult)
from repro.core.engine import Engine, plain_engine

__all__ = [
    "PexSpec", "DISABLED", "NULL", "Tap", "ExampleLayout", "TokenLayout",
    "scan", "checkpoint", "PexResult", "clip_coefficients", "Engine",
    "plain_engine", "Norms", "Grads", "Clip", "Noise", "Importance", "GNS",
    "StepResult",
]
