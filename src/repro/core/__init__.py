"""Core: the paper's per-example gradient-norm technique as a composable
JAX transform family.

  taps       — custom_vjp cotangent-accumulator ops + the pex v2 Tap
               collector and accumulator layouts (example / token)
  engine     — pex v2 Engine: one entry point for local, sharded, and
               token-level runs (see also the repro.pex namespace)
  norms      — the estimator zoo (factorized = paper §4, gram, direct,
               segmented-direct for MoE expert buffers, ...)
  passes     — internal explicit-acc transforms the Engine builds on
  clipping   — one-pass §6 (perturbation taps; faithful MLP form)
  importance — Zhao & Zhang importance sampling on top of the norms
  naive      — paper §3 oracle (vmap-of-grad), used by tests & benchmarks
"""
from repro.core.taps import (PexSpec, DISABLED, NULL, Tap, ExampleLayout,
                             TokenLayout, scan, checkpoint)
from repro.core.passes import PexResult, clip_coefficients
from repro.core.engine import Engine, plain_engine

__all__ = [
    "PexSpec", "DISABLED", "NULL", "Tap", "ExampleLayout", "TokenLayout",
    "scan", "checkpoint", "PexResult", "clip_coefficients", "Engine",
    "plain_engine",
]
