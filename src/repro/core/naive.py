"""The paper's §3 naive method — kept as the numerical oracle.

Runs backprop once per example (vectorized here with ``jax.vmap`` so it
is at least not *pathologically* slow, though it still materializes the
full per-example gradient pytree — the O(m·n·p²) memory/compute the
paper's method avoids).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def per_example_grads(loss_fn: Callable, params, batch):
    """Materialize per-example parameter gradients.

    loss_fn(params, batch_of_one) -> scalar loss for that example.
    batch: pytree whose leaves have a leading batch axis.
    Returns a pytree matching params with a leading batch axis.
    """
    def one(ex):
        return jax.grad(loss_fn)(params, ex)
    return jax.vmap(one)(batch)


def per_example_sq_norms(loss_fn: Callable, params, batch,
                         param_filter: Callable = None) -> jax.Array:
    """(B,) vector of ||∂L^(j)/∂θ||² via the naive method (paper §3)."""
    grads = per_example_grads(loss_fn, params, batch)
    leaves = jax.tree_util.tree_leaves_with_path(grads)
    total = None
    for path, g in leaves:
        if param_filter is not None and not param_filter(path):
            continue
        s = jnp.sum(jnp.square(g.astype(jnp.float32)),
                    axis=tuple(range(1, g.ndim)))
        total = s if total is None else total + s
    return total


def per_example_grad_pytree_norms(grads) -> jax.Array:
    """Squared norms from an already-materialized per-example grad pytree."""
    sq = [jnp.sum(jnp.square(g.astype(jnp.float32)), axis=tuple(range(1, g.ndim)))
          for g in jax.tree_util.tree_leaves(grads)]
    return sum(sq)
