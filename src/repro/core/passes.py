"""Per-example transform passes — the layer ``Engine`` builds on.

Internal (the v1 public ``core.api`` surface these passes used to be is
gone; user code goes through ``repro.pex.Engine``). Each pass consumes
an explicit-accumulator loss

    loss_fn(params, acc, batch) -> (loss_vec, acc_out, aux)

where ``loss_vec`` is the (B,) vector of per-example losses L^(j)
(paper §2: C = Σ_j L^(j)) and ``acc_out`` is the threaded accumulator
(must be returned so the tap chain stays live). The Engine adapts
tap-collector losses (``loss_fn(params, batch, tap) -> (loss_vec,
aux)``) onto this signature per trace and picks the local vs. mesh
path (``dist.pex`` wraps the same passes in ``shard_map``). The
``layout`` argument makes one set of passes serve per-example
``(B, G)`` and per-token ``(B, S)`` granularities.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.provenance import mark_clip, mark_noise, mark_rng
from repro.core.taps import ExampleLayout, PexSpec, TokenLayout


class PexResult(NamedTuple):
    loss: jax.Array            # scalar total C
    loss_vec: jax.Array        # (B,) per-example losses
    aux: object
    sq_norms: jax.Array        # (B, G) / (B, S) per-example ||grad||²
    grads: object = None       # param pytree (when requested)


def _total(loss_vec):
    return jnp.sum(loss_vec)


def _layout_or_default(layout, spec: PexSpec):
    return layout if layout is not None else ExampleLayout(spec.n_groups)


def check_noise_args(noise_std: float, noise_rng) -> None:
    """DP-SGD noise needs a key; fail at trace time with a clear error
    instead of deep inside ``jax.random.split``."""
    if noise_std and noise_std > 0.0 and noise_rng is None:
        raise ValueError(
            f"noise_std={noise_std} > 0 requires a PRNG key: pass "
            f"noise_rng=jax.random.PRNGKey(...) (DP-SGD noise is "
            f"irreproducible without one)")


def value_and_norms(loss_fn: Callable, params, batch, spec: PexSpec,
                    batch_size: int, layout=None) -> PexResult:
    """Norms-only pass: forward + activation backprop + O(mnp).

    The ``dW`` chains are never built (grad is taken w.r.t. the
    accumulator only), matching the cheap pass of paper §5.
    """
    acc0 = _layout_or_default(layout, spec).init(batch_size)

    def f(acc):
        loss_vec, acc_out, aux = loss_fn(params, acc, batch)
        return _total(loss_vec), (loss_vec, acc_out, aux)

    (loss, (loss_vec, _, aux)), sq = jax.value_and_grad(f, has_aux=True)(acc0)
    return PexResult(loss, loss_vec, aux, sq)


def value_grads_and_norms(loss_fn: Callable, params, batch, spec: PexSpec,
                          batch_size: int, layout=None) -> PexResult:
    """The paper's headline: gradients AND all per-example norms in one
    backward pass, for O(mnp) extra work."""
    acc0 = _layout_or_default(layout, spec).init(batch_size)

    def f(p, acc):
        loss_vec, acc_out, aux = loss_fn(p, acc, batch)
        return _total(loss_vec), (loss_vec, acc_out, aux)

    (loss, (loss_vec, _, aux)), (grads, sq) = jax.value_and_grad(
        f, argnums=(0, 1), has_aux=True)(params, acc0)
    return PexResult(loss, loss_vec, aux, sq, grads)


def add_grad_noise(grads, noise_std: float, clip_norm: float,
                   rng: jax.Array):
    """σ·C Gaussian noise per leaf — the DP-SGD noise step. Kept
    separate from the clipping passes so the sharded pipeline
    (dist.pex) can apply it once after the gradient allreduce."""
    check_noise_args(noise_std, rng)
    flat, tree = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(rng, len(flat))
    out = []
    for i, (g, k) in enumerate(zip(flat, keys)):
        k = mark_rng(k, purpose="noise", index=i)
        sample = noise_std * clip_norm * \
            jax.random.normal(k, g.shape, jnp.float32).astype(g.dtype)
        out.append(g + mark_noise(sample, noise_std=noise_std,
                                  scale=clip_norm, leaf=i))
    return jax.tree_util.tree_unflatten(tree, out)


def add_grad_noise_segmented(grads, noise_std: float, clip_norm: float,
                             rng: jax.Array, segments: jax.Array):
    """Per-segment σ·C noise for trees whose leaves are stacked on a
    leading segment axis (the multi-tenant adapter tree: leaf shape
    (n_active, ...), row s owned by tenant ``segments[s]``).

    Row s draws from ``fold_in(rng, segments[s])`` — bit-identical to
    running ``add_grad_noise(tenant_grads, σ, C, fold_in(rng, tid))``
    per tenant on the unstacked tree, which is what makes each
    tenant's DP accounting independent: its noise depends only on
    (master key, its tenant id), never on which other tenants share
    the batch. One ``mark_rng``/``mark_noise`` pair per leaf keeps the
    pexlint privacy pass's noise-exactly-once bookkeeping intact.
    """
    check_noise_args(noise_std, rng)
    flat, tree = jax.tree_util.tree_flatten(grads)
    n = len(flat)
    # (S, n, 2): tenant s's per-leaf keys; identical derivation order to
    # the single-tenant pass (fold_in then split over leaves)
    keys = jax.vmap(
        lambda t: jax.random.split(jax.random.fold_in(rng, t), n)
    )(segments)
    out = []
    for i, g in enumerate(flat):
        if g.shape[:1] != segments.shape:
            raise ValueError(
                f"segmented noise leaf {i} has shape {g.shape}; leading "
                f"axis must match segments {segments.shape} (one row per "
                f"segment)")
        k = mark_rng(keys[:, i], purpose="noise", index=i)
        sample = noise_std * clip_norm * jax.vmap(
            lambda kk: jax.random.normal(kk, g.shape[1:], jnp.float32)
        )(k).astype(g.dtype)
        out.append(g + mark_noise(sample, noise_std=noise_std,
                                  scale=clip_norm, leaf=i))
    return jax.tree_util.tree_unflatten(tree, out)


def clip_coefficients(sq_norms: jax.Array, clip_norm: float,
                      eps: float = 1e-6) -> jax.Array:
    """c_j = min(1, C / ||g_j||). sq_norms: (B,) or (B,G) (summed)."""
    if sq_norms.ndim == 2:
        sq_norms = jnp.sum(sq_norms, axis=-1)
    c = jnp.minimum(1.0, clip_norm / (jnp.sqrt(sq_norms) + eps))
    return mark_clip(c, clip_norm=clip_norm, eps=eps, granularity="example")


def clipped_value_and_grads(loss_fn: Callable, params, batch, spec: PexSpec,
                            batch_size: int, clip_norm: float,
                            noise_std: float = 0.0,
                            noise_rng: jax.Array = None,
                            layout=None) -> PexResult:
    """Per-example gradient clipping (paper §6, two-pass ghost form).

    Pass 1 computes the norms via the accumulator; pass 2 backprops the
    reweighted loss Σ_j c_j L^(j), whose parameter gradient equals the
    sum of clipped per-example gradients (c_j are constants). Optional
    Gaussian noise makes this a DP-SGD step.
    """
    check_noise_args(noise_std, noise_rng)
    if isinstance(layout, TokenLayout):
        raise NotImplementedError(
            "per-example clipping needs per-example ||g_j||^2; the "
            "(B, S) token map does not sum to them (cross-token terms) "
            "- clip with the example layout")
    res = value_and_norms(loss_fn, params, batch, spec, batch_size,
                          layout=layout)
    c = clip_coefficients(res.sq_norms, clip_norm)
    acc0 = _layout_or_default(layout, spec).init(batch_size)

    def g(p):
        loss_vec, _, _ = loss_fn(p, acc0, batch)
        return jnp.sum(jax.lax.stop_gradient(c) * loss_vec)

    grads = jax.grad(g)(params)
    if noise_std > 0.0:
        grads = add_grad_noise(grads, noise_std, clip_norm, noise_rng)
    return PexResult(res.loss, res.loss_vec, res.aux, res.sq_norms, grads)
