"""Per-example squared-gradient-norm estimators.

All estimators consume the pair the paper identifies — the layer input
``H`` and the pre-activation cotangent ``Z̄`` — and return the *exact*
per-example squared Frobenius norm of that layer's parameter gradient,
``s_j = ||∂L^(j)/∂W||_F²``, as a ``(batch,)`` float32 vector.

Shapes
------
Inputs are either unshared (``(B, p)`` — the paper's MLP setting) or
sequence-shared (``(B, S, p)`` — one weight application per position).

Methods
-------
``factorized``  paper §4 verbatim: ``||h_j||² · ||z̄_j||²``. Exact only
                for the unshared case (rank-1 per-example gradient).
``gram``        ``Σ_{t,t'} (H_jH_jᵀ)_{tt'} (Z̄_jZ̄_jᵀ)_{tt'}`` — exact for
                sequence sharing; reduces to ``factorized`` at S=1.
``direct``      ``||H_jᵀ Z̄_j||_F²`` chunked over p_in — exact, preferred
                when ``s·p_in·p_out < s²(p_in+p_out)``.
``auto``        cost-model pick between ``gram`` and ``direct``.

Cost model (flops per example per layer) — two-sided, one price list
per backend (``dense_cost``):
    XLA:     gram  2·S²·(p_in+p_out) + S²;  direct  2·S·p_in·p_out
    Pallas:  the kernels' own flop models at their *padded* launch
             tiles — the triangular gram grid does ~half the S² work,
             and both methods are charged for chunk-padding waste.
"""
from __future__ import annotations

import math
from typing import Literal

import jax
import jax.numpy as jnp

Method = Literal["factorized", "gram", "direct", "auto"]

_ACC_DTYPE = jnp.float32


def rowsumsq(x: jax.Array) -> jax.Array:
    """Σ x² over all but the leading (batch) axis. Returns (B,) f32."""
    x = x.astype(_ACC_DTYPE)
    return jnp.sum(jnp.square(x), axis=tuple(range(1, x.ndim)))


def stat_factorized(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """Paper §4: s_j = ||z̄_j||² ||h_j||².

    Exact when each example contributes a rank-1 outer product, i.e.
    inputs are (B, p). For (B, S, p) inputs this computes the factorized
    value over the flattened (S·p) vectors — the mechanical application
    of the paper's formula — which is an *upper bound*, not exact; use
    ``gram``/``direct`` for exactness under weight sharing.
    """
    return rowsumsq(zbar) * rowsumsq(h)


def stat_gram(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """Gram-pair estimator. h: (B,S,pi), zbar: (B,S,po) → (B,) f32.

    s_j = Σ_{t,t'} <h_t, h_t'> <z̄_t, z̄_t'>  ==  ||H_jᵀZ̄_j||_F²

    Materializes the (B,S,S) Grams — fine for small S (tests, smoke);
    the Pallas kernel (kernels/gram_norm.py) is the tiled production
    path and never materializes S×S.
    """
    if h.ndim == 2:  # unshared: Gram is 1×1 → factorized, exactly the paper
        return stat_factorized(h, zbar)
    hh = jnp.einsum("bsi,bti->bst", h, h, preferred_element_type=_ACC_DTYPE)
    zz = jnp.einsum("bsi,bti->bst", zbar, zbar, preferred_element_type=_ACC_DTYPE)
    return jnp.sum(hh * zz, axis=(1, 2))


def stat_direct(h: jax.Array, zbar: jax.Array, chunk: int = 1024) -> jax.Array:
    """||H_jᵀ Z̄_j||_F² without materializing (B, p_in, p_out) at once.

    Chunks over p_in; each chunk forms (B, c, p_out), squares, reduces.
    """
    if h.ndim == 2:
        return stat_factorized(h, zbar)
    b, s, p_in = h.shape
    p_out = zbar.shape[-1]
    chunk = max(1, min(chunk, p_in))  # never pad p_in beyond one chunk
    n_chunks = max(1, math.ceil(p_in / chunk))
    pad = n_chunks * chunk - p_in
    if pad:
        h = jnp.pad(h, ((0, 0), (0, 0), (0, pad)))
    hc = h.reshape(b, s, n_chunks, chunk)

    def body(carry, hc_k):
        # hc_k: (B, S, chunk) — scan moves the chunk axis to front
        g = jnp.einsum("bsc,bso->bco", hc_k, zbar,
                       preferred_element_type=_ACC_DTYPE)
        return carry + jnp.sum(jnp.square(g), axis=(1, 2)), None

    init = jnp.zeros((b,), _ACC_DTYPE)
    out, _ = jax.lax.scan(body, init, jnp.moveaxis(hc, 2, 0))
    return out


def gram_flops(s: int, p_in: int, p_out: int) -> float:
    """XLA gram-pair cost: two S×S Grams + their product-reduce."""
    return 2.0 * s * s * (p_in + p_out) + s * s


def direct_flops(s: int, p_in: int, p_out: int) -> float:
    """XLA direct cost: the HᵀZ̄ contraction + square-reduce."""
    return 2.0 * s * p_in * p_out + 2.0 * p_in * p_out


def dense_cost(method: str, s: int, p_in: int, p_out: int, *,
               use_pallas: bool = False) -> float:
    """Per-example flop cost of one dense-layer stat on one backend.

    The two backends genuinely price the same (s, p_in, p_out) point
    differently: the Pallas gram kernel visits only the upper triangle
    of sequence-tile pairs (~half the XLA einsum's S² work), and both
    Pallas kernels pay for padding to their launch tiles — a (640, 96)
    layer costs its padded (640→640 via 5×128, 96→128) shape, not its
    logical one. Using one flop formula for both backends mispredicts
    the crossover by up to 2× in S; this model is what ``pick_method``
    (and hence ``stat_dense(method="auto")``) consults.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        if method == "gram":
            return kops.gram_cost(s, p_in, p_out)
        if method == "direct":
            return kops.direct_cost(s, p_in, p_out)
    else:
        if method == "gram":
            return gram_flops(s, p_in, p_out)
        if method == "direct":
            return direct_flops(s, p_in, p_out)
    raise ValueError(f"unknown method {method!r}")


def pick_method(s: int, p_in: int, p_out: int,
                use_pallas: bool = False) -> str:
    """Cost-model choice between gram and direct (both exact) for the
    backend that will actually run the stat."""
    g = dense_cost("gram", s, p_in, p_out, use_pallas=use_pallas)
    d = dense_cost("direct", s, p_in, p_out, use_pallas=use_pallas)
    return "gram" if g <= d else "direct"


def crossover_s(p_in: int, p_out: int, *, use_pallas: bool = False,
                s_max: int = 1 << 16) -> int:
    """Smallest sequence length at which ``direct`` beats ``gram`` under
    the backend's cost model (binary search; monotone in s because gram
    grows ~s² and direct ~s)."""
    lo, hi = 1, s_max
    if pick_method(hi, p_in, p_out, use_pallas) == "gram":
        return s_max
    while lo < hi:
        mid = (lo + hi) // 2
        if pick_method(mid, p_in, p_out, use_pallas) == "direct":
            hi = mid
        else:
            lo = mid + 1
    return lo


def stat_dense(h: jax.Array, zbar: jax.Array, method: Method = "auto",
               use_pallas: bool = False) -> jax.Array:
    """Dispatch a dense-layer stat. h (B,[S,]p_in), zbar (B,[S,]p_out).

    With ``use_pallas`` both exact methods are real kernels — the gram
    route hits the triangular tile-pair kernel and the direct route the
    blocked HᵀZ̄ kernel — and ``method="auto"`` picks between them with
    the backend-aware cost model above.
    """
    if h.ndim == 2:
        return stat_factorized(h, zbar)
    if method == "auto":
        _, s, p_in = h.shape
        method = pick_method(s, p_in, zbar.shape[-1], use_pallas)
    if method == "factorized":
        return stat_factorized(h, zbar)
    if method == "gram":
        if use_pallas:
            from repro.kernels import ops as kops
            return kops.gram_norm(h, zbar)
        return stat_gram(h, zbar)
    if method == "direct":
        if use_pallas:
            from repro.kernels import ops as kops
            return kops.direct_norm(h, zbar)
        return stat_direct(h, zbar)
    raise ValueError(f"unknown method {method!r}")


def stat_bias(zbar: jax.Array) -> jax.Array:
    """Per-example ||∂L/∂b||²: b's gradient is Σ_t z̄_t (row of ones input)."""
    if zbar.ndim == 2:
        return rowsumsq(zbar)
    v = jnp.sum(zbar.astype(_ACC_DTYPE), axis=tuple(range(1, zbar.ndim - 1)))
    return jnp.sum(jnp.square(v), axis=-1)


def stat_elementwise(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """Per-example norm for an elementwise parameter z = g ⊙ h.

    grad_g L^(j) = Σ_t z̄_{jt} ⊙ h_{jt}; exact, O(S·p).
    h/zbar: (B,[S,]p) with g broadcast over B[,S].
    """
    prod = (zbar.astype(_ACC_DTYPE) * h.astype(_ACC_DTYPE))
    if prod.ndim > 2:
        prod = jnp.sum(prod, axis=tuple(range(1, prod.ndim - 1)))
    return jnp.sum(jnp.square(prod), axis=-1)


def segmented_flops(t: int, p_in: int, p_out: int, n_seg: int,
                    token_block: int = 1024) -> float:
    """XLA segmented-direct cost: outer products + segment-sum scatter
    over the token blocks, the dense (n_seg, p_in, p_out) carry add per
    block, and the final square-reduce. The carry term is what makes
    many-segment stats expensive on this backend — the scan keeps the
    whole per-segment partial-gradient tensor live across every block."""
    n_tb = max(1, math.ceil(t / token_block))
    return float(2.0 * t * p_in * p_out
                 + n_tb * n_seg * p_in * p_out
                 + 2.0 * n_seg * p_in * p_out)


#: Effective-throughput weight on the XLA segmented formulation. Unlike
#: the dense gram/direct comparison — einsum vs kernel, both MXU matmuls
#: priced at par — the scan/segment_sum path never touches the MXU: its
#: outer products, scatter-adds and dense carry adds are all VPU work
#: (8×128 lanes vs the 128×128 systolic array, a 16× per-element gap on
#: TPU; halved here as a fusion allowance). The Pallas side stays in raw
#: MXU flops, so the crossover lands where the padded kernel launch
#: stops being cheaper than VPU-rate scatter work.
XLA_SEGMENTED_VPU_WEIGHT = 8.0


def segmented_cost(t: int, p_in: int, p_out: int, n_seg: int, *,
                   use_pallas: bool = False,
                   token_block: int = 1024) -> float:
    """Two-sided cost of one segmented stat, same contract as
    ``dense_cost``: the Pallas side is priced at the padded launch tiles
    of the sort-based kernel (static work-item grid, dummy items and
    chunk padding included), the XLA side at the scan/segment_sum
    formulation's flops weighted by ``XLA_SEGMENTED_VPU_WEIGHT`` (that
    path runs on the VPU, not the MXU)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.segmented_cost(t, p_in, p_out, n_seg)
    return XLA_SEGMENTED_VPU_WEIGHT * segmented_flops(t, p_in, p_out, n_seg,
                                                      token_block)


def pick_segmented(t: int, p_in: int, p_out: int, n_seg: int, *,
                   use_pallas: bool = False,
                   token_block: int = 1024) -> str:
    """'pallas' | 'xla' — the backend the cost model would run a
    segmented stat on (the segmented analogue of ``pick_method``).
    Without ``use_pallas`` the kernel is not on the table."""
    if not use_pallas:
        return "xla"
    c_pl = segmented_cost(t, p_in, p_out, n_seg, use_pallas=True)
    c_xla = segmented_cost(t, p_in, p_out, n_seg, token_block=token_block)
    return "pallas" if c_pl <= c_xla else "xla"


def crossover_t(p_in: int, p_out: int, n_seg: int, *,
                t_max: int = 1 << 20, token_block: int = 1024) -> int:
    """Smallest token count at which the Pallas segmented kernel beats
    the XLA scan under the two-sided cost model (binary search; the
    ratio is monotone in t once the fixed run-table overhead — the
    min(n_seg+1, t) work items — is amortized). Returns ``t_max`` when
    the kernel never wins (many tiny segments)."""
    def pl_wins(t):
        return pick_segmented(t, p_in, p_out, n_seg, use_pallas=True,
                              token_block=token_block) == "pallas"
    lo, hi = 1, t_max
    if not pl_wins(hi):
        return t_max
    while lo < hi:
        mid = (lo + hi) // 2
        if pl_wins(mid):
            hi = mid
        else:
            lo = mid + 1
    return lo


def stat_direct_segmented(h: jax.Array, zbar: jax.Array, seg_ids: jax.Array,
                          n_examples: int, chunk_in: int = 128,
                          token_block: int = 1024,
                          method: str = "auto",
                          use_pallas: bool = False) -> jax.Array:
    """Exact per-example norms for token-major layers (MoE expert buffers).

    h: (T, p_in), zbar: (T, p_out), seg_ids: (T,) example id per row
    (rows with seg_id >= n_examples — padding / dropped tokens — are
    discarded; ids are clamped into the drop bucket up front, so callers
    may pass any sentinel ≥ n_examples). Computes
    s_j = ||Σ_{t: seg=j} h_t z̄_tᵀ||². FLOPs ≈ T·p_in·p_out — the cost
    of one dW einsum.

    ``method`` picks the backend: ``"xla"`` is the scan/segment_sum
    formulation below (the regression oracle and CPU fallback);
    ``"pallas"`` the sort-based kernel (kernels/segmented_norm.py);
    ``"auto"`` consults ``pick_segmented`` — with ``use_pallas=False``
    it always resolves to the XLA path.

    Edge cases are handled explicitly rather than leaning on scatter
    out-of-bounds semantics: n_examples < 1 and T == 0 return empty /
    zero stats, all-dropped rows (every seg_id ≥ n_examples — e.g. a
    fully capacity-dropped token block) contribute nothing on either
    backend, and n_examples == 1 exercises the same clamped path as any
    other batch (regression-tested in tests/test_segmented.py).
    """
    t, p_in = h.shape
    p_out = zbar.shape[-1]
    if n_examples < 1:
        return jnp.zeros((max(n_examples, 0),), _ACC_DTYPE)
    if t == 0:
        return jnp.zeros((n_examples,), _ACC_DTYPE)
    # explicit drop bucket: every invalid row lands in segment n_examples
    seg_ids = jnp.minimum(seg_ids.astype(jnp.int32), n_examples)
    if method == "auto":
        method = pick_segmented(t, p_in, p_out, n_examples,
                                use_pallas=use_pallas,
                                token_block=token_block)
    if method == "pallas":
        from repro.kernels import ops as kops
        return kops.segmented_norm(h.astype(_ACC_DTYPE),
                                   zbar.astype(_ACC_DTYPE),
                                   seg_ids, n_examples)
    if method != "xla":
        raise ValueError(f"unknown segmented method {method!r}")
    h = h.astype(_ACC_DTYPE)
    zbar = zbar.astype(_ACC_DTYPE)

    k_in = max(1, math.ceil(p_in / chunk_in))
    if k_in * chunk_in != p_in:
        h = jnp.pad(h, ((0, 0), (0, k_in * chunk_in - p_in)))
    n_tb = max(1, math.ceil(t / token_block))
    if n_tb * token_block != t:
        pad_t = n_tb * token_block - t
        h = jnp.pad(h, ((0, pad_t), (0, 0)))
        zbar = jnp.pad(zbar, ((0, pad_t), (0, 0)))
        seg_ids = jnp.pad(seg_ids, (0, pad_t),
                          constant_values=n_examples)  # → dropped
    hc = jnp.moveaxis(h.reshape(n_tb, token_block, k_in, chunk_in), 2, 0)
    zc = zbar.reshape(n_tb, token_block, p_out)
    sc = seg_ids.reshape(n_tb, token_block)

    def per_chunk(carry_out, h_k):  # h_k: (n_tb, token_block, chunk_in)
        def per_block(g_acc, xs):
            h_b, z_b, s_b = xs
            outer = h_b[:, :, None] * z_b[:, None, :]
            # one extra segment catches padding/dropped rows (ids are
            # pre-clamped, so n_examples+1 segments is exhaustive)
            g = jax.ops.segment_sum(outer, s_b, num_segments=n_examples + 1)
            return g_acc + g[:n_examples], None

        g0 = jnp.zeros((n_examples, h_k.shape[-1], p_out), _ACC_DTYPE)
        g, _ = jax.lax.scan(per_block, g0, (h_k, zc, sc))
        return carry_out + jnp.sum(jnp.square(g), axis=(1, 2)), None

    init = jnp.zeros((n_examples,), _ACC_DTYPE)
    out, _ = jax.lax.scan(per_chunk, init, hc)
    return out


def stat_embedding(token_ids: jax.Array, zbar: jax.Array) -> jax.Array:
    """Per-example norm for an embedding table E, z_t = E[x_t].

    grad_E L^(j) = scatter-add of z̄ rows by token id. Exact norm via
    sort + segment-sum: s_j = Σ_v ||Σ_{t: x_t=v} z̄_t||². O(S·d + S log S)
    — the one-hot Gram (equality matrix) computed without S².

    token_ids: (B, S) int, zbar: (B, S, d).
    """
    zbar = zbar.astype(_ACC_DTYPE)

    def one(ids, z):
        order = jnp.argsort(ids)
        ids_s = ids[order]
        z_s = z[order]
        # segment id = rank of each distinct token value
        new_seg = jnp.concatenate([jnp.ones((1,), jnp.int32),
                                   (ids_s[1:] != ids_s[:-1]).astype(jnp.int32)])
        seg = jnp.cumsum(new_seg) - 1
        summed = jax.ops.segment_sum(z_s, seg, num_segments=ids.shape[0])
        return jnp.sum(jnp.square(summed))

    return jax.vmap(one)(token_ids, zbar)
