"""Importance-sampled optimization (Zhao & Zhang 2014) — the paper's §1
motivating application, built on the cheap per-example norms.

The optimal (variance-minimizing) sampling distribution for SGD is
p_j ∝ ||∇L^(j)||. With the accumulator taps, those norms cost a
forward + activation-backprop over the candidate pool — no per-example
gradient materialization — after which we sample a minibatch and apply
unbiased importance weights 1/(N·p_j).

This module is the sampling math; the fused execution lives in the
``Importance(k, ...)`` consumer of the plan layer (``core.plan``):
norms-on-pool → ``sample`` → ``gather_batch`` → the same plan continues
on the sub-batch, with the importance weights folded into the one
reweighted backward (DESIGN.md §9).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class ImportanceSample(NamedTuple):
    indices: jax.Array     # (k,) selected candidate rows
    weights: jax.Array     # (k,) unbiased importance weights
    probs: jax.Array       # (N,) the sampling distribution used


_DEGENERATE_MSG = ("importance.sampling_distribution: norm pool is "
                   "all-zero or non-finite; falling back to the uniform "
                   "distribution")


def sampling_distribution(sq_norms: jax.Array, smoothing: float = 0.0,
                          eps: float = 1e-12) -> jax.Array:
    """p_j ∝ ||g_j|| with optional uniform smoothing (stability knob:
    p ← (1-λ)p + λ/N, keeps weights bounded).

    A degenerate pool — all-zero norms (e.g. a freshly-zeroed model or
    a fully-masked batch) or any non-finite entry (NaN/inf poisoning) —
    would yield a zero/NaN distribution that ``jax.random.choice``
    mishandles; it falls back to the uniform distribution with a
    warning instead.
    """
    if sq_norms.ndim == 2:
        sq_norms = jnp.sum(sq_norms, axis=-1)
    norms = jnp.sqrt(jnp.maximum(sq_norms.astype(jnp.float32), 0.0))
    total = jnp.sum(norms)
    n = norms.shape[0]
    degenerate = jnp.logical_or(jnp.logical_not(jnp.isfinite(total)),
                                total <= eps)
    _warn_degenerate(degenerate)
    p = jnp.where(degenerate, jnp.full_like(norms, 1.0 / n),
                  norms / jnp.where(degenerate, 1.0, total + eps))
    if smoothing > 0.0:
        p = (1.0 - smoothing) * p + smoothing / n
    return p


def _warn_degenerate(degenerate) -> None:
    """Python warning on concrete values; a traced debug print (fires
    only when the predicate is true) under jit."""
    if not isinstance(degenerate, jax.core.Tracer):
        if bool(degenerate):
            warnings.warn(_DEGENERATE_MSG, RuntimeWarning, stacklevel=3)
        return
    jax.lax.cond(degenerate,
                 lambda: jax.debug.print(_DEGENERATE_MSG),
                 lambda: None)


def sample(rng: jax.Array, sq_norms: jax.Array, k: int,
           smoothing: float = 0.1, replace: bool = True) -> ImportanceSample:
    """Draw k examples ∝ gradient norm; weights make the estimator unbiased."""
    from repro.core.provenance import mark_rng, mark_sample
    p = sampling_distribution(sq_norms, smoothing)
    n = p.shape[0]
    rng = mark_rng(rng, purpose="importance")
    idx = mark_sample(
        jax.random.choice(rng, n, shape=(k,), replace=replace, p=p), k=k)
    # unbiased for the batch SUM (paper §2's C = Σ_j L^(j)):
    # E[Σ_k v/(k·p)] = Σ v
    w = 1.0 / (k * p[idx] + 1e-12)
    return ImportanceSample(idx, w, p)


def gather_batch(batch, indices, batch_size: Optional[int] = None):
    """Select rows ``indices`` from the leaves of a batch pytree that
    actually carry the batch axis.

    Scalar and static leaves (mask flags, step counters, python
    numbers) pass through untouched — blindly ``jnp.take``-ing them
    crashes or silently corrupts. A leaf is indexed iff it has rank
    ≥ 1 and its leading extent equals the batch size; when
    ``batch_size`` is not given it is inferred from the array leaves
    and must be unambiguous.
    """
    def is_arr(x):
        return hasattr(x, "ndim") and hasattr(x, "shape")

    if batch_size is None:
        sizes = {x.shape[0] for x in jax.tree_util.tree_leaves(batch)
                 if is_arr(x) and x.ndim >= 1}
        if len(sizes) > 1:
            raise ValueError(
                f"batch leaves carry different leading extents "
                f"{sorted(sizes)}; pass batch_size= to pick which leaves "
                f"hold the example axis")
        batch_size = sizes.pop() if sizes else None

    def take(x):
        if is_arr(x) and x.ndim >= 1 and x.shape[0] == batch_size:
            return jnp.take(x, indices, axis=0)
        return x

    return jax.tree_util.tree_map(take, batch)


def effective_sample_size(weights: jax.Array) -> jax.Array:
    """ESS = (Σw)²/Σw² — diagnostic for weight degeneracy."""
    return jnp.square(jnp.sum(weights)) / (jnp.sum(jnp.square(weights)) + 1e-12)
