"""Importance-sampled optimization (Zhao & Zhang 2014) — the paper's §1
motivating application, built on the cheap per-example norms.

The optimal (variance-minimizing) sampling distribution for SGD is
p_j ∝ ||∇L^(j)||. With the accumulator taps, those norms cost a
forward + activation-backprop over the candidate pool — no per-example
gradient materialization — after which we sample a minibatch and apply
unbiased importance weights 1/(N·p_j).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ImportanceSample(NamedTuple):
    indices: jax.Array     # (k,) selected candidate rows
    weights: jax.Array     # (k,) unbiased importance weights
    probs: jax.Array       # (N,) the sampling distribution used


def sampling_distribution(sq_norms: jax.Array, smoothing: float = 0.0,
                          eps: float = 1e-12) -> jax.Array:
    """p_j ∝ ||g_j|| with optional uniform smoothing (stability knob:
    p ← (1-λ)p + λ/N, keeps weights bounded)."""
    if sq_norms.ndim == 2:
        sq_norms = jnp.sum(sq_norms, axis=-1)
    norms = jnp.sqrt(jnp.maximum(sq_norms, 0.0))
    p = norms / (jnp.sum(norms) + eps)
    if smoothing > 0.0:
        n = sq_norms.shape[0]
        p = (1.0 - smoothing) * p + smoothing / n
    return p


def sample(rng: jax.Array, sq_norms: jax.Array, k: int,
           smoothing: float = 0.1, replace: bool = True) -> ImportanceSample:
    """Draw k examples ∝ gradient norm; weights make the estimator unbiased."""
    p = sampling_distribution(sq_norms, smoothing)
    n = p.shape[0]
    idx = jax.random.choice(rng, n, shape=(k,), replace=replace, p=p)
    # unbiased for the batch SUM (paper §2's C = Σ_j L^(j)):
    # E[Σ_k v/(k·p)] = Σ v
    w = 1.0 / (k * p[idx] + 1e-12)
    return ImportanceSample(idx, w, p)


def gather_batch(batch, indices):
    """Select rows `indices` from every leaf of a batch pytree."""
    return jax.tree_util.tree_map(lambda x: jnp.take(x, indices, axis=0), batch)


def effective_sample_size(weights: jax.Array) -> jax.Array:
    """ESS = (Σw)²/Σw² — diagnostic for weight degeneracy."""
    return jnp.square(jnp.sum(weights)) / (jnp.sum(jnp.square(weights)) + 1e-12)
