"""Provenance markers — identity ops that make the DP pipeline's
privacy-critical values identifiable in a traced jaxpr (DESIGN.md §12).

The plan layer's DP invariants (clip applied per example *before* the
batch sum, noise injected exactly once *after* the gradient psum, PRNG
keys never reused) are properties of the traced program, but a raw
jaxpr gives the static analyzer nothing to anchor on: a clip
coefficient is just a ``min``, a noise sample just a ``mul`` of a
``random_bits``. ``pex_mark`` is a custom primitive that behaves as
the identity everywhere — impl, abstract eval, lowering (it vanishes
from HLO), jvp/transpose (linear pass-through), vmap — while carrying
a static ``(tag, meta)`` payload that survives into the jaxpr:

    e:f32[4] = pex_mark[tag=clip_coef meta=(('clip_norm', 1.0), ...)] d

``analysis.privacy`` walks the step jaxpr and treats each tag as a
semantic anchor:

  * ``clip_coef``  — the per-example (or per-token) clip coefficients,
    meta: clip_norm, eps, granularity;
  * ``grad_seed``  — a cotangent seed entering a backward application,
    meta: kind ∈ {'plain', 'norms', 'weighted'} (weighted = the
    clip × importance × user-weight product);
  * ``noise``      — one leaf's DP noise sample, meta: noise_std,
    scale, leaf index;
  * ``rng_use``    — a PRNG key at its point of consumption, meta:
    purpose + index (the key-lineage single-use check hangs off these).

Marker placement is production code (``core.passes``, ``core.plan``,
``core.clipping``, ``core.importance``) — the analyzer only *reads*
them, and the mutation corpus (tests/test_pexlint_mutation.py) proves
each invariant trips when the marked logic is broken.
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.extend import core as jex_core
from jax.interpreters import ad, batching, mlir

#: the primitive name as it appears in jaxpr equations
MARK_PRIMITIVE = "pex_mark"

#: known tags (an unknown tag in a trace is an analyzer error — it
#: means a marker was added without teaching the privacy pass about it)
TAG_CLIP = "clip_coef"
TAG_SEED = "grad_seed"
TAG_NOISE = "noise"
TAG_RNG = "rng_use"
TAG_SAMPLE = "sample_idx"
TAG_GLEAF = "grad_leaf"
KNOWN_TAGS = frozenset({TAG_CLIP, TAG_SEED, TAG_NOISE, TAG_RNG, TAG_SAMPLE,
                        TAG_GLEAF})

mark_p = jex_core.Primitive(MARK_PRIMITIVE)
mark_p.def_impl(lambda x, *, tag, meta: x)
mark_p.def_abstract_eval(lambda x, *, tag, meta: x)
mlir.register_lowering(mark_p, lambda ctx, x, *, tag, meta: [x])
# linear in x ⇒ jvp passes the tangent through; the transpose drops the
# marker from the cotangent (a marked value's adjoint is not itself a
# clip coefficient / noise sample — re-marking it would lie)
ad.deflinear2(mark_p, lambda ct, x, *, tag, meta: [ct])
batching.primitive_batchers[mark_p] = \
    lambda args, dims, *, tag, meta: (
        mark_p.bind(args[0], tag=tag, meta=meta), dims[0])


def _static(v):
    """Meta values must be hashable jaxpr params; traced values (rare —
    e.g. a σ computed on-device) degrade to None rather than failing
    the trace."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    try:
        return float(v)
    except Exception:
        return None


def mark(x, tag: str, **meta):
    """Identity on ``x``; records ``(tag, meta)`` in the jaxpr."""
    items = tuple(sorted((k, _static(v)) for k, v in meta.items()))
    return mark_p.bind(x, tag=tag, meta=items)


def mark_clip(c, *, clip_norm, eps, granularity: str):
    return mark(c, TAG_CLIP, clip_norm=clip_norm, eps=eps,
                granularity=granularity)


def mark_seed(seed, *, kind: str):
    """kind: 'plain' (unweighted fold / user-only weights absent),
    'norms' (the ones seed of the norms backward), 'weighted' (the
    clip × importance × user-weight product)."""
    return mark(seed, TAG_SEED, kind=kind)


def mark_noise(sample, *, noise_std, scale, leaf: int):
    return mark(sample, TAG_NOISE, noise_std=noise_std, scale=scale,
                leaf=leaf)


def mark_sample(indices, *, k: int):
    """Mark importance-sampling indices at the selection boundary.
    Selection lineage (which examples were drawn depends on the norms)
    is not *scaling* lineage — the privacy pass's clip-before-sum check
    must not confuse a norm-guided gather with an unclipped seed — so
    the analyzer launders seed taint here."""
    return mark(indices, TAG_SAMPLE, k=k)


def mark_grad_leaf(g, *, leaf: int):
    """Mark one summed-gradient leaf at the plan/optimizer boundary —
    after GNS reads the raw gradient, before noise and the apply. The
    traffic pass (``analysis.traffic``) anchors its per-leaf HBM-stream
    counting on these: every fusion component downstream that re-reads
    a full leaf-sized array derived from this marker is one more pass
    over the gradient in HBM."""
    return mark(g, TAG_GLEAF, leaf=leaf)


def mark_grad_tree(grads):
    """``mark_grad_leaf`` over every leaf of a gradient pytree, in the
    tree's flatten order (== the parameter tree's leaf order)."""
    from jax.tree_util import tree_flatten, tree_unflatten
    leaves, tree = tree_flatten(grads)
    return tree_unflatten(
        tree, [mark_grad_leaf(g, leaf=i) for i, g in enumerate(leaves)])


def mark_rng(key, *, purpose: str, index: Optional[int] = None):
    """Mark a PRNG key at its point of consumption. Every key that
    feeds a sampling primitive must pass through exactly one of these;
    two ``rng_use`` marks with the same key *lineage* are a reuse."""
    return mark(key, TAG_RNG, purpose=purpose, index=index)


def meta_dict(meta: Tuple) -> dict:
    """The ``meta`` param of a pex_mark equation, as a dict."""
    return dict(meta)
