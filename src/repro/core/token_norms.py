"""Per-TOKEN gradient norms — the paper's trick at token granularity.

The paper's §4 factorization is exact whenever a weight sees a unit of
data exactly once. Per *example* that's only true for MLPs; per *token*
it is true for every dense layer in every sequence model: token t's
contribution to ∂L/∂W is the rank-1 outer product h_t z̄_tᵀ, so

    s_{j,t} = ‖h_{j,t}‖² · ‖z̄_{j,t}‖²          (exactly paper §4)

with a (B, S) accumulator instead of (B,). Uses: token-level data
filtering/curriculum, influence diagnostics, per-token clipping.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def init_token_acc(batch: int, seq: int) -> jax.Array:
    return jnp.zeros((batch, seq), _F32)


@jax.custom_vjp
def token_dense(h: jax.Array, w: jax.Array, acc: jax.Array):
    """z = h @ w with per-token norm accumulation. h: (B, S, p_in),
    acc: (B, S)."""
    return jnp.einsum("bsi,io->bso", h, w), acc


def _fwd(h, w, acc):
    return token_dense(h, w, acc), (h, w)


def _bwd(res, cts):
    h, w = res
    zbar, acc_bar = cts
    dh = jnp.einsum("bso,io->bsi", zbar, w).astype(h.dtype)
    dw = jnp.einsum("bsi,bso->io", h, zbar).astype(w.dtype)
    stat = (jnp.sum(jnp.square(h.astype(_F32)), -1) *
            jnp.sum(jnp.square(zbar.astype(_F32)), -1))
    return dh, dw, acc_bar + stat


token_dense.defvjp(_fwd, _bwd)


class TokenNormResult(NamedTuple):
    loss: jax.Array
    sq_norms: jax.Array    # (B, S): Σ_layers ‖h_t‖²‖z̄_t‖²


def value_and_token_norms(loss_fn: Callable, params, batch,
                          batch_size: int, seq: int) -> TokenNormResult:
    """loss_fn(params, acc, batch) -> (loss_vec, acc_out, aux) where the
    model threads a (B, S) accumulator through `token_dense` taps."""
    acc0 = init_token_acc(batch_size, seq)

    def f(acc):
        loss_vec, acc_out, aux = loss_fn(params, acc, batch)
        return jnp.sum(loss_vec), (loss_vec, acc_out, aux)

    (loss, _), sq = jax.value_and_grad(f, has_aux=True)(acc0)
    return TokenNormResult(loss, sq)
