"""pex v2 ``Engine`` — one entry point for local, sharded, and
token-level per-example-gradient runs (DESIGN.md §7, §9).

The Engine is constructed once with the instrumentation policy and the
execution context, and every pass takes a **tap-collector loss**

    loss_fn(params, batch, tap) -> (loss_vec, aux)

(the v2 canonical signature; ``registry.make_loss_fn_v2`` builds one
for any registered arch). The consumer surface is declarative
(``core.plan``): ``step`` compiles a list of consumers into one fused
execution plan — a single tapped forward, an activation backward for
the norms every consumer shares, and at most one reweighted backward
whose per-example (or per-token) weights are the product of clip
coefficients, importance weights, and user loss weights:

    eng = Engine(PexSpec(method="auto"), mesh=mesh)
    res = eng.step(loss_fn, params, batch,
                   consumers=[pex.Clip(1.0), pex.Noise(0.5, rng),
                              pex.GNS()])
    res.grads, res.sq_norms, res.gns   # one compiled program

The four fixed-function methods (``value_and_norms``,
``value_grads_and_norms``, ``clipped_step``,
``gradient_noise_scale``) remain as one-line sugar over ``step``.

``granularity="token"`` swaps the accumulator layout to the per-token
``(B, S)`` map (``TokenLayout``) — same taps, same passes, token-level
norms; with a loss that registers its token map (``tap.token_loss``),
``Clip(C, granularity="token")`` reweights every token's loss term by
its own contribution norm in the same fused pass.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax

from repro.core import plan as plan_mod
from repro.core.passes import PexResult
from repro.core.plan import StepResult
from repro.core.taps import DISABLED, ExampleLayout, PexSpec, Tap, TokenLayout
from repro.dist import pex as _dpex


def infer_batch_size(batch) -> int:
    """Leading-axis extent shared by every batch leaf."""
    leaves = jax.tree_util.tree_leaves(batch)
    if not leaves:
        raise ValueError("cannot infer batch_size from an empty batch pytree")
    sizes = {leaf.shape[0] for leaf in leaves}
    if len(sizes) != 1:
        raise ValueError(f"batch leaves disagree on the leading (example) "
                         f"axis: {sorted(sizes)}; pass batch_size= explicitly")
    return sizes.pop()


def infer_seq_len(batch) -> int:
    """Second-axis extent of the sequence-shaped batch leaves; must be
    unambiguous (multi-sequence batches, e.g. encoder-decoder frames vs
    ids, need an explicit seq=)."""
    sizes = {leaf.shape[1] for leaf in jax.tree_util.tree_leaves(batch)
             if leaf.ndim >= 2}
    if len(sizes) == 1:
        return sizes.pop()
    if not sizes:
        raise ValueError("token granularity needs a (B, S, ...) batch leaf "
                         "to infer the sequence length; pass seq= explicitly")
    raise ValueError(f"batch leaves carry different sequence lengths "
                     f"{sorted(sizes)}; pass seq= explicitly to pick the "
                     f"tapped one")


class Engine:
    """Per-example-gradient engine bound to one (spec, mesh, policy).

    Parameters
    ----------
    spec:        ``PexSpec`` instrumentation policy (default: enabled,
                 method='auto'). ``taps.DISABLED`` gives a plain engine
                 (taps compile away; sq_norms are zeros).
    mesh:        ``jax.sharding.Mesh`` or None. A mesh routes every pass
                 through the ``dist.pex`` shard_map pipeline over
                 ``data_axes``; None runs single-device/GSPMD.
    clip_norm:   default clip threshold C for ``clipped_step``.
    noise_std:   default DP-SGD noise multiplier σ for ``clipped_step``
                 (noise σ·C is added once, after the gradient psum).
    granularity: 'example' → (B, G) accumulator (per-group columns from
                 ``spec.groups``); 'token' → (B, S) accumulator.
    """

    def __init__(self, spec: Optional[PexSpec] = None, *,
                 mesh=None, data_axes: Sequence[str] = ("data",),
                 clip_norm: Optional[float] = None, noise_std: float = 0.0,
                 granularity: str = "example"):
        if granularity not in ("example", "token"):
            raise ValueError(f"granularity must be 'example' or 'token', "
                             f"got {granularity!r}")
        self.spec = spec if spec is not None else PexSpec()
        self.mesh = mesh
        self.data_axes = (data_axes,) if isinstance(data_axes, str) \
            else tuple(data_axes)
        self.clip_norm = clip_norm
        self.noise_std = noise_std
        self.granularity = granularity

    # ------------------------------------------------------------------
    def _adapt(self, loss_fn: Callable, layout,
               want_token_map: bool = False) -> Callable:
        """Tap-collector loss → the explicit-acc loss the plan layer
        consumes; the Tap is created inside the traced function, per
        trace. Signature of the result:
        ``acc_loss(params, acc, batch) -> (loss_vec, token_map|None,
        acc_out, aux)`` (acc=None ⇒ inert tap ⇒ the plain model)."""
        def acc_loss(params, acc, batch):
            tap = Tap(self.spec, acc=acc, layout=layout)
            loss_vec, aux = loss_fn(params, batch, tap)
            tok = tap.token_losses() if want_token_map else None
            return loss_vec, tok, tap.carry(), aux
        return acc_loss

    # ------------------------------------------------------------------
    def step(self, loss_fn: Callable, params, batch,
             consumers: Sequence = (), *,
             loss_weights: Optional[jax.Array] = None,
             batch_size: Optional[int] = None,
             seq: Optional[int] = None) -> StepResult:
        """Compile a consumer list into one fused pass and run it.

        ``consumers`` is any subset of ``{pex.Norms(), pex.Grads(),
        pex.Clip(C, granularity=...), pex.Noise(σ, rng),
        pex.Importance(k, ...), pex.GNS()}`` — each a ~30-line
        declarative object; composition and weight semantics are
        DESIGN.md §9. ``loss_weights`` is an optional (B,) user weight
        vector folded into the same reweighted backward. With
        ``consumers=()`` the program is the plain forward."""
        plan = plan_mod.analyze(consumers,
                                engine_granularity=self.granularity)
        b = batch_size if batch_size is not None else infer_batch_size(batch)
        if plan.token_norms:
            layout = TokenLayout(seq if seq is not None
                                 else infer_seq_len(batch))
        else:
            layout = ExampleLayout(self.spec.n_groups)
        acc_loss = self._adapt(loss_fn, layout,
                               want_token_map=plan.token_weighted)
        if self.mesh is None:
            return plan_mod.execute(plan, acc_loss, params, batch, b,
                                    layout, loss_weights=loss_weights)
        return _dpex.plan_step(plan, acc_loss, params, batch, b,
                               mesh=self.mesh, data_axes=self.data_axes,
                               layout=layout, loss_weights=loss_weights)

    # -- fixed-function sugar (one line each over `step`) ---------------
    def value_and_norms(self, loss_fn: Callable, params, batch, *,
                        batch_size: Optional[int] = None,
                        seq: Optional[int] = None) -> PexResult:
        """Norms-only pass (paper §5 cheap pass): no ``dW`` chains."""
        r = self.step(loss_fn, params, batch, [plan_mod.Norms()],
                      batch_size=batch_size, seq=seq)
        return PexResult(r.loss, r.loss_vec, r.aux, r.sq_norms)

    def value_grads_and_norms(self, loss_fn: Callable, params, batch, *,
                              batch_size: Optional[int] = None,
                              seq: Optional[int] = None) -> PexResult:
        """Summed gradients AND all per-example norms in one backward."""
        r = self.step(loss_fn, params, batch,
                      [plan_mod.Norms(), plan_mod.Grads()],
                      batch_size=batch_size, seq=seq)
        return PexResult(r.loss, r.loss_vec, r.aux, r.sq_norms, r.grads)

    def clipped_step(self, loss_fn: Callable, params, batch, *,
                     rng: Optional[jax.Array] = None,
                     clip_norm: Optional[float] = None,
                     noise_std: Optional[float] = None,
                     batch_size: Optional[int] = None,
                     seq: Optional[int] = None) -> PexResult:
        """Per-example clipping (paper §6 two-pass ghost form), plus
        DP-SGD noise when ``noise_std > 0`` (needs ``rng``). On a
        token-granularity engine this is per-token clipping."""
        c = clip_norm if clip_norm is not None else self.clip_norm
        if c is None:
            raise ValueError("clipped_step needs clip_norm: set it on the "
                             "Engine or pass clip_norm= per call")
        sigma = noise_std if noise_std is not None else self.noise_std
        consumers = [plan_mod.Clip(c, granularity=self.granularity)]
        if sigma and sigma > 0.0:
            # on a token engine, analyze() rejects the defaulted scale
            # (per-token C is not a per-example sensitivity)
            consumers.append(plan_mod.Noise(sigma, rng))
        r = self.step(loss_fn, params, batch, consumers,
                      batch_size=batch_size, seq=seq)
        return PexResult(r.loss, r.loss_vec, r.aux, r.sq_norms, r.grads)

    def gradient_noise_scale(self, loss_fn: Callable, params, batch, *,
                             batch_size: Optional[int] = None) -> jax.Array:
        """Critical-batch diagnostic B_simple = tr(Σ)/||G||² from one
        grads+norms pass (Gray et al. 2024 / McCandlish et al. 2018)."""
        return self.step(loss_fn, params, batch, [plan_mod.GNS()],
                         batch_size=batch_size).gns

    # ------------------------------------------------------------------
    def verify(self, loss_fn: Callable, params, batch,
               consumers: Sequence = (), *, allow: Sequence[str] = (),
               batch_size: Optional[int] = None,
               seq: Optional[int] = None, cfg=None,
               backend: str = "tpu", deep: bool = True,
               cost: bool = False, optimizer: str = "adamw",
               profile: Optional[str] = None, chips: int = 1,
               model: Optional[str] = None):
        """Static verification of a (model, plan) pair — trace-only,
        no compilation, safe on abstract ``ShapeDtypeStruct`` params
        and batches (DESIGN.md §10, §12).

        Runs the pexlint passes against THIS engine's spec, mesh, and
        granularity: plan analysis of ``consumers`` (one list or a
        sequence of lists), tap-coverage verification of ``loss_fn``
        (``allow`` declares intentionally-untapped parameter path
        substrings; ``registry.untapped_allowlist`` has the registered
        archs' tables), launch validation of every Pallas schedule
        the trace's tap sites imply (``cfg`` additionally checks the
        config-derived production geometries), and — with ``deep`` —
        the privacy-flow, collective-layout (mesh engines), and
        determinism passes over full step traces. With ``cost``, the
        traffic/cost passes additionally trace the full training step
        — plan execution plus the ``optimizer`` apply — and attach
        ``TrafficReport``/``CostReport`` tuples (predicted on hardware
        ``profile`` × ``chips``). Returns a
        ``repro.analysis.VerifyReport``; ``.ok`` /
        ``.raise_if_errors()`` gate on it."""
        from repro.analysis.verify import verify as _verify
        return _verify(loss_fn, params, batch, consumers,
                              spec=self.spec,
                              granularity=self.granularity, allow=allow,
                              batch_size=batch_size, seq=seq, cfg=cfg,
                              backend=backend, mesh=self.mesh,
                              data_axes=self.data_axes, deep=deep,
                              cost=cost, optimizer=optimizer,
                              profile=profile, chips=chips, model=model)

    # ------------------------------------------------------------------
    def tap(self, batch_size: int, *, seq: Optional[int] = None) -> Tap:
        """Standalone live Tap for hand-rolled transforms (the Engine
        passes above create their own)."""
        layout = TokenLayout(seq) if self.granularity == "token" \
            else ExampleLayout(self.spec.n_groups)
        return Tap(self.spec, acc=layout.init(batch_size), layout=layout)


#: Engine with instrumentation off: every pass is the plain model
#: (sq_norms are zeros; taps compile away).
def plain_engine(**kw) -> Engine:
    return Engine(DISABLED, **kw)
