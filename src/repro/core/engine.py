"""pex v2 ``Engine`` — one entry point for local, sharded, and
token-level per-example-gradient runs (DESIGN.md §7).

The Engine is the one public entry point (the old ``core.api`` +
``dist.pex.api_for`` split is gone): it is constructed once with the
instrumentation policy and the execution context, and every pass takes
a **tap-collector loss**

    loss_fn(params, batch, tap) -> (loss_vec, aux)

(the v2 canonical signature; ``registry.make_loss_fn_v2`` builds one
for any registered arch). The Engine creates the ``Tap`` inside the
traced function, infers the batch size from the batch pytree, and
dispatches the local path (``mesh=None``) or the ``shard_map``
pipeline (``dist.pex``) — per-example quantities stay batch-sharded,
only gradients/loss cross devices.

    eng = Engine(PexSpec(method="auto"), mesh=mesh, clip_norm=1.0)
    res = eng.value_grads_and_norms(loss_fn, params, batch)
    res = eng.clipped_step(loss_fn, params, batch, rng=key)   # DP-SGD
    bs  = eng.gradient_noise_scale(loss_fn, params, batch)    # B_simple

``granularity="token"`` swaps the accumulator layout to the per-token
``(B, S)`` map (``TokenLayout``) — same taps, same passes, token-level
norms — replacing the old parallel ``core.token_norms`` stack.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax

from repro.core import passes
from repro.core.passes import PexResult
from repro.core.taps import DISABLED, ExampleLayout, PexSpec, Tap, TokenLayout
from repro.dist import pex as _dpex


def infer_batch_size(batch) -> int:
    """Leading-axis extent shared by every batch leaf."""
    leaves = jax.tree_util.tree_leaves(batch)
    if not leaves:
        raise ValueError("cannot infer batch_size from an empty batch pytree")
    sizes = {leaf.shape[0] for leaf in leaves}
    if len(sizes) != 1:
        raise ValueError(f"batch leaves disagree on the leading (example) "
                         f"axis: {sorted(sizes)}; pass batch_size= explicitly")
    return sizes.pop()


def infer_seq_len(batch) -> int:
    """Second-axis extent of the sequence-shaped batch leaves; must be
    unambiguous (multi-sequence batches, e.g. encoder-decoder frames vs
    ids, need an explicit seq=)."""
    sizes = {leaf.shape[1] for leaf in jax.tree_util.tree_leaves(batch)
             if leaf.ndim >= 2}
    if len(sizes) == 1:
        return sizes.pop()
    if not sizes:
        raise ValueError("token granularity needs a (B, S, ...) batch leaf "
                         "to infer the sequence length; pass seq= explicitly")
    raise ValueError(f"batch leaves carry different sequence lengths "
                     f"{sorted(sizes)}; pass seq= explicitly to pick the "
                     f"tapped one")


class Engine:
    """Per-example-gradient engine bound to one (spec, mesh, policy).

    Parameters
    ----------
    spec:        ``PexSpec`` instrumentation policy (default: enabled,
                 method='auto'). ``taps.DISABLED`` gives a plain engine
                 (taps compile away; sq_norms are zeros).
    mesh:        ``jax.sharding.Mesh`` or None. A mesh routes every pass
                 through the ``dist.pex`` shard_map pipeline over
                 ``data_axes``; None runs single-device/GSPMD.
    clip_norm:   default clip threshold C for ``clipped_step``.
    noise_std:   default DP-SGD noise multiplier σ for ``clipped_step``
                 (noise σ·C is added once, after the gradient psum).
    granularity: 'example' → (B, G) accumulator (per-group columns from
                 ``spec.groups``); 'token' → (B, S) accumulator.
    """

    def __init__(self, spec: Optional[PexSpec] = None, *,
                 mesh=None, data_axes: Sequence[str] = ("data",),
                 clip_norm: Optional[float] = None, noise_std: float = 0.0,
                 granularity: str = "example"):
        if granularity not in ("example", "token"):
            raise ValueError(f"granularity must be 'example' or 'token', "
                             f"got {granularity!r}")
        self.spec = spec if spec is not None else PexSpec()
        self.mesh = mesh
        self.data_axes = (data_axes,) if isinstance(data_axes, str) \
            else tuple(data_axes)
        self.clip_norm = clip_norm
        self.noise_std = noise_std
        self.granularity = granularity

    # ------------------------------------------------------------------
    def _layout(self, batch, seq: Optional[int]):
        if self.granularity == "token":
            return TokenLayout(seq if seq is not None
                               else infer_seq_len(batch))
        return ExampleLayout(self.spec.n_groups)

    def _adapt(self, loss_fn: Callable, layout) -> Callable:
        """Tap-collector loss → the explicit-acc loss the pass layer
        (core.passes) consumes; the Tap is created inside the traced
        function, per trace."""
        def v1_loss(params, acc, batch):
            tap = Tap(self.spec, acc=acc, layout=layout)
            loss_vec, aux = loss_fn(params, batch, tap)
            return loss_vec, tap.carry(), aux
        return v1_loss

    def _run(self, fn, loss_fn, params, batch, batch_size, seq, **kw):
        b = batch_size if batch_size is not None else infer_batch_size(batch)
        layout = self._layout(batch, seq)
        v1_loss = self._adapt(loss_fn, layout)
        if self.mesh is None:
            return getattr(passes, fn)(v1_loss, params, batch, self.spec, b,
                                       layout=layout, **kw)
        return getattr(_dpex, fn)(v1_loss, params, batch, self.spec, b,
                                  mesh=self.mesh, data_axes=self.data_axes,
                                  layout=layout, **kw)

    # ------------------------------------------------------------------
    def value_and_norms(self, loss_fn: Callable, params, batch, *,
                        batch_size: Optional[int] = None,
                        seq: Optional[int] = None) -> PexResult:
        """Norms-only pass (paper §5 cheap pass): no ``dW`` chains."""
        return self._run("value_and_norms", loss_fn, params, batch,
                         batch_size, seq)

    def value_grads_and_norms(self, loss_fn: Callable, params, batch, *,
                              batch_size: Optional[int] = None,
                              seq: Optional[int] = None) -> PexResult:
        """Summed gradients AND all per-example norms in one backward."""
        return self._run("value_grads_and_norms", loss_fn, params, batch,
                         batch_size, seq)

    def clipped_step(self, loss_fn: Callable, params, batch, *,
                     rng: Optional[jax.Array] = None,
                     clip_norm: Optional[float] = None,
                     noise_std: Optional[float] = None,
                     batch_size: Optional[int] = None) -> PexResult:
        """Per-example clipping (paper §6 two-pass ghost form), plus
        DP-SGD noise when ``noise_std > 0`` (needs ``rng``)."""
        if self.granularity == "token":
            raise NotImplementedError(
                "clipped_step reweights the (B,) per-example losses; "
                "per-token clip coefficients have no loss to reweight — "
                "use granularity='example'")
        c = clip_norm if clip_norm is not None else self.clip_norm
        if c is None:
            raise ValueError("clipped_step needs clip_norm: set it on the "
                             "Engine or pass clip_norm= per call")
        sigma = noise_std if noise_std is not None else self.noise_std
        passes.check_noise_args(sigma, rng)
        return self._run("clipped_value_and_grads", loss_fn, params, batch,
                         batch_size, None, clip_norm=c, noise_std=sigma,
                         noise_rng=rng)

    def gradient_noise_scale(self, loss_fn: Callable, params, batch, *,
                             batch_size: Optional[int] = None) -> jax.Array:
        """Critical-batch diagnostic B_simple = tr(Σ)/||G||² from one
        grads+norms pass (Gray et al. 2024 / McCandlish et al. 2018)."""
        if self.granularity == "token":
            raise NotImplementedError(
                "gradient_noise_scale needs per-example ||g_j||²; "
                "per-token norms do not sum to them (cross-token terms) — "
                "use granularity='example'")
        b = batch_size if batch_size is not None else infer_batch_size(batch)
        res = self.value_grads_and_norms(loss_fn, params, batch,
                                         batch_size=b)
        return _dpex.gradient_noise_scale(res.sq_norms, res.grads,
                                          batch_size=b)

    # ------------------------------------------------------------------
    def tap(self, batch_size: int, *, seq: Optional[int] = None) -> Tap:
        """Standalone live Tap for hand-rolled transforms (the Engine
        passes above create their own)."""
        layout = self._layout(None, seq) if self.granularity == "token" \
            else ExampleLayout(self.spec.n_groups)
        return Tap(self.spec, acc=layout.init(batch_size), layout=layout)


#: Engine with instrumentation off: every pass is the plain model
#: (sq_norms are zeros; taps compile away).
def plain_engine(**kw) -> Engine:
    return Engine(DISABLED, **kw)
