"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state. The dry-run entry point sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; everything else (tests, benches) sees the real single device.
"""
from __future__ import annotations

import jax

from repro.dist.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one 256-chip v5e pod) or 2×16×16 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))
