import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline probe runner (§Roofline inputs).

Compiles each arch's 1–3-layer *unrolled* probe variants at full width ×
full shape × single-pod mesh, extrapolates FLOPs/bytes/collective-bytes
to the full depth (exact for homogeneous stacks), joins with the
full-program dry-run memory analysis, and writes
experiments/roofline/<arch>__<shape>.json.

    PYTHONPATH=src python -m repro.launch.probes --all
    PYTHONPATH=src python -m repro.launch.probes --arch qwen2-7b --shape train_4k --pex-method gram
"""

import argparse
import dataclasses
import json
import traceback

from repro.configs.common import SHAPES
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.roofline.analysis import (build_roofline, mfu, model_flops,
                                     n_active_for, probe_metrics)


def run_probes(arch_id: str, shape_name: str, mesh, *,
               pex_method: str = "direct", pex_on: bool = True,
               out_dir: str = "experiments/roofline",
               dryrun_dir: str = "experiments/dryrun",
               tag: str = "", verbose: bool = True):
    aspec = registry.get(arch_id)
    if shape_name in aspec.skip_shapes:
        if verbose:
            print(f"[SKIP] {arch_id} × {shape_name}: {aspec.skip_reason}")
        return None
    shape = SHAPES[shape_name]
    metrics = []
    for i, pcfg in enumerate(aspec.probes()):
        res, _ = lower_cell(arch_id, shape_name, mesh, False,
                            cfg_override=pcfg, pex_method=pex_method,
                            pex_on=pex_on, donate=False)
        assert res.ok, res.error
        metrics.append(probe_metrics(res))
        if verbose:
            print(f"  probe{i} ({pcfg.name if hasattr(pcfg, 'name') else i}):"
                  f" flops={res.flops:.3g} coll={res.coll_bytes.get('total', 0):.3g}")
    full = aspec.combine(metrics)

    # memory + n_params from the full-program dry-run cell
    cell_path = os.path.join(dryrun_dir,
                             f"{arch_id}__{shape_name}__16x16.json")
    peak = 0.0
    n_total = 0.0
    if os.path.exists(cell_path):
        cell = json.load(open(cell_path))
        peak = cell["peak_bytes_per_dev"]
        n_total = cell["n_params"]
    cfg = aspec.full()
    n_act = n_active_for(arch_id, n_total, cfg)
    r = build_roofline(arch_id, shape_name, "16x16", full,
                       model_flops(shape, n_act), peak)
    d = dataclasses.asdict(r)
    d["mfu_bound"] = mfu(r)
    d["n_active"] = n_act
    d["coll_breakdown"] = {k: full[k] for k in
                           ("coll_ar", "coll_ag", "coll_rs", "coll_a2a",
                            "coll_cp")}
    d["pex_method"] = pex_method
    d["pex_on"] = pex_on
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(out_dir,
                           f"{arch_id}__{shape_name}{suffix}.json"), "w") as f:
        json.dump(d, f, indent=1)
    if verbose:
        print(f"[ROOF] {arch_id} × {shape_name}: "
              f"compute={r.t_compute * 1e3:.2f}ms memory={r.t_memory * 1e3:.2f}ms "
              f"coll={r.t_collective * 1e3:.2f}ms → {r.bottleneck}-bound; "
              f"useful={r.useful_ratio:.2f} mfu_bound={d['mfu_bound']:.2f}")
    return d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--pex-method", default="direct")
    ap.add_argument("--no-pex", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    archs = sorted(registry.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    failures = 0
    for arch in archs:
        for shp in shapes:
            try:
                run_probes(arch, shp, mesh, pex_method=args.pex_method,
                           pex_on=not args.no_pex, tag=args.tag,
                           out_dir=args.out)
            except Exception:
                failures += 1
                print(f"[FAIL] {arch} × {shp}\n{traceback.format_exc()[-1500:]}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
