"""Elastic soak harness: a simulated N-host world under a fault storm.

    PYTHONPATH=src python -m repro.launch.soak --hosts 8 --steps 40 \
        --storm short --seed 0
    PYTHONPATH=src python -m repro.launch.soak --hosts 4 --steps 24 \
        --mutation-check

Simulates N hosts in one process (forced host devices, one device per
simulated host), runs the per-example-norm training pipeline through a
deterministic ``FaultPlan`` storm — kill a host mid-run, contract,
restore, resume; corrupt the newest checkpoint shard and watch restore
fall back a step; return the hosts and expand back — and *asserts*
the three invariants that make it a test, not a demo (DESIGN.md §11):

INV1 ``bit-exact-restore``  — after every recovery, params + optimizer
     state are byte-identical (sha256 over canonical leaf bytes) to
     the snapshot taken when the restored step was saved.
INV2 ``data-replay``        — every trained step consumes exactly the
     global batch an uninterrupted single-mesh run would have seen at
     that step, regardless of how hosts were renumbered (the
     logical-shard grid is pinned at launch; ownership moves, data
     does not).
INV3 ``norm-invariance``    — per-example gradient norms and the
     gradient-noise scale computed on the post-recovery mesh match the
     pre-failure single-mesh oracle at the restored step (selfcheck
     tolerances: rtol 2e-4 on norms, 5e-3 relative on GNS).

Time is simulated: one tick per attempted train step, heartbeats at
``now=tick`` — no wall clocks, no process kills, so the same
(storm, seed) replays bit-for-bit.

``--mutation-check`` proves the invariants have teeth: it re-runs the
storm three times, each with one recovery guard deliberately broken
(trust live state instead of restoring / scramble the shard
renumbering / compute GNS with the local batch size), and demands that
exactly the matching invariant trips. A soak whose invariants cannot
fail verifies nothing.

Graceful degradation rides along: the storm's NaN-poisoned batch must
be quarantined example-by-example through the plan's ``loss_weights=``
path (skip examples, not steps) — the harness asserts the quarantine
event names exactly the poisoned rows.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import tempfile
import warnings
from typing import Dict, List, Optional


def _argv_hosts(argv) -> int:
    for i, a in enumerate(argv):
        if a == "--hosts" and i + 1 < len(argv):
            try:
                return int(argv[i + 1])
            except ValueError:
                return 8
        if a.startswith("--hosts="):
            try:
                return int(a.split("=", 1)[1])
            except ValueError:
                return 8
    return 8


# MUST precede the first jax backend init (dryrun/selfcheck idiom):
# the simulated world needs one forced host device per simulated host.
if "--xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count="
        f"{max(8, _argv_hosts(sys.argv))}").strip()

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import ft
from repro.ckpt.checkpoint import CheckpointManager
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.data.pipeline import (DataConfig, LogicalShardedLM,
                                 assign_logical_shards)
from repro.dist.sharding import make_mesh_over
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

INV_RESTORE = "bit-exact-restore"
INV_REPLAY = "data-replay"
INV_NORMS = "norm-invariance"

#: mutation → the invariant that must catch it (the mutation matrix)
MUTATIONS = {
    "restore": INV_RESTORE,    # keep live state instead of restoring
    "renumber": INV_REPLAY,    # scramble the shard→host renumbering
    "reshard": INV_NORMS,      # GNS with the local, not global, batch
}


class SoakInvariantError(RuntimeError):
    def __init__(self, invariant: str, msg: str):
        super().__init__(f"[{invariant}] {msg}")
        self.invariant = invariant


class MutationCheckError(RuntimeError):
    """A disabled guard did NOT trip its invariant — the soak is
    asserting less than it claims."""


def unwrap_invariant(exc: BaseException) -> Optional[SoakInvariantError]:
    """Find the SoakInvariantError in an exception's cause chain (the
    supervisor wraps recovery failures in SupervisorHalted)."""
    seen = set()
    e: Optional[BaseException] = exc
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        if isinstance(e, SoakInvariantError):
            return e
        e = e.__cause__ or e.__context__
    return None


# ---------------------------------------------------------------------------
# canonical digests (INV1 / INV2)
# ---------------------------------------------------------------------------

def tree_digest(tree) -> str:
    """sha256 over path + dtype + shape + raw bytes of every leaf, in
    canonical tree order — bit-exact equality, not allclose."""
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        arr = np.ascontiguousarray(np.asarray(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(repr(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class SoakConfig:
    hosts: int = 8
    steps: int = 40
    seed: int = 0
    arch: str = "llama3.2-1b"
    seq: int = 16
    batch_per_host: int = 2
    ckpt_every: int = 0           # 0 ⇒ max(2, steps // 8)
    storm: str = "short"
    workdir: Optional[str] = None
    #: "" = honest run; a MUTATIONS key disables that recovery guard
    mutate: str = ""
    verbose: bool = True


class SoakWorld(ft.RecoveryActions):
    """The simulated cluster: trainer + data grid + heartbeat files +
    supervisor, advanced one tick per attempted train step. Implements
    ``RecoveryActions`` so the supervisor's recovery transitions act on
    this world — and get invariant-checked while doing so."""

    def __init__(self, cfg: SoakConfig, plan: ft.FaultPlan, workdir: str):
        n = cfg.hosts
        if len(jax.devices()) < n:
            raise RuntimeError(
                f"need {n} devices to simulate {n} hosts, have "
                f"{len(jax.devices())} (repro/__init__ forces 512 host "
                f"devices — is another jax already initialized?)")
        self.cfg = cfg
        self.plan = plan
        self.mutate = cfg.mutate
        if self.mutate and self.mutate not in MUTATIONS:
            raise ValueError(f"unknown mutation {self.mutate!r}; "
                             f"have {sorted(MUTATIONS)}")
        self.n = n
        self.B = n * cfg.batch_per_host
        self.ckpt_every = cfg.ckpt_every or max(2, cfg.steps // 8)
        self.ckpt_dir = os.path.join(workdir, "ckpt")
        hb_dir = os.path.join(workdir, "heartbeats")

        aspec = registry.get(cfg.arch)
        mcfg = aspec.smoke()
        mod = registry.family_module(aspec)
        params = unbox(mod.init(jax.random.PRNGKey(cfg.seed), mcfg))
        #: poison-aware loss: batch["poison"] (all-ones normally) is a
        #: bit-exact no-op; the storm's nan_batch makes rows NaN
        self.loss = ft.poison_loss_fn(registry.make_loss_fn_v2(aspec, mcfg))

        data_cfg = DataConfig(vocab=mcfg.vocab, seq=cfg.seq,
                              global_batch=self.B, seed=cfg.seed)
        #: the logical shard grid is pinned at n_logical = launch hosts
        #: and NEVER changes — hosts own shard subsets (INV2's anchor)
        self.lm = LogicalShardedLM(data_cfg, n_logical=n)
        self.active: List[int] = list(range(n))
        self.owned = assign_logical_shards(n, self.active)
        #: hosts currently emitting heartbeats (killed ⇒ removed;
        #: contraction-dropped survivors go silent; host_return re-adds)
        self.beating = set(self.active)
        hb_cfg = ft.HeartbeatConfig(interval_s=1.0, deadline_s=2.5)
        self.monitors = {h: ft.HeartbeatMonitor(hb_dir, h, hb_cfg)
                         for h in range(n)}
        sup_monitor = ft.HeartbeatMonitor(hb_dir, n, hb_cfg)  # never beats

        mesh = make_mesh_over(jax.devices()[:n], (n,), ("data",))
        self.trainer = Trainer(
            self.loss, params, PexSpec(enabled=True),
            adamw.AdamWConfig(lr=1e-3),
            TrainConfig(consumers=(plan_mod.Norms(), plan_mod.Grads()),
                        steps=cfg.steps, log_every=0,
                        ckpt_every=self.ckpt_every,
                        ckpt_dir=self.ckpt_dir, seed=cfg.seed),
            data_cfg, mesh=mesh, data=self.lm)
        self.supervisor = ft.Supervisor(
            ft.Topology(n_hosts=n, devices_per_host=1, model_parallel=1),
            self.active, sup_monitor, actions=self)

        #: mesh=None single-device oracle for INV3 (selfcheck's role)
        self._oracle_eng = Engine(PexSpec(enabled=True), mesh=None)

        def oracle(p, b):
            res = self._oracle_eng.step(
                self.loss, p, b,
                consumers=(plan_mod.Norms(), plan_mod.Grads()))
            gns = plan_mod.gradient_noise_scale(res.sq_norms, res.grads,
                                                batch_size=self.B)
            return res.sq_norms, gns
        self._oracle = jax.jit(oracle)

        #: step → {digest, oracle_sq, oracle_gns}, written at every save
        self.snapshots: Dict[int, Dict] = {}
        self.fallbacks = 0
        self.recoveries: List[Dict] = []
        self.fault_log: List[Dict] = []
        self.ticks = 0

    # -- helpers ----------------------------------------------------------
    def _say(self, msg: str) -> None:
        if self.cfg.verbose:
            print(f"[soak] {msg}", flush=True)

    def _state_digest(self) -> str:
        tr = self.trainer
        return tree_digest({"state": tr._state_tree(),
                            "opt_step": np.int64(int(tr.opt_state.step))})

    def _probe_batch(self, step: int):
        """The oracle/mesh probe input at ``step``: the pure
        logical-order global batch with a clean poison vector."""
        batch = dict(self.lm.global_batch_at(step))
        batch["poison"] = jnp.ones(self.B, jnp.float32)
        return batch

    def _save_snapshot(self) -> None:
        """Checkpoint + record the INV1/INV3 ground truth for this step:
        the state digest and the single-mesh oracle (norms, GNS)."""
        tr = self.trainer
        sq, gns = self._oracle(tr.params, self._probe_batch(tr.step))
        self.snapshots[tr.step] = {
            "digest": self._state_digest(),
            "oracle_sq": np.asarray(sq, np.float32),
            "oracle_gns": float(gns),
        }
        tr.save_checkpoint()        # async: the writer thread races on

    # -- fault application ------------------------------------------------
    def _apply_fault(self, e: ft.FaultEvent, tick: int) -> None:
        tr = self.trainer
        self.fault_log.append({"tick": tick, "kind": e.kind})
        if e.kind == "host_death":
            self._say(f"tick {tick}: KILL host {e.host}")
            self.beating.discard(e.host)
        elif e.kind == "host_return":
            self._say(f"tick {tick}: hosts {sorted(e.hosts)} return")
            self.beating |= set(e.hosts)
        elif e.kind in ("ckpt_corrupt", "ckpt_truncate"):
            tr.ckpt.wait()          # corrupt the *committed* newest
            step = ft.corrupt_newest_checkpoint(
                self.ckpt_dir, truncate=(e.kind == "ckpt_truncate"))
            self._say(f"tick {tick}: {e.kind} step {step}")
        elif e.kind == "tmp_litter":
            # a far-future step number: real saves never reuse the dir,
            # so the litter survives until a manager sweeps it
            path = ft.litter_tmp_dir(self.ckpt_dir,
                                     step=tr.step + 1_000_000)
            self._say(f"tick {tick}: tmp litter {os.path.basename(path)}")
        # "straggler" needs no world mutation: it enters through the
        # per-host step times fed to the supervisor each tick

    # -- RecoveryActions --------------------------------------------------
    def restore_to(self, topology: ft.Topology, active_hosts, reason: str
                   ) -> None:
        tr = self.trainer
        active = sorted(active_hosts)
        self._say(f"recovery[{reason}] → {len(active)} hosts {active}")
        tr.ckpt.wait()              # surface async writer failures now
        if reason == "expand":
            # a healthy world checkpoints before resharding wider so
            # expansion never costs progress
            self._save_snapshot()
            tr.ckpt.wait()
        # the recovered world restarts its checkpoint manager — which
        # must sweep any crashed-mid-save .tmp litter on construction
        tr.ckpt = CheckpointManager(self.ckpt_dir)
        leftover = [f for f in os.listdir(self.ckpt_dir)
                    if f.endswith(".tmp")]
        if leftover:
            raise RuntimeError(f"stale tmp litter survived manager "
                               f"construction: {leftover}")

        live = (tr.params, tr.opt_state)
        newest = tr.ckpt.latest_step()
        mesh = make_mesh_over([jax.devices()[h] for h in active],
                              (len(active),), ("data",))
        restored = tr.restore_from(
            None, shardings=NamedSharding(mesh, PartitionSpec()))
        if self.mutate == "restore":
            # MUTATION: trust the live in-memory state instead of the
            # checkpoint (a supervisor that "resumes" without restoring)
            tr.params, tr.opt_state = live

        # INV1 — bit-exact restore
        snap = self.snapshots.get(restored)
        if snap is None:
            raise RuntimeError(f"restored step {restored} has no "
                               f"snapshot (saves: {sorted(self.snapshots)})")
        got = self._state_digest()
        if got != snap["digest"]:
            raise SoakInvariantError(
                INV_RESTORE,
                f"post-recovery state digest {got[:12]} != snapshot "
                f"{snap['digest'][:12]} at step {restored}")
        if newest is not None and restored < newest:
            self.fallbacks += 1
            self._say(f"  restore fell back: {newest} → {restored}")

        # renumber the data pipeline onto the survivors
        owned = assign_logical_shards(self.n, active)
        if self.mutate == "renumber":
            # MUTATION: hand hosts each other's shards in reverse order
            # (full coverage, wrong order — only INV2 can see it)
            vals = [owned[h] for h in active][::-1]
            owned = dict(zip(active, vals))
        self.owned = owned
        tr.rebind_mesh(mesh)

        # INV3 — per-example norms + GNS on the new mesh vs the
        # pre-failure single-mesh oracle at the restored step
        probe = self._probe_batch(restored)
        res = jax.jit(lambda p, b: tr.engine.step(
            self.loss, p, b,
            consumers=(plan_mod.Norms(), plan_mod.Grads())))(
                tr.params, probe)
        bs = self.B if self.mutate != "reshard" \
            else self.B // len(active)      # MUTATION: local batch size
        gns = float(plan_mod.gradient_noise_scale(
            res.sq_norms, res.grads, batch_size=bs))
        sq = np.asarray(res.sq_norms, np.float32)
        if sq.shape != snap["oracle_sq"].shape or \
                not np.allclose(sq, snap["oracle_sq"],
                                rtol=2e-4, atol=1e-6):
            raise SoakInvariantError(
                INV_NORMS,
                f"per-example sq norms on the {len(active)}-way mesh "
                f"diverge from the single-mesh oracle at step {restored}")
        ogns = snap["oracle_gns"]
        if abs(gns - ogns) > 5e-3 * max(1.0, abs(ogns)):
            raise SoakInvariantError(
                INV_NORMS,
                f"GNS after reshard = {gns:.6g}, single-mesh oracle = "
                f"{ogns:.6g} at step {restored}")

        self.active = active
        # the launcher only keeps the hosts it placed in the new world
        # beating; dropped-but-alive survivors go silent (idle)
        self.beating = set(active)
        self.recoveries.append(
            {"reason": reason, "restored_step": restored,
             "hosts": list(active)})

    # -- the storm loop ---------------------------------------------------
    def run(self) -> Dict:
        cfg, tr, plan = self.cfg, self.trainer, self.plan
        max_ticks = cfg.steps * 3 + 30
        tick = 0
        while tr.step < cfg.steps:
            if tick > max_ticks:
                raise RuntimeError(
                    f"storm did not converge in {max_ticks} ticks "
                    f"(trained {tr.step}/{cfg.steps})")
            now = float(tick)
            for e in plan.at_tick(tick):
                self._apply_fault(e, tick)
            for h in sorted(self.beating):
                self.monitors[h].beat(tr.step, now=now)
            step_times = {h: plan.straggler_factor(tick, h)
                          for h in self.active}
            self.supervisor.tick(now, step_times=step_times)

            s = tr.step
            batch = dict(self.lm.global_batch_at(s, self.owned))
            # INV2 — the batch assembled from the current host→shard
            # ownership equals the uninterrupted-run stream at s
            want = tree_digest(self.lm.global_batch_at(s))
            if tree_digest(batch) != want:
                raise SoakInvariantError(
                    INV_REPLAY,
                    f"step {s}: batch assembled from ownership "
                    f"{self.owned} diverges from the uninterrupted "
                    f"stream (renumbering broke data replay)")
            batch["poison"] = jnp.asarray(plan.poison_vector(s, self.B))
            tr.run_step(batch)
            tr.step += 1
            if tr.step % self.ckpt_every == 0:
                self._save_snapshot()
            tick += 1
        tr.ckpt.wait()
        self.ticks = tick
        return self._finish()

    def _finish(self) -> Dict:
        tr = self.trainer
        for leaf in jax.tree_util.tree_leaves(tr.params):
            if not np.isfinite(np.asarray(leaf)).all():
                raise SoakInvariantError(
                    INV_RESTORE, "non-finite parameters after the storm "
                                 "(quarantine failed to contain poison)")
        # every scheduled NaN batch must have produced a quarantine of
        # exactly the poisoned rows (examples skipped, steps trained)
        quarantines = [e for e in tr.events if e["kind"] == "quarantine"]
        for e in self.plan.events:
            if e.kind != "nan_batch" or e.at >= self.cfg.steps:
                continue
            hits = [q for q in quarantines if q["step"] == e.at]
            if not hits:
                raise SoakInvariantError(
                    INV_REPLAY,
                    f"nan_batch at step {e.at} was never quarantined")
            for q in hits:
                if set(q["examples"]) != set(e.examples):
                    raise SoakInvariantError(
                        INV_REPLAY,
                        f"step {e.at}: quarantined {q['examples']}, "
                        f"poisoned {sorted(set(e.examples))}")
        reasons = [r["reason"] for r in self.recoveries]
        summary = {
            "steps": self.cfg.steps,
            "ticks": self.ticks,
            "hosts": self.n,
            "final_hosts": len(self.active),
            "recoveries": self.recoveries,
            "contractions": reasons.count("contract") +
                            reasons.count("evict"),
            "expansions": reasons.count("expand"),
            "fallbacks": self.fallbacks,
            "quarantined_steps": sorted({q["step"] for q in quarantines}),
            "supervisor_events": len(self.supervisor.events),
            "final_loss": tr.metrics[-1]["loss"] if tr.metrics else None,
            "invariants": "PASS",
        }
        return summary


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _build_plan(cfg: SoakConfig) -> ft.FaultPlan:
    if cfg.storm == "random":
        return ft.random_storm(cfg.seed, cfg.hosts, cfg.steps)
    return ft.scripted_storm(cfg.storm, cfg.hosts, cfg.steps)


def run_soak(cfg: SoakConfig) -> Dict:
    """One full storm run; raises SoakInvariantError (possibly wrapped
    in SupervisorHalted) when an invariant trips."""
    workdir = cfg.workdir or tempfile.mkdtemp(prefix="soak_")
    plan = _build_plan(cfg)
    world = SoakWorld(cfg, plan, workdir)
    summary = world.run()
    if cfg.storm == "short":
        # the acceptance storm must actually exercise every path
        if summary["contractions"] < 2:
            raise MutationCheckError(
                f"storm 'short' produced {summary['contractions']} "
                f"contractions, expected >= 2")
        if summary["expansions"] < 1:
            raise MutationCheckError(
                "storm 'short' never expanded back")
        if summary["fallbacks"] < 1:
            raise MutationCheckError(
                "storm 'short' never exercised checkpoint fallback")
        if not summary["quarantined_steps"]:
            raise MutationCheckError(
                "storm 'short' never exercised quarantine")
    return summary


def run_mutation_checks(cfg: SoakConfig) -> Dict[str, str]:
    """Prove the invariants can fail: disable one recovery guard at a
    time and demand the matching invariant trips."""
    results = {}
    for mutate, want in MUTATIONS.items():
        mcfg = dataclasses.replace(cfg, mutate=mutate, workdir=None)
        try:
            run_soak(mcfg)
        except (SoakInvariantError, ft.SupervisorHalted) as e:
            inv = unwrap_invariant(e)
            if inv is None:
                raise MutationCheckError(
                    f"mutation {mutate!r} halted without an invariant "
                    f"error: {e}") from e
            if inv.invariant != want:
                raise MutationCheckError(
                    f"mutation {mutate!r} tripped {inv.invariant!r}, "
                    f"expected {want!r}") from e
            results[mutate] = inv.invariant
            print(f"[soak] mutation {mutate!r}: tripped {want!r} ✓",
                  flush=True)
        else:
            raise MutationCheckError(
                f"mutation {mutate!r} completed the storm — invariant "
                f"{want!r} has no teeth")
    return results


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="elastic soak harness (DESIGN.md §11)")
    p.add_argument("--hosts", type=int, default=8)
    p.add_argument("--steps", type=int, default=40)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--seq", type=int, default=16)
    p.add_argument("--batch-per-host", type=int, default=2)
    p.add_argument("--ckpt-every", type=int, default=0,
                   help="0 = max(2, steps//8)")
    p.add_argument("--storm", default="short",
                   choices=("none", "short", "random"))
    p.add_argument("--workdir", default=None)
    p.add_argument("--mutation-check", action="store_true",
                   help="run the storm with each recovery guard "
                        "disabled; every mutant must trip its invariant")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)
    cfg = SoakConfig(hosts=args.hosts, steps=args.steps, seed=args.seed,
                     arch=args.arch, seq=args.seq,
                     batch_per_host=args.batch_per_host,
                     ckpt_every=args.ckpt_every, storm=args.storm,
                     workdir=args.workdir, verbose=not args.quiet)
    if len(jax.devices()) < cfg.hosts:
        print(f"need {cfg.hosts} devices, have {len(jax.devices())}",
              file=sys.stderr)
        return 2
    warnings.filterwarnings(
        "ignore", message=".*(unreadable|sweeping stale).*")
    try:
        if args.mutation_check:
            results = run_mutation_checks(cfg)
            print(json.dumps({"mutation_check": results,
                              "status": "PASS"}))
            return 0
        summary = run_soak(cfg)
        print(json.dumps(summary, indent=2))
        return 0
    except (SoakInvariantError, ft.SupervisorHalted) as e:
        inv = unwrap_invariant(e)
        name = inv.invariant if inv else "halt"
        print(f"SOAK FAILED [{name}]: {e}", file=sys.stderr)
        return 1
    except MutationCheckError as e:
        print(f"MUTATION CHECK FAILED: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    sys.exit(main())
