"""Training launcher: --arch <id> [--smoke] with pex step modes.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --smoke --mode clip --steps 50 --ckpt-dir /tmp/ck

Full-size configs target the production mesh (run under a real TPU
runtime with the same flags); --smoke runs the reduced config on
whatever devices exist.
"""
from __future__ import annotations

import argparse

import jax

from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import count_params, unbox
from repro.optim import adamw
from repro.optim.schedule import linear_warmup_cosine
from repro.train.trainer import TrainConfig, Trainer, consumers_for_mode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="norms",
                    choices=["plain", "norms", "clip", "importance"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--noise-std", type=float, default=0.0,
                    help="DP-SGD noise multiplier for --mode clip")
    ap.add_argument("--pex-method", default="auto")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard the batch over all local devices via the "
                         "dist.pex shard_map pipeline")
    args = ap.parse_args()

    aspec = registry.get(args.arch)
    cfg = aspec.smoke() if args.smoke else aspec.full()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(args.seed), cfg))
    print(f"{args.arch}: {count_params(params) / 1e6:.1f}M params, "
          f"mode={args.mode}")

    pex = PexSpec(enabled=args.mode != "plain", method=args.pex_method)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    mesh = None
    if args.data_parallel:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model_parallel=1)
        print(f"data-parallel over {mesh.shape['data']} devices")
    # the CLI still speaks the legacy mode names; the trainer itself
    # runs declarative consumer plans (DESIGN.md §9)
    consumers = consumers_for_mode(args.mode, args.batch,
                                   clip_norm=args.clip_norm,
                                   noise_std=args.noise_std)
    trainer = Trainer(
        loss_fn, params, pex,
        adamw.AdamWConfig(lr=args.lr,
                          schedule=linear_warmup_cosine(10, args.steps)),
        TrainConfig(consumers=consumers,
                    steps=args.steps, ckpt_dir=args.ckpt_dir, seed=args.seed),
        DataConfig(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch,
                   seed=args.seed),
        mesh=mesh)
    trainer.train(resume=args.resume)


if __name__ == "__main__":
    main()
