import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: compile tagged probe variants of a cell and
report the roofline-term deltas vs the recorded baseline.

    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-v2-236b \
        --shape train_4k --variant moe_local_dispatch

Variants (composable with +):
  baseline            — paper-faithful: pex=direct, full remat, global MoE scatter
  pex_off             — instrumentation disabled (reference floor)
  pex_gram            — adaptive/gram stat estimator
  pex_factorized      — paper §4 formula applied mechanically (upper bound!)
  moe_local_dispatch  — GShard-style grouped dispatch (G=16, data-aligned)
  remat_dots          — checkpoint policy: save dot outputs
  no_remat            — no activation checkpointing
"""

import argparse
import dataclasses
import json

from repro.configs.common import SHAPES
from repro.launch.probes import run_probes
from repro.launch.mesh import make_production_mesh
from repro.models import registry


def apply_variant(cfg, name: str):
    if name in ("baseline", "pex_off", "pex_gram", "pex_factorized"):
        return cfg
    if name == "moe_local_dispatch":
        assert getattr(cfg, "moe", None) is not None
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=16))
    if name == "remat_dots":
        return dataclasses.replace(cfg, remat_policy="dots")
    if name == "no_remat":
        return dataclasses.replace(cfg, remat=False)
    if name == "moe_cf1":
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help="'+'-joined list, e.g. moe_local_dispatch+pex_gram")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    names = args.variant.split("+")
    pex_on = "pex_off" not in names
    pex_method = "direct"
    if "pex_gram" in names:
        pex_method = "auto"
    if "pex_factorized" in names:
        pex_method = "factorized"

    aspec = registry.get(args.arch)
    base_probes = aspec.probes()
    cfgs = []
    for p in base_probes:
        for n in names:
            p = apply_variant(p, n)
        cfgs.append(p)

    # monkey-patch the probe list for this run
    patched = dataclasses.replace(aspec, probes=lambda: cfgs)
    registry.ARCHS[args.arch] = patched
    mesh = make_production_mesh(multi_pod=False)
    tag = args.variant.replace("+", "_")
    d = run_probes(args.arch, args.shape, mesh, pex_method=pex_method,
                   pex_on=pex_on, out_dir=args.out, tag=tag)
    base_path = f"experiments/roofline/{args.arch}__{args.shape}.json"
    if os.path.exists(base_path) and d is not None:
        b = json.load(open(base_path))
        print("\nΔ vs baseline:")
        for k in ("t_compute", "t_memory", "t_collective"):
            print(f"  {k:13s} {b[k] * 1e3:12.1f} → {d[k] * 1e3:12.1f} ms  "
                  f"({d[k] / max(b[k], 1e-12):.3f}x)")
        print(f"  useful_ratio  {b['useful_ratio']:.3f} → {d['useful_ratio']:.3f}")
        print(f"  mfu_bound     {b['mfu_bound']:.4f} → {d['mfu_bound']:.4f}")


if __name__ == "__main__":
    main()
