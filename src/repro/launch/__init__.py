"""repro.launch"""
