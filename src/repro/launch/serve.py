"""Serving launcher: --arch <id> [--smoke], batched random requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.common import ShapeSpec
from repro.models import registry
from repro.nn.param import unbox
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    aspec = registry.get(args.arch)
    base = aspec.smoke() if args.smoke else aspec.full()
    cfg = registry.serving_config(
        aspec, base, ShapeSpec("serve", "decode", args.cache_len, args.slots))
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    eng = Engine(args.arch, cfg, params, batch_slots=args.slots,
                 temperature=0.8)

    rng = np.random.default_rng(1)
    reqs = [Request(prompt=list(rng.integers(0, cfg.vocab, size=int(n))),
                    max_new=args.max_new)
            for n in rng.integers(2, 12, size=args.requests)]
    t0 = time.perf_counter()
    done = eng.generate(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in done)
    print(f"{tokens} tokens for {len(done)} requests in {dt:.2f}s "
          f"({tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
