import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import (device count locks at first init).

_DOC = """Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  jit(step).lower(**ShapeDtypeStruct specs).compile()
must succeed on the 16×16 single-pod mesh and the 2×16×16 multi-pod
mesh. No arrays are allocated — params/caches/batches are eval_shape
stand-ins carrying shardings. The compiled artifact yields
memory_analysis (fits-per-device proof) and cost_analysis + HLO text
(roofline inputs, §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --multi-pod
"""

import argparse
import dataclasses
import json
import math
import time
import traceback
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.common import SHAPES, ShapeSpec
from repro.core.engine import Engine
from repro.core.plan import Grads, Norms
from repro.core.taps import PexSpec
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.nn.param import axes_of, unbox
from repro.optim import adamw
from repro.roofline import hlo as hlo_parse
from repro.roofline.constants import CHIPS_PER_POD


# reduced shapes for the in-suite dry-run regression test (--smoke)
_EXTRA_SHAPES = {
    "smoke_train": ShapeSpec("smoke_train", "train", 64, 32),
    "smoke_prefill": ShapeSpec("smoke_prefill", "prefill", 64, 32),
    "smoke_decode": ShapeSpec("smoke_decode", "decode", 64, 32),
}


def _shape(name: str) -> ShapeSpec:
    return SHAPES.get(name) or _EXTRA_SHAPES[name]


def _dp(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _batch_shardings(batch_specs, mesh, shape: ShapeSpec, multi_pod: bool):
    dp = _dp(multi_pod)
    shard_batch = shape.batch % shd.axis_size(dp, mesh) == 0

    def one(sds):
        if not shard_batch:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (len(sds.shape) - 1))))

    return jax.tree_util.tree_map(one, batch_specs)


def _cache_shardings(aspec, cfg, cache_specs, mesh, shape, multi_pod):
    """Per-family sharding for decode/prefill caches (see DESIGN.md §6)."""
    dp = _dp(multi_pod)
    b_ok = shape.batch % shd.axis_size(dp, mesh) == 0
    dpa = dp if b_ok else None
    msz = mesh.shape["model"]
    kv_ok = False
    if aspec.family == "transformer" and cfg.attn is not None:
        kv_ok = cfg.attn.n_kv % msz == 0
    if aspec.family in ("seamless",):
        kv_ok = cfg.kv_heads % msz == 0
    if aspec.family == "zamba2":
        kv_ok = cfg.kv_heads % msz == 0
    kv_ax = "model" if kv_ok else None
    # cache time axis: `data` for long-context (batch=1); additionally
    # `model` when KV heads can't shard (flash-decoding-style split —
    # softmax over the sharded axis costs only tiny max/sum reductions)
    if shape.name == "long_500k":
        seq_ax = ("data",) if kv_ok else ("data", "model")
    else:
        seq_ax = None if kv_ok else ("model",)

    def spec_for(path, sds):
        ks = jax.tree_util.keystr(path)
        nd = len(sds.shape)
        if aspec.family == "transformer":
            if "ckv" in ks or "krope" in ks:   # MLA latent: (L?,B,T,C)
                base = (dpa, seq_ax, None)
            else:                               # GQA: (L?,B,T,H,D)
                base = (dpa, seq_ax, kv_ax, None)
            lead = nd - len(base)
            return P(*([None] * lead), *base)
        if aspec.family == "rwkv6":             # (L,B,...) O(1) state
            return P(None, dpa, *([None] * (nd - 2)))
        if aspec.family == "zamba2":
            if "shared" in ks:                  # (G,B,T,H,D)
                return P(None, dpa, seq_ax, kv_ax, None)
            # ssm states: (G,K,B,...) or (T,B,...)
            lead = nd - (nd - 2)
            if "blocks" in ks:
                return P(None, None, dpa, *([None] * (nd - 3)))
            return P(None, dpa, *([None] * (nd - 2)))
        if aspec.family == "seamless":
            if "memory" in ks:                  # (B,S,d)
                return P(dpa, None, None)
            return P(None, dpa, seq_ax, kv_ax, None)   # (L,B,T,H,D)
        raise ValueError(aspec.family)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_specs)
    out = [NamedSharding(mesh, spec_for(path, sds)) for path, sds in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def _tree_bytes_per_dev(sds_tree, sh_tree, mesh) -> float:
    """Analytic per-device bytes of a (ShapeDtypeStruct, NamedSharding)
    tree — donation-aliasing in memory_analysis hides these."""
    total = 0.0
    flat_s = jax.tree_util.tree_leaves(sds_tree)
    flat_h = jax.tree_util.tree_leaves(
        sh_tree, is_leaf=lambda x: isinstance(x, NamedSharding))
    for sds, sh in zip(flat_s, flat_h):
        n_bytes = math.prod(sds.shape) * sds.dtype.itemsize
        shards = 1
        for ax in jax.tree_util.tree_leaves(tuple(sh.spec)):
            if ax is not None:
                shards *= mesh.shape[ax]
        total += n_bytes / shards
    return total


def _opt_shardings(param_sh, mesh):
    mu = jax.tree_util.tree_map(lambda s: s, param_sh)
    return adamw.AdamWState(NamedSharding(mesh, P()), mu,
                            jax.tree_util.tree_map(lambda s: s, param_sh))


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    arg_bytes_per_dev: float = 0.0
    out_bytes_per_dev: float = 0.0
    temp_bytes_per_dev: float = 0.0
    peak_bytes_per_dev: float = 0.0
    param_bytes_per_dev: float = 0.0   # analytic (donation-proof)
    state_bytes_per_dev: float = 0.0   # opt state / caches, analytic
    n_params: float = 0.0
    error: str = ""


def lower_cell(arch_id: str, shape_name: str, mesh, multi_pod: bool, *,
               cfg_override=None, pex_method: str = "direct",
               pex_on: bool = True, keep_hlo: bool = False,
               donate: bool = True, extra_rules: Optional[dict] = None,
               optimizer: str = "adamw", pex_spmd: bool = False):
    """Lower + compile one cell; returns (CellResult, compiled|None).

    ``pex_spmd`` routes the train step through the dist.pex shard_map
    pipeline (explicit per-shard norms + gradient psum) instead of
    GSPMD auto-sharding; requires a data-only mesh (model extent 1).
    """
    aspec = registry.get(arch_id)
    shape = _shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch_id, shape_name, mesh_name, ok=False)
    if cfg_override is None and shape_name in aspec.skip_shapes:
        res.skipped, res.reason, res.ok = True, aspec.skip_reason, True
        return res, None

    cfg = cfg_override if cfg_override is not None else aspec.full()
    if shape.kind != "train":
        cfg = registry.serving_config(aspec, cfg, shape)
    rules = registry.rules_for(aspec, cfg, shape, multi_pod,
                               model_size=mesh.shape["model"],
                               data_size=mesh.shape["data"])
    if extra_rules:
        rules.update(extra_rules)
    mod = registry.family_module(aspec)

    with shd.use_rules(mesh, rules):
        boxed = jax.eval_shape(lambda k: mod.init(k, cfg), jax.random.key(0))
        param_sds = unbox(boxed)
        param_sh = shd.sharding_tree(axes_of(boxed))
        res.n_params = float(sum(
            math.prod(x.shape)
            for x in jax.tree_util.tree_leaves(param_sds)))

        t0 = time.time()
        if shape.kind == "train":
            pex = PexSpec(enabled=pex_on, method=pex_method)
            loss_fn = registry.make_loss_fn_v2(aspec, cfg)
            eng = Engine(pex, mesh=mesh if pex_spmd else None,
                         data_axes=_dp(multi_pod))
            if optimizer == "adafactor":
                from repro.optim import adafactor as opt_mod
                opt_cfg = opt_mod.AdafactorConfig()
            else:
                opt_mod, opt_cfg = adamw, adamw.AdamWConfig()
            b = shape.batch
            # probes (cfg_override) run un-accumulated: grad accumulation
            # repeats fwd/bwd verbatim, so probe FLOPs already equal the
            # accumulated program's total
            n_micro = aspec.train_microbatches if cfg_override is None else 1

            # the train cell lowers the fused consumer plan (norms +
            # grads in one backward); pex_on=False disables the spec,
            # so the norms land as zeros and the program is the plain
            # step (the DCE property)
            consumers = [Norms(), Grads()]

            def train_step(params, opt_state, batch):
                if pex_spmd or n_micro == 1:
                    r = eng.step(loss_fn, params, batch,
                                 consumers=consumers, batch_size=b)
                    grads, loss, sq = r.grads, r.loss, r.sq_norms
                else:
                    mb = b // n_micro
                    batch_r = jax.tree_util.tree_map(
                        lambda x: x.reshape((n_micro, mb) + x.shape[1:]),
                        batch)
                    g0 = jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)

                    def micro(gsum, mbatch):
                        r = eng.step(loss_fn, params, mbatch,
                                     consumers=consumers, batch_size=mb)
                        gsum = jax.tree_util.tree_map(
                            lambda a, g: a + g.astype(jnp.float32),
                            gsum, r.grads)
                        return gsum, (r.loss, r.sq_norms)

                    grads, (losses, sqs) = jax.lax.scan(micro, g0, batch_r)
                    loss = jnp.sum(losses)
                    sq = sqs.reshape((b,) + sqs.shape[2:])
                params, opt_state = opt_mod.update(opt_cfg, opt_state,
                                                   params, grads)
                return params, opt_state, loss, sq

            batch_sds = registry.train_batch_specs(aspec, cfg, shape)
            batch_sh = _batch_shardings(batch_sds, mesh, shape, multi_pod)
            opt_sds = jax.eval_shape(opt_mod.init, param_sds)
            if optimizer == "adafactor":
                # factored vectors: replicate (tiny); full-v leaves follow params
                opt_sh = jax.tree_util.tree_map(
                    lambda _: NamedSharding(mesh, P()), opt_sds)
            else:
                opt_sh = _opt_shardings(param_sh, mesh)
            res.param_bytes_per_dev = _tree_bytes_per_dev(param_sds, param_sh, mesh)
            res.state_bytes_per_dev = _tree_bytes_per_dev(opt_sds, opt_sh, mesh)
            jitted = jax.jit(
                train_step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(param_sds, opt_sds, batch_sds)
        else:
            prefill = shape.kind == "prefill"
            fwd = registry.make_forward_tokens(aspec, cfg)
            batch_sds = registry.serve_batch_specs(aspec, cfg, shape,
                                                   prefill=prefill)
            batch_sh = _batch_shardings(batch_sds, mesh, shape, multi_pod)
            cache_sds = registry.cache_specs(aspec, cfg, shape)
            cache_sh = _cache_shardings(aspec, cfg, cache_sds, mesh, shape,
                                        multi_pod)
            idx_sds = jax.ShapeDtypeStruct((), jnp.int32)

            def serve_step(params, batch, caches, idx):
                return fwd(params, batch, caches, idx)

            res.param_bytes_per_dev = _tree_bytes_per_dev(param_sds, param_sh, mesh)
            res.state_bytes_per_dev = _tree_bytes_per_dev(cache_sds, cache_sh, mesh)
            jitted = jax.jit(
                serve_step,
                in_shardings=(param_sh, batch_sh, cache_sh,
                              NamedSharding(mesh, P())),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(param_sds, batch_sds, cache_sds, idx_sds)
        res.lower_s = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        res.compile_s = time.time() - t0

        # cost_analysis on the SPMD-partitioned module reports PER-DEVICE
        # numbers (verified in-container: sharded == unsharded/256);
        # scale to global so the spec's /(chips × ...) formulas apply.
        n_dev_total = mesh.devices.size
        flops, bytes_accessed = hlo_parse.compiled_cost(compiled)
        res.flops = flops * n_dev_total
        res.bytes_accessed = bytes_accessed * n_dev_total
        ma = compiled.memory_analysis()
        if ma is not None:
            n_dev = mesh.devices.size
            res.arg_bytes_per_dev = ma.argument_size_in_bytes / n_dev
            res.out_bytes_per_dev = ma.output_size_in_bytes / n_dev
            res.temp_bytes_per_dev = ma.temp_size_in_bytes / n_dev
            # donated buffers alias outputs and vanish from the arg/out
            # counts — add the analytic param/state residency instead
            res.peak_bytes_per_dev = (
                res.param_bytes_per_dev + res.state_bytes_per_dev +
                (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                 ma.temp_size_in_bytes) / n_dev)
        txt = compiled.as_text()
        res.coll_bytes = {k: v * n_dev_total for k, v in
                          hlo_parse.collective_bytes(txt).items()}
        res.coll_counts = hlo_parse.collective_counts(txt)
        res.ok = True
        if keep_hlo:
            res.error = ""   # hlo returned separately
            return res, (compiled, txt)
        return res, compiled


def run_cell(arch_id, shape_name, multi_pod, out_dir=None, **kw):
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        res, _ = lower_cell(arch_id, shape_name, mesh, multi_pod, **kw)
    except Exception:
        res = CellResult(arch_id, shape_name,
                         "2x16x16" if multi_pod else "16x16", ok=False,
                         error=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch_id}__{shape_name}__{res.mesh}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(dataclasses.asdict(res), f, indent=1)
    status = "SKIP" if res.skipped else ("OK" if res.ok else "FAIL")
    print(f"[{status}] {arch_id} × {shape_name} × {res.mesh} "
          f"compile={res.compile_s:.1f}s flops={res.flops:.3g} "
          f"coll={res.coll_bytes.get('total', 0):.3g}B "
          f"peak={res.peak_bytes_per_dev / 1e9:.2f}GB/dev")
    if res.error:
        print(res.error)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--pex-method", default="direct")
    ap.add_argument("--pex-spmd", action="store_true",
                    help="lower the dist.pex shard_map pipeline instead of "
                         "GSPMD auto-sharding (train cells; data-only mesh)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs + smoke shapes (CI regression)")
    args = ap.parse_args()

    if args.smoke:
        if args.pex_spmd:   # shard_map needs every >1 axis in data_axes
            shp_m = (2, 8, 1) if args.multi_pod else (16, 1)
        else:
            shp_m = (2, 4, 4) if args.multi_pod else (4, 4)
        axes = ("pod", "data", "model") if args.multi_pod else ("data", "model")
        mesh = shd.make_mesh(shp_m, axes)
        archs = sorted(registry.ARCHS) if not args.arch else [args.arch]
        shapes = ("smoke_train",) if args.pex_spmd else \
            ("smoke_train", "smoke_prefill", "smoke_decode")
        fails = 0
        for arch in archs:
            cfg = registry.get(arch).smoke()
            for shp in shapes:
                try:
                    res, _ = lower_cell(arch, shp, mesh, args.multi_pod,
                                        cfg_override=cfg,
                                        pex_spmd=args.pex_spmd)
                    print(f"[{'OK' if res.ok else 'FAIL'}] {arch} × {shp}")
                    fails += 0 if res.ok else 1
                except Exception as e:
                    print(f"[FAIL] {arch} × {shp}: {e}")
                    fails += 1
        raise SystemExit(1 if fails else 0)

    archs = sorted(registry.ARCHS) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shp in shapes:
                results.append(run_cell(arch, shp, mp, out_dir=args.out,
                                        pex_method=args.pex_method,
                                        pex_spmd=args.pex_spmd))
    n_ok = sum(r.ok for r in results)
    n_skip = sum(r.skipped for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK ({n_skip} documented skips)")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
