"""AdamW with decoupled weight decay, f32 moments, global-norm clipping,
and schedule support. Pure-pytree (no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    global_clip: Optional[float] = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def init(params) -> AdamWState:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    step = state.step + 1
    if cfg.global_clip is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.global_clip / (gn + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)
