"""repro.optim"""
