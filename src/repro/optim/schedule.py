"""LR schedules as step→multiplier functions (composable with AdamW.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / max(1, warmup))
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return warm * cos
    return f


def constant():
    return lambda step: jnp.ones_like(step, jnp.float32)


def rsqrt(warmup: int):
    def f(step):
        step = step.astype(jnp.float32)
        return jnp.minimum(step / max(1, warmup) ** 1.5, 1.0 / jnp.sqrt(
            jnp.maximum(step, 1.0)))
    return f
