"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+ nodes the data-parallel all-reduce of gradients is the largest
single collective. Quantizing to int8 with per-tensor scale cuts those
bytes 4× (bf16) / 2× (int8 vs bf16) while error feedback keeps the
optimizer unbiased over time (Seide et al., 1-bit SGD lineage).

Usage: wrap grads before the optimizer —
    cgrads, new_err = compress_decompress(grads, err)
The quantize→dequantize pair is placed *around* the point where pjit
inserts the DP reduction, so XLA reduces the int8 tensors.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-tensor int8 quantization, guarded against a non-finite
    amax: a single NaN/inf element would otherwise poison the scale
    and turn the WHOLE tensor into NaN on dequant (NaN/NaN rounds to
    NaN, clip keeps it, int8 cast is undefined). When amax is not
    finite the tensor is quantized as zeros and the caller falls back
    to the uncompressed values for that step."""
    amax = jnp.max(jnp.abs(x))
    finite = jnp.isfinite(amax)
    scale = jnp.maximum(jnp.where(finite, amax, 0.0), 1e-12) / 127.0
    xq = jnp.where(jnp.isfinite(x) & finite, x, 0.0)
    q = jnp.clip(jnp.round(xq / scale), -127, 127).astype(jnp.int8)
    return q, scale, finite


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """Returns (compressed-then-restored grads, new error feedback).

    A tensor whose amax is non-finite (overflow/NaN gradient, e.g. a
    loss-scale spike) passes through uncompressed for that step — the
    values are unchanged for the optimizer/skip logic downstream — and
    contributes nothing to the error carry, so one bad step cannot
    poison future compressed steps through the feedback loop."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s, finite = _quant(gf)
        deq = _dequant(q, s)
        out = jnp.where(finite, deq, gf)
        new_e = jnp.where(finite, gf - deq, jnp.zeros_like(gf))
        return out.astype(g.dtype), new_e

    out = jax.tree_util.tree_map(one, grads, err)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and \
        not isinstance(t[0], tuple)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
    return new_g, new_e
