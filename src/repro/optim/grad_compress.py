"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+ nodes the data-parallel all-reduce of gradients is the largest
single collective. Quantizing to int8 with per-tensor scale cuts those
bytes 4× (bf16) / 2× (int8 vs bf16) while error feedback keeps the
optimizer unbiased over time (Seide et al., 1-bit SGD lineage).

Usage: wrap grads before the optimizer —
    cgrads, new_err = compress_decompress(grads, err)
The quantize→dequantize pair is placed *around* the point where pjit
inserts the DP reduction, so XLA reduces the int8 tensors.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, err):
    """Returns (compressed-then-restored grads, new error feedback)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = _quant(gf)
        deq = _dequant(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree_util.tree_map(one, grads, err)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and \
        not isinstance(t[0], tuple)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
    return new_g, new_e
