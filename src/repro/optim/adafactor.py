"""Adafactor (Shazeer & Stern 2018): factored second moments —
O(n+m) optimizer state per (n×m) matrix instead of AdamW's O(2·n·m) f32.

At deepseek-v2-236b this is the difference between 6.9 GB/device of
moments and ~0.02 GB/device (+ the f32 row/col vectors), which buys the
activation headroom the train_4k cell needs without microbatching.
Implements the standard recipe: factored v for ≥2-D params, scalar-free
update clipping by RMS, relative step size or fixed lr.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8           # t^-decay schedule for v's EMA
    eps1: float = 1e-30
    eps2: float = 1e-3
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_factored: int = 2    # factor matrices with ≥2 dims
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: object    # row second-moments (or full v for vectors)
    vc: object    # col second-moments (dummy zeros for vectors)


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    def rows(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((), jnp.float32)

    return AdafactorState(
        jnp.zeros((), jnp.int32),
        jax.tree_util.tree_map(rows, params),
        jax.tree_util.tree_map(cols, params))


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def update(cfg: AdafactorConfig, state: AdafactorState, params, grads):
    step = state.step + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + cfg.eps1
        if _factored(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            # v ≈ vr vcᵀ / mean(vr)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            r = (vr / jnp.maximum(denom, cfg.eps1))[..., None]
            u = g * jax.lax.rsqrt(r * vc[..., None, :] + cfg.eps1)
        else:
            vr = beta2 * vr + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(vr + cfg.eps1)
        u = u / jnp.maximum(1.0, _rms(u) / cfg.clip_threshold)
        scale = jnp.maximum(cfg.eps2, _rms(p.astype(jnp.float32))) \
            if p.ndim >= 1 else cfg.eps2
        new_p = p.astype(jnp.float32) - lr * scale * u
        if cfg.weight_decay:
            new_p -= lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), vr, vc

    out = jax.tree_util.tree_map(upd, params, grads, state.vr, state.vc)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdafactorState(step, pick(1), pick(2))
