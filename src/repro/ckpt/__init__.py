"""repro.ckpt"""
