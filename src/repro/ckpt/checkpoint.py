"""Sharded, async, atomic checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000123.tmp/            — written first
        manifest.json                  — tree structure, shapes, mesh, hash
        shard_<host>.npz               — this host's param/opt leaves
    <dir>/step_000123/                 — atomic rename on commit

Properties needed at 1000+ nodes:
  * async   — a background thread serializes + writes while training
              continues (device→host copy happens at save() call).
  * atomic  — readers only ever see fully-committed step dirs.
  * elastic — the manifest records the saving mesh; `restore` reassembles
              full arrays from any shard layout and re-shards to the
              current mesh (data-axis contraction after a node loss).
  * self-validating — manifest carries a content hash per shard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.dir, f"step_{step:09d}" + (".tmp" if tmp else ""))

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        """Device→host copy now; serialization + fsync on the writer thread."""
        items, _ = _flatten(tree)
        host_items = [(k, np.asarray(v)) for k, v in items]
        self.wait()   # one in-flight save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host_items, extra or {}))
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_items, extra: Dict) -> None:
        tmp = self._step_dir(step, tmp=True)
        final = self._step_dir(step)
        os.makedirs(tmp, exist_ok=True)
        # byte-view every leaf: npz has no bfloat16; manifest keeps dtype
        arrays = {
            f"leaf_{i}": np.frombuffer(
                np.ascontiguousarray(v).tobytes(), np.uint8)
            for i, (_, v) in enumerate(host_items)}
        shard_path = os.path.join(tmp, f"shard_{self.host_id:05d}.npz")
        np.savez(shard_path, **arrays)
        digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "keys": [k for k, _ in host_items],
            "shapes": [list(v.shape) for _, v in host_items],
            "dtypes": [str(v.dtype) for _, v in host_items],
            "shard_hash": {str(self.host_id): digest},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(shard_path, os.path.join(tmp, os.path.basename(shard_path)))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def restore(self, step: Optional[int], like_tree,
                shardings=None) -> Tuple[Any, Dict]:
        """Rebuild the pytree (re-sharded to `shardings` if given).
        Verifies content hashes; raises on corruption."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shard_path = os.path.join(d, f"shard_{self.host_id:05d}.npz")
        digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
        want = manifest["shard_hash"].get(str(self.host_id))
        if want is not None and digest != want:
            raise IOError(f"checkpoint shard corrupt at step {step}")
        data = np.load(shard_path)
        flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
        assert len(flat_like) == len(manifest["keys"]), "tree structure changed"
        out = []
        for i, like in enumerate(flat_like):
            raw = data[f"leaf_{i}"]
            dtype = jnp.dtype(manifest["dtypes"][i])
            shape = tuple(manifest["shapes"][i])
            arr = jnp.asarray(
                np.frombuffer(raw.tobytes(), dtype).reshape(shape),
                dtype=like.dtype)
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest["extra"]
