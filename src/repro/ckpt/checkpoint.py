"""Sharded, async, atomic checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000123.tmp/            — written first
        manifest.json                  — tree structure, shapes, mesh, hash
        shard_<host>.npz               — this host's param/opt leaves
    <dir>/step_000123/                 — atomic rename on commit

Properties needed at 1000+ nodes:
  * async   — a background thread serializes + writes while training
              continues (device→host copy happens at save() call).
  * atomic  — readers only ever see fully-committed step dirs.
  * elastic — the manifest records the saving mesh; `restore` reassembles
              full arrays from any shard layout and re-shards to the
              current mesh (data-axis contraction after a node loss).
  * self-validating — manifest carries a content hash per shard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import warnings
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: everything a torn / truncated / corrupted step dir can throw at the
#: reader: missing files (OSError, which IOError aliases), torn
#: manifest json (json.JSONDecodeError ⊂ ValueError), truncated npz
#: (zipfile.BadZipFile), short raw buffers (ValueError), manifest
#: missing keys (KeyError).
_RESTORE_ERRORS = (OSError, ValueError, KeyError, zipfile.BadZipFile)


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.dir = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: Optional[threading.Thread] = None
        self._write_exc: Optional[BaseException] = None
        #: step actually used by the last successful restore() (it may
        #: have fallen back from the requested/latest step)
        self.last_restored_step: Optional[int] = None
        os.makedirs(directory, exist_ok=True)
        self._sweep_stale_tmp()

    def _sweep_stale_tmp(self) -> None:
        """Remove `step_*.tmp` litter from a writer that crashed
        mid-save in a previous life. Readers never see tmp dirs
        (``all_steps`` filters them), but the litter would block the
        atomic rename of a later save of the same step on platforms
        where rename-onto-nonempty-dir fails — and it wastes disk.
        Construction is the safe moment: this manager has no save in
        flight yet, and a committed dir is never named ``.tmp``."""
        for name in os.listdir(self.dir):
            if name.startswith("step_") and name.endswith(".tmp"):
                path = os.path.join(self.dir, name)
                warnings.warn(f"sweeping stale checkpoint tmp dir {path} "
                              f"(crashed mid-save)")
                if os.path.isdir(path):
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    os.unlink(path)

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.dir, f"step_{step:09d}" + (".tmp" if tmp else ""))

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        """Device→host copy now; serialization + fsync on the writer thread."""
        items, _ = _flatten(tree)
        host_items = [(k, np.asarray(v)) for k, v in items]
        self.wait()   # one in-flight save at a time
        self._thread = threading.Thread(
            target=self._write, args=(step, host_items, extra or {}))
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        """Join the in-flight save. A background ``_write`` failure is
        captured on the writer thread and re-raised HERE (and therefore
        at the next ``save()``, which waits first) — silently losing
        checkpoints is how a later host failure becomes unrecoverable."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._write_exc is not None:
            exc, self._write_exc = self._write_exc, None
            raise exc

    def _write(self, step: int, host_items, extra: Dict) -> None:
        try:
            self._write_inner(step, host_items, extra)
        except BaseException as e:          # noqa: BLE001 — re-raised at wait()
            self._write_exc = e

    def _write_inner(self, step: int, host_items, extra: Dict) -> None:
        tmp = self._step_dir(step, tmp=True)
        final = self._step_dir(step)
        os.makedirs(tmp, exist_ok=True)
        # byte-view every leaf: npz has no bfloat16; manifest keeps dtype
        arrays = {
            f"leaf_{i}": np.frombuffer(
                np.ascontiguousarray(v).tobytes(), np.uint8)
            for i, (_, v) in enumerate(host_items)}
        shard_path = os.path.join(tmp, f"shard_{self.host_id:05d}.npz")
        np.savez(shard_path, **arrays)
        digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "keys": [k for k, _ in host_items],
            "shapes": [list(v.shape) for _, v in host_items],
            "dtypes": [str(v.dtype) for _, v in host_items],
            "shard_hash": {str(self.host_id): digest},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(shard_path, os.path.join(tmp, os.path.basename(shard_path)))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def restore(self, step: Optional[int], like_tree,
                shardings=None) -> Tuple[Any, Dict]:
        """Rebuild the pytree (re-sharded to `shardings` if given).

        Verifies content hashes. A hash-mismatched, truncated, or
        otherwise unreadable step dir is *not* fatal: it warns and
        falls back to the newest earlier committed step, raising only
        when no restorable checkpoint exists — a single corrupt shard
        costing a step of progress beats it killing the run. The step
        actually used is recorded in ``self.last_restored_step``."""
        steps = self.all_steps()
        if step is None:
            candidates = list(reversed(steps))
        else:
            candidates = [step] + [s for s in reversed(steps) if s < step]
        assert candidates, "no checkpoint found"
        errors: List[str] = []
        for s in candidates:
            try:
                tree, extra = self._restore_step(s, like_tree)
            except _RESTORE_ERRORS as e:
                errors.append(f"step {s}: {type(e).__name__}: {e}")
                if len(candidates) > len(errors):
                    warnings.warn(
                        f"checkpoint step {s} unreadable "
                        f"({type(e).__name__}: {e}); falling back to the "
                        f"newest earlier committed step")
                continue
            self.last_restored_step = s
            if shardings is not None:
                tree = jax.device_put(tree, shardings)
            return tree, extra
        raise IOError("no restorable checkpoint in "
                      f"{self.dir}; tried: " + "; ".join(errors))

    def _restore_step(self, step: int, like_tree) -> Tuple[Any, Dict]:
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shard_path = os.path.join(d, f"shard_{self.host_id:05d}.npz")
        digest = hashlib.sha256(open(shard_path, "rb").read()).hexdigest()
        want = manifest["shard_hash"].get(str(self.host_id))
        if want is not None and digest != want:
            raise IOError(f"checkpoint shard corrupt at step {step}")
        data = np.load(shard_path)
        flat_like, treedef = jax.tree_util.tree_flatten(like_tree)
        if len(flat_like) != len(manifest["keys"]):
            raise ValueError(
                f"tree structure changed: checkpoint has "
                f"{len(manifest['keys'])} leaves, caller expects "
                f"{len(flat_like)}")
        out = []
        for i, like in enumerate(flat_like):
            raw = data[f"leaf_{i}"]
            dtype = jnp.dtype(manifest["dtypes"][i])
            shape = tuple(manifest["shapes"][i])
            arr = jnp.asarray(
                np.frombuffer(raw.tobytes(), dtype).reshape(shape),
                dtype=like.dtype)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
