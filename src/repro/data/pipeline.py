"""Deterministic, resumable synthetic-token data pipeline.

Every batch is a pure function of (seed, step), so restart-from-
checkpoint reproduces the exact token stream with no persisted cursor
beyond the step counter — the property the fault-tolerance layer relies
on. Sharding: each data-parallel host materializes only its slice
(host_id, num_hosts), as a real loader would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    # synthetic-LM structure: repeated motifs make the loss learnable,
    # with per-example difficulty variation (exercises importance sampling)
    n_motifs: int = 64
    motif_len: int = 8


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        return cls(step=int(d["step"]))


class SyntheticLM:
    """Motif-mixture LM stream. Deterministic in (seed, step, host)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        base = np.random.default_rng(cfg.seed)
        self.motifs = base.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id, 0xDA7A))
        b, s = self.local_batch, cfg.seq
        # per-example noise level → heterogeneous gradient norms
        noise_p = rng.uniform(0.0, 0.9, size=(b, 1))
        n_slots = s // cfg.motif_len + 1
        motif_ids = rng.integers(0, cfg.n_motifs, size=(b, n_slots))
        seqs = self.motifs[motif_ids].reshape(b, -1)[:, :s]
        noise = rng.integers(0, cfg.vocab, size=(b, s))
        take_noise = rng.uniform(size=(b, s)) < noise_p
        ids = np.where(take_noise, noise, seqs)
        labels = np.roll(ids, -1, axis=1)
        labels[:, -1] = ids[:, 0]
        return {"ids": jnp.asarray(ids, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
