"""Deterministic, resumable synthetic-token data pipeline.

Every batch is a pure function of (seed, step), so restart-from-
checkpoint reproduces the exact token stream with no persisted cursor
beyond the step counter — the property the fault-tolerance layer relies
on. Sharding: each data-parallel host materializes only its slice
(host_id, num_hosts), as a real loader would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    # synthetic-LM structure: repeated motifs make the loss learnable,
    # with per-example difficulty variation (exercises importance sampling)
    n_motifs: int = 64
    motif_len: int = 8


@dataclasses.dataclass
class PipelineState:
    """Everything needed to resume the stream: the step cursor and the
    seed that generated it. Persisted in checkpoint ``extra`` so a
    restore can *verify* it is replaying the same stream rather than
    silently training on different data."""
    step: int = 0
    seed: int = 0

    def to_dict(self) -> Dict:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        missing = [k for k in ("step", "seed") if k not in d]
        if missing:
            raise ValueError(
                f"pipeline state is missing key(s) {missing} (have "
                f"{sorted(d)}); refusing to resume onto an unknown "
                f"data cursor")
        return cls(step=int(d["step"]), seed=int(d["seed"]))


class SyntheticLM:
    """Motif-mixture LM stream. Deterministic in (seed, step, host)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        base = np.random.default_rng(cfg.seed)
        self.motifs = base.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len))

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id, 0xDA7A))
        b, s = self.local_batch, cfg.seq
        # per-example noise level → heterogeneous gradient norms
        noise_p = rng.uniform(0.0, 0.9, size=(b, 1))
        n_slots = s // cfg.motif_len + 1
        motif_ids = rng.integers(0, cfg.n_motifs, size=(b, n_slots))
        seqs = self.motifs[motif_ids].reshape(b, -1)[:, :s]
        noise = rng.integers(0, cfg.vocab, size=(b, s))
        take_noise = rng.uniform(size=(b, s)) < noise_p
        ids = np.where(take_noise, noise, seqs)
        labels = np.roll(ids, -1, axis=1)
        labels[:, -1] = ids[:, 0]
        return {"ids": jnp.asarray(ids, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


# --- elastic host renumbering ----------------------------------------------
#
# The fault-tolerance layer needs the *global* token stream to be
# invariant under topology changes: after a contraction from 8 hosts to
# 4, the uninterrupted-run batches must still be reproducible, or every
# recovery silently changes the data. The trick is to fix the shard
# grid at launch ("logical shards" — one per host of the LAUNCH
# topology) and renumber only the *ownership* of shards when hosts come
# and go. Each logical shard is a pure (seed, step, shard) stream, so
# the concatenation over shards — the global batch — never depends on
# which physical host happens to own which shard.

def assign_logical_shards(n_logical: int,
                          active_hosts: Sequence[int]) -> Dict[int, List[int]]:
    """Order-preserving, contiguous, balanced assignment of the fixed
    logical shard grid onto the (sorted) active host set: the k-th
    active host owns shards [k·m, (k+1)·m). Order preservation is what
    keeps the assembled global batch equal to the logical-order
    concatenation after any retopologize."""
    hosts = sorted(active_hosts)
    if not hosts:
        raise ValueError("no active hosts to assign shards to")
    if n_logical % len(hosts):
        raise ValueError(
            f"{n_logical} logical shards do not divide over "
            f"{len(hosts)} hosts; contract/expand to a divisor "
            f"(power-of-two topologies guarantee this)")
    m = n_logical // len(hosts)
    return {h: list(range(k * m, (k + 1) * m))
            for k, h in enumerate(hosts)}


class LogicalShardedLM:
    """``SyntheticLM`` over a fixed logical shard grid.

    ``n_logical`` is pinned at launch (normally the launch topology's
    host count) and never changes; physical hosts own shard subsets via
    ``assign_logical_shards``. ``global_batch_at(step)`` is therefore a
    pure function of (cfg.seed, step) alone — the invariant the soak
    harness (launch/soak.py) asserts across kill/contract/expand."""

    def __init__(self, cfg: DataConfig, n_logical: int):
        if cfg.global_batch % n_logical:
            raise ValueError(f"global_batch {cfg.global_batch} not "
                             f"divisible into {n_logical} logical shards")
        self.cfg = cfg
        self.n_logical = n_logical
        self.shards = [SyntheticLM(cfg, host_id=i, num_hosts=n_logical)
                       for i in range(n_logical)]

    def shard_batch_at(self, step: int, shard_ids: Sequence[int]):
        """One physical host's slice: its owned logical shards, in
        shard order."""
        parts = [self.shards[i].batch_at(step) for i in shard_ids]
        return jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def global_batch_at(self, step: int,
                        owned: Optional[Dict[int, List[int]]] = None):
        """The full global batch. With ``owned`` (host → shard list),
        assembled the way the cluster would — host by host in sorted
        host order — which equals the logical-order concatenation iff
        the assignment is order-preserving: feeding this to the
        data-replay invariant is what catches renumbering bugs."""
        if owned is None:
            return self.shard_batch_at(step, range(self.n_logical))
        order = [i for h in sorted(owned) for i in owned[h]]
        return self.shard_batch_at(step, order)

    def batch_at(self, step: int):
        """Trainer data-source protocol (the full global batch)."""
        return self.global_batch_at(step)
