"""repro.data"""
