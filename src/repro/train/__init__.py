"""repro.train"""
