"""Training loop with the paper's machinery as first-class step modes.

Step modes:
  plain       — standard grads (taps DCE'd away; zero overhead)
  norms       — grads + per-example norms in one backward (paper §4/§5)
  clip        — per-example clipping, two-pass ghost form (paper §6)
  importance  — norms on a candidate pool → sample ∝ norm → weighted
                step on the subsample (Zhao & Zhang; paper §1)

Every per-example pass routes through one pex v2 ``Engine``
(``core.engine``): the Trainer takes the v2 canonical loss
``loss_fn(params, batch, tap) -> (loss_vec, aux)`` and the Engine
dispatches single-device vs. the data-parallel shard_map pipeline from
its mesh.

Integrates: microbatch gradient accumulation, optional int8
error-feedback compression, async checkpointing, heartbeats, straggler
stats, deterministic resume.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import importance, taps
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig, PipelineState, SyntheticLM
from repro.ft.heartbeat import HeartbeatConfig, HeartbeatMonitor
from repro.optim import adamw, grad_compress


@dataclasses.dataclass
class TrainConfig:
    mode: str = "norms"          # plain | norms | clip | importance
    clip_norm: float = 1.0
    noise_std: float = 0.0       # >0 + clip ⇒ DP-SGD
    candidate_factor: int = 4    # importance: pool = factor × batch
    importance_smoothing: float = 0.2
    microbatches: int = 1
    compress_grads: bool = False
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, loss_fn: Callable, params, pex_spec: PexSpec,
                 opt_cfg: adamw.AdamWConfig, train_cfg: TrainConfig,
                 data_cfg: DataConfig, *, mesh=None, data_axes=("data",)):
        """``loss_fn`` is the v2 canonical tap-collector loss
        (``registry.make_loss_fn_v2``). ``mesh=None`` runs
        single-device; a mesh routes every per-example transform
        through the data-parallel shard_map pipeline (dist.pex) with
        gradients psum'd across ``data_axes``."""
        self.loss_fn = loss_fn
        self.cfg = train_cfg
        self.opt_cfg = opt_cfg
        spec = pex_spec if train_cfg.mode != "plain" else taps.DISABLED
        self.engine = Engine(spec, mesh=mesh, data_axes=data_axes,
                             clip_norm=train_cfg.clip_norm,
                             noise_std=train_cfg.noise_std)
        self.data = SyntheticLM(data_cfg)
        self.params = params
        self.opt_state = adamw.init(params)
        self.err = grad_compress.init_error(params) \
            if train_cfg.compress_grads else None
        self.step = 0
        self.rng = jax.random.PRNGKey(train_cfg.seed)
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir) \
            if train_cfg.ckpt_dir else None
        self.metrics: list = []
        self._step_fn = self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, loss_fn, opt_cfg = self.cfg, self.loss_fn, self.opt_cfg
        eng = self.engine

        @jax.jit
        def plain_or_norms(params, opt_state, err, batch):
            res = eng.value_grads_and_norms(loss_fn, params, batch)
            grads = res.grads
            if err is not None:
                grads, err = grad_compress.compress_decompress(grads, err)
            params, opt_state = adamw.update(opt_cfg, opt_state, params, grads)
            return params, opt_state, err, res.loss, res.sq_norms

        @jax.jit
        def clip_step(params, opt_state, err, batch, rng):
            res = eng.clipped_step(loss_fn, params, batch, rng=rng)
            grads = res.grads
            if err is not None:
                grads, err = grad_compress.compress_decompress(grads, err)
            params, opt_state = adamw.update(opt_cfg, opt_state, params, grads)
            return params, opt_state, err, res.loss, res.sq_norms

        @partial(jax.jit, static_argnames=("take",))
        def importance_select(params, batch, rng, take):
            res = eng.value_and_norms(loss_fn, params, batch)
            samp = importance.sample(rng, res.sq_norms, take,
                                     smoothing=cfg.importance_smoothing)
            return samp.indices, samp.weights, res.sq_norms

        @jax.jit
        def weighted_step(params, opt_state, err, batch, weights):
            def f(p):
                lv, _ = loss_fn(p, batch, taps.NULL)
                return jnp.sum(weights * lv), lv

            (loss, lv), grads = jax.value_and_grad(f, has_aux=True)(params)
            if err is not None:
                grads, err = grad_compress.compress_decompress(grads, err)
            params, opt_state = adamw.update(opt_cfg, opt_state, params, grads)
            return params, opt_state, err, loss

        return {"plain": plain_or_norms, "norms": plain_or_norms,
                "clip": clip_step, "importance":
                (importance_select, weighted_step)}[cfg.mode]

    # ------------------------------------------------------------------
    def run_step(self, batch) -> Dict:
        b = batch["ids"].shape[0]
        t0 = time.perf_counter()
        if self.cfg.mode in ("plain", "norms"):
            (self.params, self.opt_state, self.err, loss,
             sq) = self._step_fn(self.params, self.opt_state, self.err,
                                 batch)
        elif self.cfg.mode == "clip":
            self.rng, sub = jax.random.split(self.rng)
            (self.params, self.opt_state, self.err, loss,
             sq) = self._step_fn(self.params, self.opt_state, self.err,
                                 batch, sub)
        else:  # importance
            select, wstep = self._step_fn
            self.rng, sub = jax.random.split(self.rng)
            take = b // self.cfg.candidate_factor
            idx, w, sq = select(self.params, batch, sub, take)
            sub_batch = importance.gather_batch(batch, idx)
            (self.params, self.opt_state, self.err,
             loss) = wstep(self.params, self.opt_state, self.err,
                           sub_batch, w)
        jax.block_until_ready(loss)
        dt = time.perf_counter() - t0
        m = {"step": self.step, "loss": float(loss), "time_s": dt}
        if self.cfg.mode in ("norms", "clip"):
            sqs = jnp.sum(sq, -1)
            m["norm_mean"] = float(jnp.mean(jnp.sqrt(sqs)))
            m["norm_max"] = float(jnp.max(jnp.sqrt(sqs)))
        self.metrics.append(m)
        return m

    def train(self, resume: bool = False) -> list:
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            state = {"params": self.params, "mu": self.opt_state.mu,
                     "nu": self.opt_state.nu}
            restored, extra = self.ckpt.restore(None, state)
            self.params = restored["params"]
            self.opt_state = adamw.AdamWState(
                jnp.asarray(extra["opt_step"], jnp.int32),
                restored["mu"], restored["nu"])
            self.step = int(extra["step"])
        while self.step < self.cfg.steps:
            batch = self.data.batch_at(self.step)
            m = self.run_step(batch)
            self.step += 1
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"[{self.step}] " + " ".join(
                    f"{k}={v:.4g}" for k, v in m.items() if k != "step"))
            if self.ckpt and self.step % self.cfg.ckpt_every == 0:
                self.ckpt.save(
                    self.step,
                    {"params": self.params, "mu": self.opt_state.mu,
                     "nu": self.opt_state.nu},
                    extra={"step": self.step,
                           "opt_step": int(self.opt_state.step)})
        if self.ckpt:
            self.ckpt.wait()
        return self.metrics
