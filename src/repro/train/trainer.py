"""Training loop over declarative consumer plans (DESIGN.md §9).

The old fixed step modes (plain / norms / clip / importance) are gone
as trainer concepts: a step is described by a **consumer list** and the
pex v2 ``Engine.step`` compiles it into one fused pass —

    TrainConfig(consumers=(pex.Clip(1.0), pex.Noise(0.1), pex.GNS()))

runs DP-SGD clipping, noise, and gradient-noise-scale telemetry off a
single tapped forward + activation backward + one reweighted backward.
``Noise``/``Importance`` entries may leave ``rng=None``; the trainer
splits its step key into them. ``consumers_for_mode`` maps the legacy
mode names (the launcher CLI still speaks them) onto consumer lists.

Every per-example pass routes through one ``Engine`` (``core.engine``):
the Trainer takes the v2 canonical loss
``loss_fn(params, batch, tap) -> (loss_vec, aux)`` and the Engine
dispatches single-device vs. the data-parallel shard_map pipeline from
its mesh.

Integrates: microbatch gradient accumulation, optional int8
error-feedback compression, async checkpointing, heartbeats, straggler
stats, deterministic resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.core.engine import infer_batch_size
from repro.data.pipeline import DataConfig, PipelineState, SyntheticLM
from repro.ft.heartbeat import HeartbeatConfig, HeartbeatMonitor
from repro.optim import adamw, grad_compress


@dataclasses.dataclass
class TrainConfig:
    #: the consumer plan for every step; None ⇒ (Norms(), Grads()) —
    #: the classic grads+norms-in-one-backward step
    consumers: Optional[Sequence] = None
    microbatches: int = 1
    compress_grads: bool = False
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0


def consumers_for_mode(mode: str, batch_size: int, *,
                       clip_norm: float = 1.0, noise_std: float = 0.0,
                       candidate_factor: int = 4,
                       importance_smoothing: float = 0.2) -> Tuple:
    """Legacy mode names → consumer lists (the launcher CLI contract).

    plain       — gradient only (no instrumentation traced at all)
    norms       — grads + per-example norms in one backward (§4/§5)
    clip        — per-example clipping (§6); + Noise when noise_std>0
    importance  — norms on the pool → sample batch/candidate_factor
                  examples ∝ norm → weighted step on the sub-batch
    """
    if mode == "plain":
        return (plan_mod.Grads(),)
    if mode == "norms":
        return (plan_mod.Norms(), plan_mod.Grads())
    if mode == "clip":
        cons = [plan_mod.Norms(), plan_mod.Clip(clip_norm)]
        if noise_std > 0.0:
            cons.append(plan_mod.Noise(noise_std))
        return tuple(cons)
    if mode == "importance":
        return (plan_mod.Importance(batch_size // candidate_factor,
                                    smoothing=importance_smoothing),
                plan_mod.Grads())
    raise ValueError(f"unknown mode {mode!r}; have plain/norms/clip/"
                     f"importance (or pass TrainConfig(consumers=...))")


def _inject_rngs(consumers: Sequence, rng: jax.Array):
    """Fill rng=None slots of Noise/Importance consumers from the step
    key (one split per slot, order-stable)."""
    need = [c for c in consumers
            if isinstance(c, (plan_mod.Noise, plan_mod.Importance))
            and c.rng is None]
    if not need:
        return tuple(consumers)
    keys = iter(jax.random.split(rng, len(need)))
    return tuple(dataclasses.replace(c, rng=next(keys)) if c in need else c
                 for c in consumers)


#: checkpoint ``extra`` keys a resume refuses to run without: the step
#: cursor, the optimizer step, the trainer seed, and the data-pipeline
#: state (which carries its own step/seed — PipelineState.from_dict
#: validates those).
RESUME_EXTRA_KEYS = ("step", "opt_step", "seed", "data")


class Trainer:
    def __init__(self, loss_fn: Callable, params, pex_spec: PexSpec,
                 opt_cfg: adamw.AdamWConfig, train_cfg: TrainConfig,
                 data_cfg: DataConfig, *, mesh=None, data_axes=("data",),
                 data=None):
        """``loss_fn`` is the v2 canonical tap-collector loss
        (``registry.make_loss_fn_v2``). ``mesh=None`` runs
        single-device; a mesh routes every per-example transform
        through the data-parallel shard_map pipeline (dist.pex) with
        gradients psum'd across ``data_axes``. ``data`` overrides the
        default ``SyntheticLM(data_cfg)`` with any source exposing
        ``batch_at(step)`` (e.g. the soak harness's
        ``LogicalShardedLM``)."""
        self.loss_fn = loss_fn
        self.cfg = train_cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.consumers = tuple(train_cfg.consumers) \
            if train_cfg.consumers is not None \
            else (plan_mod.Norms(), plan_mod.Grads())
        if not any(isinstance(c, (plan_mod.Grads, plan_mod.Clip,
                                  plan_mod.Noise, plan_mod.GNS))
                   for c in self.consumers):
            raise ValueError(
                f"training needs a gradient-producing consumer "
                f"(Grads/Clip/Noise/GNS); got {self.consumers}")
        self.pex_spec = pex_spec
        self.data_axes = data_axes
        self.engine = Engine(pex_spec, mesh=mesh, data_axes=data_axes)
        self.data = data if data is not None else SyntheticLM(data_cfg)
        self.params = params
        self.opt_state = adamw.init(params)
        self.err = grad_compress.init_error(params) \
            if train_cfg.compress_grads else None
        self.step = 0
        self.rng = jax.random.PRNGKey(train_cfg.seed)
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir) \
            if train_cfg.ckpt_dir else None
        self.metrics: list = []
        #: graceful-degradation log: quarantine / skip events
        self.events: list = []
        self._step_fn = self._build_step()
        self._step_fn_weighted = None       # built on first quarantine

    # ------------------------------------------------------------------
    def _build_step(self, weighted: bool = False):
        loss_fn, opt_cfg = self.loss_fn, self.opt_cfg
        consumers, eng = self.consumers, self.engine

        @jax.jit
        def step_fn(params, opt_state, err, batch, rng, *loss_weights):
            res = eng.step(loss_fn, params, batch,
                           consumers=_inject_rngs(consumers, rng),
                           loss_weights=loss_weights[0] if weighted
                           else None)
            grads = res.grads
            if err is not None:
                grads, err = grad_compress.compress_decompress(grads, err)
            params, opt_state = adamw.update(opt_cfg, opt_state, params,
                                             grads)
            return params, opt_state, err, res

        return step_fn

    # -- graceful degradation -------------------------------------------
    def _quarantine_mask(self, res) -> Optional[np.ndarray]:
        """True for examples whose loss and per-example norms are
        finite. None when every example is bad (nothing to salvage)."""
        mask = np.isfinite(np.asarray(res.loss_vec, np.float32))
        if res.sq_norms is not None:
            per_ex = np.asarray(res.sq_norms, np.float32)
            mask &= np.isfinite(per_ex).all(axis=tuple(range(1, per_ex.ndim)))
        return mask if mask.any() else None

    @staticmethod
    def _substitute_rows(batch, mask: np.ndarray):
        """Replace quarantined rows with the first healthy row. The
        zero loss-weight removes the substitute's (finite) gradient
        contribution *exactly*; substitution only keeps NaNs produced
        in the forward out of the program — a zero cotangent seed does
        not (0·NaN = NaN), so masking by weight alone cannot quarantine
        an example whose activations are already poisoned."""
        b = infer_batch_size(batch)
        donor = int(np.argmax(mask))
        keep = jnp.asarray(mask)

        def sub(x):
            if x.ndim == 0 or x.shape[0] != b:
                return x
            m = keep.reshape((b,) + (1,) * (x.ndim - 1))
            return jnp.where(m, x, x[donor][None])

        return jax.tree_util.tree_map(sub, batch)

    # ------------------------------------------------------------------
    def run_step(self, batch) -> Dict:
        t0 = time.perf_counter()
        self.rng, sub = jax.random.split(self.rng)
        params, opt_state, err, res = self._step_fn(
            self.params, self.opt_state, self.err, batch, sub)
        loss = float(res.loss)
        bad = not np.isfinite(loss)
        if not bad and res.sq_norms is not None:
            bad = not bool(jnp.all(jnp.isfinite(res.sq_norms)))
        quarantined = 0
        if bad:
            # non-finite loss/grad: the per-example norms (and losses)
            # the pass already computed identify the poisoned examples;
            # reweight them out through the plan's loss_weights= path
            # and retry — skip examples, not steps (DESIGN.md §11)
            mask = self._quarantine_mask(res)
            if mask is None:
                self.events.append({"step": self.step, "kind": "skip_step",
                                    "reason": "every example non-finite"})
                dt = time.perf_counter() - t0
                m = {"step": self.step, "loss": loss, "time_s": dt,
                     "skipped": 1}
                self.metrics.append(m)
                return m          # previous params/opt_state kept
            quarantined = int((~mask).sum())
            self.events.append({
                "step": self.step, "kind": "quarantine",
                "examples": [int(i) for i in np.flatnonzero(~mask)]})
            if self._step_fn_weighted is None:
                self._step_fn_weighted = self._build_step(weighted=True)
            params, opt_state, err, res = self._step_fn_weighted(
                self.params, self.opt_state, self.err,
                self._substitute_rows(batch, mask), sub,
                jnp.asarray(mask, jnp.float32))
            loss = float(res.loss)
        self.params, self.opt_state, self.err = params, opt_state, err
        jax.block_until_ready(res.loss)
        dt = time.perf_counter() - t0
        m = {"step": self.step, "loss": loss, "time_s": dt}
        if quarantined:
            m["quarantined"] = quarantined
        if res.sq_norms is not None:
            sqs = jnp.sum(res.sq_norms, -1)
            m["norm_mean"] = float(jnp.mean(jnp.sqrt(sqs)))
            m["norm_max"] = float(jnp.max(jnp.sqrt(sqs)))
        if res.gns is not None:
            m["gns"] = float(res.gns)
        self.metrics.append(m)
        return m

    # -- checkpoint plumbing --------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "mu": self.opt_state.mu,
                "nu": self.opt_state.nu}

    def _ckpt_extra(self) -> Dict:
        return {"step": self.step, "opt_step": int(self.opt_state.step),
                "seed": self.cfg.seed,
                "data": PipelineState(step=self.step,
                                      seed=self.data_cfg.seed).to_dict()}

    def save_checkpoint(self, block: bool = False) -> None:
        assert self.ckpt is not None, "no ckpt_dir configured"
        self.ckpt.save(self.step, self._state_tree(),
                       extra=self._ckpt_extra(), block=block)

    def _validate_extra(self, extra: Dict) -> PipelineState:
        missing = [k for k in RESUME_EXTRA_KEYS if k not in extra]
        if missing:
            raise ValueError(
                f"checkpoint extra is missing key(s) {missing} (have "
                f"{sorted(extra)}); refusing to resume — a checkpoint "
                f"without step/seed/pipeline state cannot be replayed "
                f"deterministically")
        if int(extra["seed"]) != self.cfg.seed:
            raise ValueError(
                f"checkpoint was written by a run with seed="
                f"{extra['seed']}, this trainer has seed={self.cfg.seed}; "
                f"resuming would fork the rng/noise stream")
        ps = PipelineState.from_dict(extra["data"])
        if ps.seed != self.data_cfg.seed:
            raise ValueError(
                f"checkpoint data stream has seed={ps.seed}, this "
                f"trainer's pipeline has seed={self.data_cfg.seed}; "
                f"resuming would replay different batches")
        return ps

    def restore_from(self, step: Optional[int] = None,
                     shardings=None) -> int:
        """Restore params/opt-state/step from the newest restorable
        checkpoint (≤ ``step`` if given; CheckpointManager falls back
        past corrupt ones), after validating the ``extra`` payload.
        Returns the step actually restored."""
        assert self.ckpt is not None, "no ckpt_dir configured"
        self.ckpt.wait()        # surface writer-thread failures first
        restored, extra = self.ckpt.restore(step, self._state_tree(),
                                            shardings=shardings)
        ps = self._validate_extra(extra)
        self.params = restored["params"]
        self.opt_state = adamw.AdamWState(
            jnp.asarray(extra["opt_step"], jnp.int32),
            restored["mu"], restored["nu"])
        self.step = int(extra["step"])
        assert ps.step == self.step, \
            f"pipeline cursor {ps.step} != trainer step {self.step}"
        return self.step

    # -- elastic rebinding ----------------------------------------------
    def rebind_mesh(self, mesh, data_axes=None) -> None:
        """Rebuild the engine and compiled step(s) on a new topology —
        the supervisor calls this after contraction/expansion. Params
        and optimizer state are re-placed by the next jitted step."""
        if data_axes is not None:
            self.data_axes = data_axes
        self.engine = Engine(self.pex_spec, mesh=mesh,
                             data_axes=self.data_axes)
        self._step_fn = self._build_step()
        self._step_fn_weighted = None

    # ------------------------------------------------------------------
    def train(self, resume: bool = False) -> list:
        if resume and self.ckpt and self.ckpt.latest_step() is not None:
            self.restore_from(None)
        while self.step < self.cfg.steps:
            batch = self.data.batch_at(self.step)
            m = self.run_step(batch)
            self.step += 1
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"[{self.step}] " + " ".join(
                    f"{k}={v:.4g}" for k, v in m.items() if k != "step"))
            if self.ckpt and self.step % self.cfg.ckpt_every == 0:
                self.save_checkpoint()
        if self.ckpt:
            self.ckpt.wait()
        return self.metrics
