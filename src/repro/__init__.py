"""repro — Efficient Per-Example Gradient Computations (Goodfellow 2015)
as a production-grade multi-pod JAX framework.

The paper's contribution lives in ``repro.core`` (cotangent-accumulator
taps + the estimator family); ``repro.kernels`` holds the Pallas TPU
kernels; everything else is the substrate that makes it deployable:
models (10 architectures), distribution, data, optimizers, training,
serving, checkpointing, fault tolerance, and the multi-pod dry-run /
roofline tooling under ``repro.launch`` / ``repro.roofline``.
"""
