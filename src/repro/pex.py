"""``repro.pex`` — the pex v2 public namespace (DESIGN.md §7).

Declare instrumentation once, tap anywhere:

    from repro import pex

    eng = pex.Engine(pex.PexSpec(method="auto"), mesh=mesh)
    res = eng.value_grads_and_norms(loss_fn, params, batch)

with models written against the trace-time collector::

    def loss_fn(params, batch, tap):
        h = tap.embedding(params["emb"], batch["ids"])
        z = tap.dense(h, params["w"], group="mlp")
        ...
        return loss_vec, {}

``pex.scan`` / ``pex.checkpoint`` thread the collector's accumulator
through ``lax.scan`` / ``jax.checkpoint`` boundaries; ``pex.NULL`` is
the inert tap for serving / oracle paths.
"""
from repro.core.passes import PexResult, clip_coefficients
from repro.core.engine import Engine, infer_batch_size, plain_engine
from repro.core.taps import (DISABLED, NULL, ExampleLayout, PexSpec, Tap,
                             TokenLayout, checkpoint, scan)

__all__ = [
    "Engine", "PexResult", "PexSpec", "Tap", "TokenLayout", "ExampleLayout",
    "DISABLED", "NULL", "scan", "checkpoint", "clip_coefficients",
    "infer_batch_size", "plain_engine",
]
