"""``repro.pex`` — the pex v2 public namespace (DESIGN.md §7, §9).

Declare instrumentation once, tap anywhere:

    from repro import pex

    eng = pex.Engine(pex.PexSpec(method="auto"), mesh=mesh)
    res = eng.step(loss_fn, params, batch,
                   consumers=[pex.Clip(1.0), pex.Noise(0.5, rng),
                              pex.GNS()])

with models written against the trace-time collector::

    def loss_fn(params, batch, tap):
        h = tap.embedding(params["emb"], batch["ids"])
        z = tap.dense(h, params["w"], group="mlp")
        ...
        token_losses = tap.token_loss(token_losses)   # (B, S) map
        return jnp.sum(token_losses, -1), {}

Consumers (``pex.Norms`` / ``Grads`` / ``Clip`` / ``Noise`` /
``Importance`` / ``GNS``) compose declaratively: ``Engine.step``
compiles any subset into one fused pass — a single tapped forward, one
activation backward for the shared norms, and at most one reweighted
backward (DESIGN.md §9). The fixed-function methods
(``value_and_norms`` et al.) remain as sugar.

``pex.scan`` / ``pex.checkpoint`` thread the collector's accumulator
through ``lax.scan`` / ``jax.checkpoint`` boundaries; ``pex.NULL`` is
the inert tap for serving / oracle paths.
"""
from repro.core.passes import PexResult, clip_coefficients
from repro.core.clipping import token_clip_coefficients
from repro.core.engine import Engine, infer_batch_size, plain_engine
from repro.core.plan import (GNS, Clip, Grads, Importance, Noise, Norms,
                             StepResult, gradient_noise_scale)
from repro.core.taps import (DISABLED, NULL, ExampleLayout, PexSpec, Tap,
                             TokenLayout, checkpoint, scan)

__all__ = [
    "Engine", "PexResult", "PexSpec", "Tap", "TokenLayout", "ExampleLayout",
    "DISABLED", "NULL", "scan", "checkpoint", "clip_coefficients",
    "token_clip_coefficients", "infer_batch_size", "plain_engine",
    "Norms", "Grads", "Clip", "Noise", "Importance", "GNS", "StepResult",
    "gradient_noise_scale",
]
