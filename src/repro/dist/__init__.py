"""Distribution substrate: rules-based sharding + the mesh-sharded
per-example-norm pipeline.

``repro.dist.sharding`` is the logical-axis layer every ``nn/`` module
talks to; ``repro.dist.pex`` lifts the ``core.passes`` per-example
transforms onto a device mesh with ``shard_map``. See DESIGN.md §4.

``pex`` loads lazily: it imports ``core.passes``, whose tap layer
imports ``dist.sharding`` — an eager import here would close that cycle
while ``core.passes`` is still half-initialized.
"""
from repro.dist import sharding

__all__ = ["sharding", "pex"]


def __getattr__(name):
    if name == "pex":
        import importlib
        return importlib.import_module("repro.dist.pex")
    raise AttributeError(f"module 'repro.dist' has no attribute {name!r}")
