import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# ^ MUST precede any jax-touching import (device count locks at first init).

_DOC = """Mesh-sharded per-example pipeline self-check.

Runs the tap-instrumented smoke model through a local ``Engine`` and
again through a mesh-bound ``Engine`` (the dist.pex shard_map pipeline)
on a ≥2-way data-parallel host mesh, and asserts the two agree: scalar
loss, (B,) per-example losses, (B, G) squared norms, summed gradients,
clipped gradients, and a fused consumer plan (Clip + GNS — DESIGN.md
§9) (f32 allclose). This is the
repo's executable proof that the per-example-norm math composes with
batch sharding — run it on any box:

    PYTHONPATH=src python -m repro.dist.selfcheck
    PYTHONPATH=src python -m repro.dist.selfcheck --arch llama3.2-1b --batch 16

``--model-parallel N`` exists to demonstrate the pinned-jax limit: any
N > 1 exits with the dist.pex NotImplementedError (shard_map
auto-subgroups crash XLA's SPMD partitioner on jax 0.4.x).
"""

import argparse
import sys


def run(arch: str = "llama3.2-1b", batch: int = 8, seq: int = 8,
        model_parallel: int = 1, method: str = "gram",
        verbose: bool = True) -> int:
    import jax
    import numpy as np

    from repro.configs.common import ShapeSpec
    from repro.core.engine import Engine
    from repro.core.taps import PexSpec
    from repro.dist import pex
    from repro.launch.mesh import make_host_mesh
    from repro.models import registry
    from repro.nn.param import unbox

    n_dev = len(jax.devices())
    if n_dev < 2:
        print("selfcheck needs >=2 devices (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        return 2

    aspec = registry.get(arch)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    spec = PexSpec(enabled=True, method=method)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    batch_data = registry.make_train_batch(
        aspec, cfg, ShapeSpec("selfcheck", "train", seq, batch))

    mesh = make_host_mesh(model_parallel=model_parallel)
    n_shards = mesh.shape["data"]
    assert n_shards >= 2, f"only {n_shards} data shards; need >= 2"

    eng_local = Engine(spec)
    eng_mesh = Engine(spec, mesh=mesh)

    ref = jax.jit(lambda p, b: eng_local.value_grads_and_norms(
        loss_fn, p, b))(params, batch_data)
    got = jax.jit(lambda p, b: eng_mesh.value_grads_and_norms(
        loss_fn, p, b))(params, batch_data)

    ok = True

    def check(name, a, b, rtol=1e-5, atol=1e-6):
        nonlocal ok
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        good = np.allclose(a, b, rtol=rtol, atol=atol)
        ok &= good
        if verbose or not good:
            err = np.max(np.abs(a - b)) if a.size else 0.0
            print(f"[{'ok' if good else 'FAIL'}] {name:24s} "
                  f"max|Δ|={err:.3g}")

    check("loss", ref.loss, got.loss)
    check("loss_vec", ref.loss_vec, got.loss_vec)
    check("sq_norms", ref.sq_norms, got.sq_norms, rtol=1e-4)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref.grads),
            jax.tree_util.tree_leaves_with_path(got.grads)):
        check("grads" + jax.tree_util.keystr(pa), a, b, rtol=1e-4,
              atol=1e-5)

    ref_n = jax.jit(lambda p, b: eng_local.value_and_norms(
        loss_fn, p, b))(params, batch_data)
    got_n = jax.jit(lambda p, b: eng_mesh.value_and_norms(
        loss_fn, p, b))(params, batch_data)
    check("norms-only sq_norms", ref_n.sq_norms, got_n.sq_norms, rtol=1e-4)

    clip = 0.5 * float(np.sqrt(np.median(
        np.sum(np.asarray(ref.sq_norms), -1))))
    ref_c = jax.jit(lambda p, b: eng_local.clipped_step(
        loss_fn, p, b, clip_norm=clip))(params, batch_data)
    got_c = jax.jit(lambda p, b: eng_mesh.clipped_step(
        loss_fn, p, b, clip_norm=clip))(params, batch_data)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref_c.grads),
            jax.tree_util.tree_leaves_with_path(got_c.grads)):
        check("clipped" + jax.tree_util.keystr(pa), a, b, rtol=1e-4,
              atol=1e-5)

    # fused consumer plan (DESIGN.md §9): clip + GNS in one pass must
    # also agree across the shard boundary
    from repro.core import plan as plan_mod
    cons = [plan_mod.Clip(clip), plan_mod.GNS()]
    ref_p = jax.jit(lambda p, b: eng_local.step(
        loss_fn, p, b, consumers=cons))(params, batch_data)
    got_p = jax.jit(lambda p, b: eng_mesh.step(
        loss_fn, p, b, consumers=cons))(params, batch_data)
    check("plan gns", ref_p.gns, got_p.gns, rtol=1e-4)
    check("plan weights", ref_p.weights, got_p.weights, rtol=1e-4)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref_p.grads),
            jax.tree_util.tree_leaves_with_path(got_p.grads)):
        check("plan" + jax.tree_util.keystr(pa), a, b, rtol=1e-4,
              atol=1e-5)

    gns = pex.gradient_noise_scale(got.sq_norms, got.grads)
    print(f"{'PASS' if ok else 'FAIL'}: {n_shards}-way data-parallel "
          f"(model_parallel={model_parallel}) matches single-device; "
          f"B_simple={float(gns):.3g}")
    return 0 if ok else 1


def main():
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--method", default="gram")
    sys.exit(run(**{k.replace("-", "_"): v
                    for k, v in vars(ap.parse_args()).items()}))


if __name__ == "__main__":
    main()
