"""Mesh-sharded per-example-norm pipeline (DESIGN.md §4).

Lifts the ``core.passes`` transforms onto a device mesh with
``shard_map``: the batch is split over the data axes, each shard runs
the tap-instrumented model on its local examples, and only the
*parameter gradients* (and scalar loss) cross devices via ``psum``.
The per-example quantities — the (B,) loss vector and the (B, G)
squared norms — stay batch-sharded end to end, which is the whole
point: the accumulator technique adds no collective traffic (taps
docstring), and this module keeps that true on a mesh.

Clipping composes for free: the clip coefficient c_j depends only on
example j's own norm, so it is computed shard-locally and the clipped
gradients allreduce exactly like plain ones. DP-SGD noise is added
once, *after* the psum — adding it per-shard would inflate the noise
variance by the shard count. ``plan_step`` extends the same rules to
whole consumer plans (DESIGN.md §9): the fused norms→weights→
reweighted-backward core runs per shard inside one region, with only
the gradient psum crossing devices.

Mesh axes not named in ``data_axes`` (e.g. "model") are left in auto
mode, so the standard ``("data", "model")`` mesh from ``launch.mesh``
composes directly. On the pinned jax (0.4.x) XLA's manual-subgroup
SPMD support is incomplete: auto axes of extent > 1 crash the
partitioner, so those are rejected with an actionable error until the
toolchain moves — pure data parallelism (any extent, any number of
data axes) is fully supported.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import passes as api
from repro.core import plan as plan_mod
from repro.core.passes import PexResult
from repro.core.taps import PexSpec
from repro.dist import sharding as shd

DataAxes = Tuple[str, ...]


def _norm_axes(data_axes) -> DataAxes:
    if isinstance(data_axes, str):
        return (data_axes,)
    return tuple(data_axes)


def _reject_aux(aux) -> None:
    """The sharded paths return PexResult.aux == {}: an arbitrary aux
    pytree has no inferable out_spec (per-example? replicated?). Fail
    loudly at trace time instead of silently dropping real metrics."""
    if jax.tree_util.tree_leaves(aux):
        raise NotImplementedError(
            "loss_fn returned a non-empty aux pytree, which the sharded "
            "per-example pipeline does not thread through; fold metrics "
            "into loss_vec/sq_norms or run single-device (mesh=None)")


def _wrap(mesh: Mesh, data_axes: DataAxes, fn: Callable, n_out_sharded: int,
          n_out_replicated: int) -> Callable:
    """shard_map ``fn(params, batch) -> (*sharded, *replicated)``.

    ``sharded`` outputs carry a leading batch axis split over
    ``data_axes``; ``replicated`` outputs (scalar loss, psum'd grads)
    are identical on every shard. Axes outside ``data_axes`` stay auto
    so in-model tensor-parallel constraints keep working.
    """
    dp = P(data_axes)
    rest = frozenset(mesh.axis_names) - frozenset(data_axes)
    big_rest = [a for a in rest if mesh.shape[a] > 1]
    if big_rest:
        raise NotImplementedError(
            f"mesh axes {big_rest} (extent > 1) outside data_axes="
            f"{data_axes}: jax 0.4.x shard_map auto-subgroups crash "
            f"XLA's SPMD partitioner; run per-example sharding "
            f"data-parallel-only, or include the axis in data_axes")
    # extent-1 non-data axes are safely manual (replication over a
    # singleton is trivial), so no `auto=` — which 0.4.x also lacks
    # outside jit.
    out_specs = tuple([dp] * n_out_sharded + [P()] * n_out_replicated)

    def body(params, batch):
        # inside the fully-manual region every placement is already
        # decided, so in-model rules-based constraints (dist.sharding
        # .shard) must go quiet — they reference manual mesh axes,
        # which with_sharding_constraint rejects
        with shd.use_rules(None, {}):
            return fn(params, batch)

    return shard_map(body, mesh=mesh, in_specs=(P(), dp),
                     out_specs=out_specs, check_rep=False)


def value_and_norms(loss_fn: Callable, params, batch, spec: PexSpec,
                    batch_size: int, *, mesh: Optional[Mesh] = None,
                    data_axes: Sequence[str] = ("data",),
                    layout=None) -> PexResult:
    """Sharded norms-only pass. Single-device semantics when mesh=None.

    Returns the same PexResult as ``core.passes.value_and_norms``; the
    loss is the global scalar, ``loss_vec``/``sq_norms`` are the full
    (B,)/(B, G) arrays, laid out batch-sharded over ``data_axes``.
    ``aux`` is always {} on the mesh path (non-empty aux raises — see
    ``_reject_aux``); the grads/clipped variants share this contract.
    """
    if mesh is None:
        return api.value_and_norms(loss_fn, params, batch, spec, batch_size,
                                   layout=layout)
    data_axes = _norm_axes(data_axes)
    local_b = shd.local_batch(batch_size, data_axes, mesh)

    def run(p, b):
        r = api.value_and_norms(loss_fn, p, b, spec, local_b, layout=layout)
        _reject_aux(r.aux)
        return r.loss_vec, r.sq_norms, jax.lax.psum(r.loss, data_axes)

    loss_vec, sq, loss = _wrap(mesh, data_axes, run, 2, 1)(params, batch)
    return PexResult(loss, loss_vec, {}, sq)


def value_grads_and_norms(loss_fn: Callable, params, batch, spec: PexSpec,
                          batch_size: int, *, mesh: Optional[Mesh] = None,
                          data_axes: Sequence[str] = ("data",),
                          layout=None) -> PexResult:
    """Sharded headline pass: summed gradients (psum over the data
    axes) AND batch-sharded per-example norms in one backward."""
    if mesh is None:
        return api.value_grads_and_norms(loss_fn, params, batch, spec,
                                         batch_size, layout=layout)
    data_axes = _norm_axes(data_axes)
    local_b = shd.local_batch(batch_size, data_axes, mesh)

    def run(p, b):
        r = api.value_grads_and_norms(loss_fn, p, b, spec, local_b,
                                      layout=layout)
        _reject_aux(r.aux)
        return (r.loss_vec, r.sq_norms,
                jax.lax.psum(r.loss, data_axes),
                jax.lax.psum(r.grads, data_axes))

    loss_vec, sq, loss, grads = _wrap(mesh, data_axes, run, 2, 2)(
        params, batch)
    return PexResult(loss, loss_vec, {}, sq, grads)


def clipped_value_and_grads(loss_fn: Callable, params, batch, spec: PexSpec,
                            batch_size: int, clip_norm: float,
                            noise_std: float = 0.0,
                            noise_rng: Optional[jax.Array] = None, *,
                            mesh: Optional[Mesh] = None,
                            data_axes: Sequence[str] = ("data",),
                            layout=None) -> PexResult:
    """Sharded per-example clipping (paper §6, two-pass ghost form).

    c_j uses only example j's local norm, so both passes run entirely
    shard-local; one gradient psum at the end. Noise is added once to
    the reduced gradient (matching the single-device DP-SGD step), not
    per shard.
    """
    api.check_noise_args(noise_std, noise_rng)
    if mesh is None:
        return api.clipped_value_and_grads(loss_fn, params, batch, spec,
                                           batch_size, clip_norm,
                                           noise_std=noise_std,
                                           noise_rng=noise_rng, layout=layout)
    data_axes = _norm_axes(data_axes)
    local_b = shd.local_batch(batch_size, data_axes, mesh)

    def run(p, b):
        r = api.clipped_value_and_grads(loss_fn, p, b, spec, local_b,
                                        clip_norm, layout=layout)
        _reject_aux(r.aux)
        return (r.loss_vec, r.sq_norms,
                jax.lax.psum(r.loss, data_axes),
                jax.lax.psum(r.grads, data_axes))

    loss_vec, sq, loss, grads = _wrap(mesh, data_axes, run, 2, 2)(
        params, batch)
    if noise_std > 0.0:
        grads = api.add_grad_noise(grads, noise_std, clip_norm, noise_rng)
    return PexResult(loss, loss_vec, {}, sq, grads)


# --- consumer plans (DESIGN.md §9) -----------------------------------------

def plan_step(plan, acc_loss: Callable, params, batch, batch_size: int, *,
              mesh: Optional[Mesh], data_axes: Sequence[str] = ("data",),
              layout=None, loss_weights=None) -> plan_mod.StepResult:
    """Run a fused consumer plan under ``shard_map``.

    The single-region core (``plan.run_fused``) executes inside one
    shard_map body per fused stage: per-example losses, norms, and
    weights stay batch-sharded (a clip coefficient depends only on its
    own example's norm), gradients cross devices in ONE psum, and
    DP-SGD noise / GNS telemetry are applied by the shared driver
    *after* the region — noise once on the reduced gradient, GNS on
    the global arrays. ``Importance`` plans run two regions
    (norms-on-pool, reweighted grads on the gathered sub-batch) with
    the sampling and the gather on the global arrays between them —
    both the pool size and ``k`` must divide the data-shard count
    (``shd.local_batch`` raises otherwise).
    """
    if mesh is None:
        return plan_mod.execute(plan, acc_loss, params, batch, batch_size,
                                layout, loss_weights=loss_weights)
    data_axes = _norm_axes(data_axes)
    dp = P(data_axes)
    rest = frozenset(mesh.axis_names) - frozenset(data_axes)
    big_rest = [a for a in rest if mesh.shape[a] > 1]
    if big_rest:
        raise NotImplementedError(
            f"mesh axes {big_rest} (extent > 1) outside data_axes="
            f"{data_axes}: jax 0.4.x shard_map auto-subgroups crash "
            f"XLA's SPMD partitioner; run per-example sharding "
            f"data-parallel-only, or include the axis in data_axes")

    def fused_fn(sub, b, bs, lw):
        local_b = shd.local_batch(bs, data_axes, mesh)
        # presence of each optional output is static per sub-plan, so
        # the shard_map out_specs can be built up front
        # one sharded output slot per quantity the sub-plan produces
        # (static per plan): loss_vec, norms, the per-example weight
        # product, and the clip coefficients — which for a token clip
        # ARE the token weights, so they share a slot
        has_sq = sub.needs_norms
        has_w = lw is not None or (sub.clip is not None and
                                   sub.clip.granularity == "example")
        has_cc = sub.clip is not None
        has_grads = sub.needs_grads

        def run(p, bb, *maybe_lw):
            with shd.use_rules(None, {}):
                lv, aux, sq, grads, w, tw, cc = plan_mod.run_fused(
                    sub, acc_loss, p, bb, local_b, layout,
                    loss_weights=maybe_lw[0] if maybe_lw else None)
            _reject_aux(aux)
            outs = [lv]
            if has_sq:
                outs.append(sq)
            if has_w:
                outs.append(w)
            if has_cc:
                outs.append(cc)
            if has_grads:
                outs.append(jax.lax.psum(grads, data_axes))
            return tuple(outs)

        n_sharded = 1 + has_sq + has_w + has_cc
        out_specs = tuple([dp] * n_sharded + [P()] * int(has_grads))
        in_specs = (P(), dp) + ((dp,) if lw is not None else ())
        args = (params, b) + ((lw,) if lw is not None else ())
        res = list(shard_map(run, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)(*args))

        lv = res.pop(0)
        sq = res.pop(0) if has_sq else None
        w = res.pop(0) if has_w else None
        cc = res.pop(0) if has_cc else None
        tw = cc if sub.token_weighted else None
        grads = res.pop(0) if has_grads else None
        return lv, {}, sq, grads, w, tw, cc

    return plan_mod.execute(plan, acc_loss, params, batch, batch_size,
                            layout, loss_weights=loss_weights,
                            fused_fn=fused_fn)


# --- diagnostics -----------------------------------------------------------

def gradient_noise_scale(sq_norms: jax.Array, grads,
                         batch_size: Optional[int] = None) -> jax.Array:
    """B_simple = tr(Σ) / ||G||² — see ``core.plan.gradient_noise_scale``
    (the formula moved there with the plan layer; this re-export keeps
    the established dist-level entry point)."""
    return plan_mod.gradient_noise_scale(sq_norms, grads,
                                         batch_size=batch_size)
