"""Logical-axis sharding rules (DESIGN.md §4).

Model code names *logical* axes ("batch", "mlp", "heads_act", ...);
how those map onto mesh axes is a per-run decision carried by a rules
dict inside a ``use_rules`` context:

    with use_rules(mesh, {"batch": ("data",), "mlp": "model"}):
        param_sh = sharding_tree(axes_of(boxed_params))   # params
        y = shard(y, "batch", None, "mlp")                # activations

Outside any context (tests, single-device examples, benches) every
helper degrades to the identity, so the same model code runs unsharded
with zero overhead — ``shard(x, ...) is x``.

Rule values may be ``None`` (replicate), a mesh-axis name, or a tuple
of mesh-axis names (e.g. ``("pod", "data")`` for multi-pod data
parallelism). A logical axis absent from the rules replicates.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisRule = Union[None, str, Tuple[str, ...]]


def pad_to(n: int, multiple: int) -> int:
    """Round ``n`` up to the next multiple of ``multiple``."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return ((n + multiple - 1) // multiple) * multiple


# --- rules context ---------------------------------------------------------

class _RulesStack(threading.local):
    def __init__(self):
        self.stack = []


_ACTIVE = _RulesStack()


@contextmanager
def use_rules(mesh: Optional[Mesh],
              rules: Dict[str, AxisRule]) -> Iterator[None]:
    """Activate a (mesh, logical→mesh rules) pair for the dynamic extent.

    ``mesh=None`` keeps ``shard`` an identity while still letting
    ``spec`` resolve rules (useful for spec-only unit tests).
    Contexts nest; the innermost wins.
    """
    _ACTIVE.stack.append((mesh, dict(rules)))
    try:
        yield
    finally:
        _ACTIVE.stack.pop()


def current_rules() -> Tuple[Optional[Mesh], Dict[str, AxisRule]]:
    """(mesh, rules) of the innermost active context, or (None, {})."""
    if _ACTIVE.stack:
        return _ACTIVE.stack[-1]
    return None, {}


def active_mesh() -> Optional[Mesh]:
    return current_rules()[0]


# --- specs -----------------------------------------------------------------

def _resolve(rule: AxisRule) -> AxisRule:
    if isinstance(rule, (list, tuple)):
        flat = tuple(a for a in rule if a is not None)
        if not flat:
            return None
        return flat if len(flat) > 1 else flat[0]
    return rule


def spec(*axes: Optional[str]) -> PartitionSpec:
    """Logical axis names (None ⇒ replicated dim) → PartitionSpec.

    Names missing from the active rules replicate — new model code can
    introduce logical axes before every launch config maps them.
    """
    _, rules = current_rules()
    return PartitionSpec(
        *[None if ax is None else _resolve(rules.get(ax)) for ax in axes])


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the active mesh along logical ``axes``.

    Identity (returns ``x`` itself) when no mesh is active, so model
    code is sharding-annotated unconditionally at zero cost.
    """
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*axes)))


def sharding_for(axes: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None) -> NamedSharding:
    """NamedSharding for one logical-axes tuple (params' ``Boxed.axes``)."""
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None:
        raise RuntimeError("sharding_for requires a mesh "
                           "(pass one or enter use_rules(mesh, ...))")
    return NamedSharding(mesh, spec(*axes))


def sharding_tree(axes_tree: Any, mesh: Optional[Mesh] = None) -> Any:
    """Map a tree of logical-axes tuples (``param.axes_of``) to
    NamedShardings under the active rules."""
    return jax.tree_util.tree_map(
        lambda axes: sharding_for(axes, mesh), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


# --- mesh construction -----------------------------------------------------

def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` across jax versions: newer jax wants explicit
    Auto axis types for GSPMD-partitioned meshes; 0.4.x has neither the
    kwarg nor ``jax.sharding.AxisType``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(axis_type.Auto,) * len(axes))


def make_mesh_over(devices: Sequence, shape: Sequence[int],
                   axes: Sequence[str]) -> Mesh:
    """Mesh over an *explicit* device subset — the elastic
    contraction/expansion primitive: after a host loss the surviving
    devices (in renumbered order) become the new data axis, without
    touching the dead ones ``jax.make_mesh`` would insist on using.
    ``len(devices)`` must equal ``prod(shape)``."""
    import math

    import numpy as np
    n = math.prod(shape)
    if len(devices) != n:
        raise ValueError(f"{len(devices)} devices cannot fill a mesh of "
                         f"shape {tuple(shape)} (= {n})")
    arr = np.empty(n, dtype=object)
    for i, d in enumerate(devices):
        arr[i] = d
    return Mesh(arr.reshape(tuple(shape)), tuple(axes))


# --- mesh arithmetic -------------------------------------------------------

def axis_size(axes: AxisRule, mesh: Optional[Mesh] = None) -> int:
    """Product of mesh extents over ``axes`` (str | tuple | None)."""
    mesh = mesh if mesh is not None else active_mesh()
    if axes is None or mesh is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a is not None:
            n *= mesh.shape[a]
    return n


def local_batch(global_batch: int, data_axes: AxisRule,
                mesh: Optional[Mesh] = None) -> int:
    """Per-shard batch under data parallelism; must divide evenly (the
    data pipeline pads with ``pad_to`` before it ever reaches a mesh)."""
    n = axis_size(data_axes, mesh)
    if global_batch % n:
        raise ValueError(
            f"global batch {global_batch} not divisible by the "
            f"{n}-way data-parallel extent {data_axes!r}; pad with "
            f"pad_to({global_batch}, {n}) upstream")
    return global_batch // n
