"""Elastic scaling: recompute the mesh + data assignment after a host
set change, and reshard a checkpoint onto the new topology.

Strategy (contraction after failure):
  1. Drop dead hosts; keep the largest power-of-two data-axis size that
     the survivors support (model axis is fixed by the arch's TP plan).
  2. Rebuild data-pipeline host assignments (the pipeline is a pure
     function of (seed, step, host), so reassignment is just renumber).
  3. Restore the last checkpoint re-sharded to the new mesh —
     CheckpointManager.restore(shardings=new) handles placement.

Expansion (hosts return) is the same computation in reverse
(``plan_expansion``); the supervisor (ft/supervisor.py) drives both
directions through one restore-resharded-and-renumber path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class Topology:
    n_hosts: int
    devices_per_host: int
    model_parallel: int           # fixed by the arch sharding plan

    @property
    def n_devices(self) -> int:
        return self.n_hosts * self.devices_per_host


def _pow2_floor(n: int) -> int:
    usable = 1
    while usable * 2 <= n:
        usable *= 2
    return usable


def plan_contraction(topo: Topology, dead_hosts: List[int]) -> Topology:
    """Largest runnable topology after removing dead hosts."""
    survivors = topo.n_hosts - len(dead_hosts)
    if survivors * topo.devices_per_host < topo.model_parallel:
        raise RuntimeError("not enough devices for the model-parallel plan")
    # keep data axis a power of two for collective efficiency — but the
    # pow2 rounding must not drop below the model-parallel floor
    # (survivors=3, dph=4, mp=12 passes the raw check yet pow2(3)=2
    # hosts give only 8 devices)
    usable = _pow2_floor(survivors)
    if usable * topo.devices_per_host < topo.model_parallel:
        raise RuntimeError(
            f"survivors ({survivors} hosts) pass the raw device count but "
            f"the largest power-of-two host set ({usable}) gives "
            f"{usable * topo.devices_per_host} devices < model_parallel="
            f"{topo.model_parallel}")
    return dataclasses.replace(topo, n_hosts=usable)


def plan_expansion(topo: Topology, available_hosts: int) -> Topology:
    """Largest runnable topology on a now-available host pool — the
    reverse of ``plan_contraction``, used when hosts return after a
    failure. ``available_hosts`` counts every live host (current actives
    plus returnees); the result keeps the data axis a power of two and
    never exceeds the pool, so with the original host set back the
    original (pow2) topology is recovered exactly:
    ``plan_expansion(plan_contraction(t, dead), t.n_hosts) == t``."""
    if available_hosts < 1:
        raise RuntimeError("no hosts available to expand onto")
    usable = _pow2_floor(available_hosts)
    if usable * topo.devices_per_host < topo.model_parallel:
        raise RuntimeError(
            f"available pool ({available_hosts} hosts → pow2 {usable}) "
            f"gives {usable * topo.devices_per_host} devices < "
            f"model_parallel={topo.model_parallel}")
    return dataclasses.replace(topo, n_hosts=usable)


def mesh_shape(topo: Topology, multi_pod: bool = False) -> Tuple[int, ...]:
    data = topo.n_devices // topo.model_parallel
    if multi_pod:
        assert data % 2 == 0
        return (2, data // 2, topo.model_parallel)
    return (data, topo.model_parallel)


def reassign_data_hosts(old_hosts: List[int], dead: List[int],
                        new_count: int) -> List[int]:
    """Surviving hosts, renumbered into the contracted data layout."""
    alive = [h for h in old_hosts if h not in dead]
    return alive[:new_count]
