"""Elastic scaling: recompute the mesh + data assignment after a host
set change, and reshard a checkpoint onto the new topology.

Strategy (contraction after failure):
  1. Drop dead hosts; keep the largest power-of-two data-axis size that
     the survivors support (model axis is fixed by the arch's TP plan).
  2. Rebuild data-pipeline host assignments (the pipeline is a pure
     function of (seed, step, host), so reassignment is just renumber).
  3. Restore the last checkpoint re-sharded to the new mesh —
     CheckpointManager.restore(shardings=new) handles placement.

Expansion (hosts return) is the same computation in reverse.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class Topology:
    n_hosts: int
    devices_per_host: int
    model_parallel: int           # fixed by the arch sharding plan

    @property
    def n_devices(self) -> int:
        return self.n_hosts * self.devices_per_host


def plan_contraction(topo: Topology, dead_hosts: List[int]) -> Topology:
    """Largest runnable topology after removing dead hosts."""
    survivors = topo.n_hosts - len(dead_hosts)
    if survivors * topo.devices_per_host < topo.model_parallel:
        raise RuntimeError("not enough devices for the model-parallel plan")
    # keep data axis a power of two for collective efficiency
    usable = 1
    while usable * 2 <= survivors:
        usable *= 2
    return dataclasses.replace(topo, n_hosts=usable)


def mesh_shape(topo: Topology, multi_pod: bool = False) -> Tuple[int, ...]:
    data = topo.n_devices // topo.model_parallel
    if multi_pod:
        assert data % 2 == 0
        return (2, data // 2, topo.model_parallel)
    return (data, topo.model_parallel)


def reassign_data_hosts(old_hosts: List[int], dead: List[int],
                        new_count: int) -> List[int]:
    """Surviving hosts, renumbered into the contracted data layout."""
    alive = [h for h in old_hosts if h not in dead]
    return alive[:new_count]
