"""Deterministic fault injection for the per-example-norm pipeline.

A ``FaultPlan`` is a seeded, fully-declared schedule of failure events
— host death, straggler slowdown, checkpoint shard corruption or
truncation, crashed-mid-save ``.tmp`` litter, NaN-poisoned batches,
and host return — injected through the hooks the training stack
already has (heartbeat files, the checkpoint directory, the batch
pytree). Nothing here kills processes or reads wall clocks: the soak
harness (launch/soak.py) advances a simulated clock one tick per
attempted train step, so the same (plan, seed) replays the same storm
bit-for-bit, and a failure found at tick 17 reproduces at tick 17
forever.

Event timebase (DESIGN.md §11): host/checkpoint events fire at a
**tick** (wall-time order — a kill is a kill regardless of which data
step is being retrained), while ``nan_batch`` fires at a **train
step** (the poison lives in the data, so a rollback that replays the
step replays the poison).

Checkpoint faults operate on the *committed* step directories of a
``CheckpointManager`` layout:

* ``ckpt_corrupt``  — flip bytes inside the newest committed shard, so
  the manifest hash no longer matches (restore must fall back).
* ``ckpt_truncate`` — truncate the newest shard file (unreadable npz).
* ``tmp_litter``    — drop a half-written ``step_*.tmp`` dir, the
  debris of a writer that died mid-save.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

KINDS = ("host_death", "straggler", "ckpt_corrupt", "ckpt_truncate",
         "tmp_litter", "nan_batch", "host_return")

#: kinds scheduled on the simulated wall clock (ticks); ``nan_batch``
#: is scheduled on the data-step axis instead.
TICK_KINDS = frozenset(k for k in KINDS if k != "nan_batch")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    at: int                       # tick (TICK_KINDS) or train step (nan_batch)
    kind: str
    host: Optional[int] = None            # host_death / straggler
    hosts: Tuple[int, ...] = ()           # host_return
    examples: Tuple[int, ...] = ()        # nan_batch: global example rows
    factor: float = 1.0                   # straggler slowdown multiplier
    duration: int = 1                     # straggler: ticks it persists

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.at < 0:
            raise ValueError(f"event time must be >= 0, got {self.at}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, inspectable storm schedule."""
    events: Tuple[FaultEvent, ...]

    def at_tick(self, tick: int) -> List[FaultEvent]:
        return [e for e in self.events
                if e.kind in TICK_KINDS and e.at == tick]

    def nan_examples(self, train_step: int) -> Tuple[int, ...]:
        out: Tuple[int, ...] = ()
        for e in self.events:
            if e.kind == "nan_batch" and e.at == train_step:
                out = out + e.examples
        return out

    def poison_vector(self, train_step: int, batch_size: int) -> np.ndarray:
        """(B,) float32 multiplier: 1.0 everywhere, NaN on the poisoned
        rows of this train step. Multiplying a loss vector by exactly
        1.0 is a bit-exact no-op, so un-poisoned runs are unchanged."""
        v = np.ones(batch_size, np.float32)
        for i in self.nan_examples(train_step):
            if not 0 <= i < batch_size:
                raise ValueError(f"nan_batch example {i} outside the "
                                 f"global batch of {batch_size}")
            v[i] = np.nan
        return v

    def straggler_factor(self, tick: int, host: int) -> float:
        """Slowdown multiplier for ``host`` at ``tick`` (1.0 = healthy)."""
        f = 1.0
        for e in self.events:
            if (e.kind == "straggler" and e.host == host
                    and e.at <= tick < e.at + e.duration):
                f = max(f, e.factor)
        return f

    def validate(self, n_hosts: int, steps: int) -> "FaultPlan":
        """Static sanity: hosts in range, a host is not killed twice
        without returning, events inside the run."""
        dead: set = set()
        for e in sorted(self.events, key=lambda e: e.at):
            for h in ((e.host,) if e.host is not None else ()) + e.hosts:
                if not 0 <= h < n_hosts:
                    raise ValueError(f"{e.kind}@{e.at}: host {h} outside "
                                     f"world of {n_hosts}")
            if e.kind == "host_death":
                if e.host in dead:
                    raise ValueError(f"host {e.host} killed twice "
                                     f"(tick {e.at}) without returning")
                dead.add(e.host)
            if e.kind == "host_return":
                dead -= set(e.hosts)
        return self


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def scripted_storm(name: str, n_hosts: int, steps: int) -> FaultPlan:
    """The canonical soak storms, scaled to (n_hosts, steps).

    ``short`` is the acceptance storm: litter → transient straggler →
    kill (contract) → corrupt the newest checkpoint → second kill
    (restore must fall back a step) → NaN-poisoned batch (quarantine)
    → hosts return (expand back to full width). Needs n_hosts ≥ 4
    (power of two) and steps ≥ 20 so detection deadlines and
    checkpoint cadence fit between events.
    """
    if name == "none":
        return FaultPlan(())
    if name != "short":
        raise ValueError(f"unknown storm {name!r}; have none/short "
                         f"(or build a FaultPlan / use random_storm)")
    if n_hosts < 4 or n_hosts & (n_hosts - 1):
        raise ValueError(f"storm 'short' needs a power-of-two world of "
                         f">= 4 hosts, got {n_hosts}")
    if steps < 20:
        raise ValueError(f"storm 'short' needs >= 20 steps, got {steps}")

    t = lambda f: max(1, int(round(steps * f)))  # noqa: E731
    kill_a, kill_b = 2 % n_hosts, 0
    returners = tuple(sorted(set(range(n_hosts)) - {1, 3}))
    events = (
        FaultEvent(t(0.08), "tmp_litter"),
        FaultEvent(t(0.12), "straggler", host=1, factor=6.0, duration=2),
        FaultEvent(t(0.20), "host_death", host=kill_a),
        FaultEvent(t(0.40), "host_death", host=kill_b),
        # corrupt the newest committed checkpoint on the kill's
        # *detection* tick (kill at K ⇒ last beat K-1 ⇒ staleness hits
        # the 2.5-tick deadline at K+2; faults apply before the
        # supervisor runs): no save can land in between, so the
        # recovery restore must fall back to the previous committed
        # step
        FaultEvent(t(0.40) + 2, "ckpt_corrupt"),
        FaultEvent(t(0.60), "nan_batch",
                   examples=(3 % (2 * n_hosts), (2 * n_hosts) - 5)),
        FaultEvent(t(0.75), "host_return", hosts=returners),
    )
    return FaultPlan(events).validate(n_hosts, steps)


def random_storm(seed: int, n_hosts: int, steps: int, *,
                 p_kill: float = 0.05, p_ckpt: float = 0.05,
                 p_nan: float = 0.05, max_kills: Optional[int] = None)\
        -> FaultPlan:
    """Seeded random schedule: same (seed, n_hosts, steps, rates) →
    same storm. Kills are capped so the world stays contractible
    (model_parallel=1 worlds survive down to one host)."""
    rng = np.random.default_rng((seed, n_hosts, steps, 0xFA017))
    if max_kills is None:
        max_kills = max(1, n_hosts // 2)
    events: List[FaultEvent] = []
    alive = set(range(n_hosts))
    kills = 0
    for tick in range(2, steps):
        if kills < max_kills and rng.random() < p_kill and len(alive) > 1:
            h = int(rng.choice(sorted(alive)))
            alive.discard(h)
            kills += 1
            events.append(FaultEvent(tick, "host_death", host=h))
        if rng.random() < p_ckpt:
            kind = "ckpt_corrupt" if rng.random() < 0.5 else "tmp_litter"
            events.append(FaultEvent(tick, kind))
        if rng.random() < p_nan:
            b = 2 * n_hosts
            k = int(rng.integers(1, max(2, b // 4)))
            ex = tuple(int(i) for i in
                       rng.choice(b, size=k, replace=False))
            events.append(FaultEvent(tick, "nan_batch", examples=ex))
    if kills:
        back = tuple(sorted(set(range(n_hosts)) - alive))
        events.append(FaultEvent(int(steps * 0.8), "host_return",
                                 hosts=back))
    return FaultPlan(tuple(events)).validate(n_hosts, steps)


# ---------------------------------------------------------------------------
# injection hooks
# ---------------------------------------------------------------------------

def poison_loss_fn(loss_fn):
    """Wrap a v2 tap-collector loss so the (B,) ``batch["poison"]``
    multiplier scales the per-example losses. With the all-ones vector
    this is bit-exact identity (x · 1.0); a NaN entry poisons exactly
    that example's loss — and, through the backward, its per-example
    norm — which is how a bad input batch looks to the trainer."""
    def poisoned(params, batch, tap):
        inner = {k: v for k, v in batch.items() if k != "poison"}
        loss_vec, aux = loss_fn(params, inner, tap)
        return loss_vec * batch["poison"], aux
    return poisoned


def corrupt_newest_checkpoint(ckpt_dir: str, *, truncate: bool = False,
                              host_id: int = 0) -> Optional[int]:
    """Flip bytes in (or truncate) the newest committed shard so its
    manifest hash mismatches. Returns the step corrupted, or None when
    no committed checkpoint exists yet. Deterministic: always the same
    bytes at the same offset."""
    steps = sorted(
        int(name.split("_")[1]) for name in os.listdir(ckpt_dir)
        if name.startswith("step_") and not name.endswith(".tmp"))
    if not steps:
        return None
    step = steps[-1]
    shard = os.path.join(ckpt_dir, f"step_{step:09d}",
                         f"shard_{host_id:05d}.npz")
    if truncate:
        size = os.path.getsize(shard)
        with open(shard, "r+b") as f:
            f.truncate(max(0, size // 2))
    else:
        with open(shard, "r+b") as f:
            f.seek(min(64, max(0, os.path.getsize(shard) - 2)))
            f.write(b"\xde\xad")
    return step


def litter_tmp_dir(ckpt_dir: str, step: int) -> str:
    """Leave the debris of a save that died mid-write: a ``.tmp`` step
    dir holding a torn manifest. ``CheckpointManager`` must both
    ignore it on restore and sweep it on construction."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}.tmp")
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write('{"step": ')            # torn json
    return path
