"""repro.ft"""
