"""repro.ft — fault tolerance: detection (heartbeat), planning
(elastic), deterministic fault injection (inject), and supervised
recovery (supervisor). The closed loop is exercised end to end by the
soak harness, ``python -m repro.launch.soak`` (DESIGN.md §11)."""
from repro.ft.elastic import (Topology, plan_contraction, plan_expansion,
                              reassign_data_hosts)
from repro.ft.heartbeat import (HeartbeatConfig, HeartbeatMonitor,
                                detect_stragglers)
from repro.ft.inject import (FaultEvent, FaultPlan, corrupt_newest_checkpoint,
                             litter_tmp_dir, poison_loss_fn, random_storm,
                             scripted_storm)
from repro.ft.supervisor import (RecoveryActions, RecoveryEvent, Supervisor,
                                 SupervisorConfig, SupervisorHalted)

__all__ = [
    "Topology", "plan_contraction", "plan_expansion", "reassign_data_hosts",
    "HeartbeatConfig", "HeartbeatMonitor", "detect_stragglers",
    "FaultEvent", "FaultPlan", "corrupt_newest_checkpoint",
    "litter_tmp_dir", "poison_loss_fn", "random_storm", "scripted_storm",
    "RecoveryActions", "RecoveryEvent", "Supervisor", "SupervisorConfig",
    "SupervisorHalted",
]
