"""Supervised recovery: the state machine that closes the
fault-tolerance loop (DESIGN.md §11).

``ft/heartbeat.py`` detects, ``ft/elastic.py`` plans, ``ckpt/``
restores — but until this module nothing *drove* them through a
failure. The ``Supervisor`` consumes heartbeat surveys and per-host
step times every tick and, when something is wrong, executes one
recovery transition:

    RUNNING ──(dead host / evicted straggler)──▶ RECOVERING(contract)
    RUNNING ──(persistent straggler < grace)───▶ DEGRADED (observe)
    RUNNING ──(fresh spare hosts, bigger pow2)─▶ RECOVERING(expand)
    RECOVERING ──(restored + resharded + renumbered)──▶ RUNNING
    any ──(plan_contraction impossible / no restorable ckpt)─▶ HALTED

Contraction and expansion share one recovery path: plan the new
topology (``plan_contraction`` / ``plan_expansion``), pick the new
active host set (``reassign_data_hosts`` — survivors renumbered into
the contracted data layout, order-preserving), then hand the plan to a
``RecoveryActions`` implementation that restores the latest valid
checkpoint resharded onto the new topology, renumbers the
data-pipeline hosts, and resumes the Trainer. The split keeps the
machine unit-testable (feed it a fake actions object) and keeps
cluster mechanics (meshes, shard assignment, jit rebinding) out of the
policy — ``launch/soak.py`` provides the real actions for the
simulated world.

Straggler policy: a straggler is *observed* (DEGRADED) for
``straggler_grace`` consecutive detections before it is evicted
through the contraction path — transient slowness (GC pause, noisy
neighbor) must not trigger a reshard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.ft import elastic
from repro.ft.heartbeat import HeartbeatMonitor, detect_stragglers

RUNNING = "RUNNING"
DEGRADED = "DEGRADED"
RECOVERING = "RECOVERING"
HALTED = "HALTED"


class SupervisorHalted(RuntimeError):
    """The world is unrecoverable: contraction below the
    model-parallel floor, or no restorable checkpoint."""


@dataclasses.dataclass
class SupervisorConfig:
    #: consecutive straggler detections before eviction
    straggler_grace: int = 3
    straggler_mad_factor: float = 3.0
    #: allow growing back when spare/returned hosts heartbeat
    allow_expansion: bool = True


@dataclasses.dataclass
class RecoveryEvent:
    now: float
    kind: str           # dead / straggler / evict / contract / expand / halt
    detail: Dict


class RecoveryActions:
    """What a recovery transition must do to the world. Implementors:
    ``launch/soak.py`` (simulated cluster); a real launcher would
    restart processes here."""

    def restore_to(self, topology: elastic.Topology,
                   active_hosts: Sequence[int], reason: str) -> None:
        """Restore the latest valid checkpoint resharded to
        ``topology`` over ``active_hosts``, renumber the data pipeline,
        and resume the trainer. ``reason`` ∈ {contract, evict, expand}.
        Must raise on an unrecoverable world (propagates to HALTED)."""
        raise NotImplementedError


class Supervisor:
    def __init__(self, topo: elastic.Topology,
                 active_hosts: Sequence[int],
                 monitor: HeartbeatMonitor,
                 actions: RecoveryActions,
                 cfg: Optional[SupervisorConfig] = None):
        assert topo.n_hosts == len(active_hosts), \
            f"topology says {topo.n_hosts} hosts, active set has " \
            f"{len(active_hosts)}"
        self.topo = topo
        self.active: List[int] = sorted(active_hosts)
        self.monitor = monitor
        self.actions = actions
        self.cfg = cfg or SupervisorConfig()
        self.state = RUNNING
        self.events: List[RecoveryEvent] = []
        self._straggler_count: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def _log(self, now: float, kind: str, **detail) -> RecoveryEvent:
        ev = RecoveryEvent(now, kind, detail)
        self.events.append(ev)
        return ev

    def _recover(self, now: float, new_topo: elastic.Topology,
                 new_active: List[int], reason: str) -> None:
        self.state = RECOVERING
        try:
            self.actions.restore_to(new_topo, new_active, reason)
        except Exception as e:
            self.state = HALTED
            self._log(now, "halt", reason=f"{reason} failed: {e}")
            raise SupervisorHalted(str(e)) from e
        self.topo = new_topo
        self.active = sorted(new_active)
        self._straggler_count.clear()
        self.state = RUNNING
        self._log(now, reason, topology=dataclasses.asdict(new_topo),
                  active=list(self.active))

    def _contract(self, now: float, dead: List[int], reason: str) -> None:
        try:
            new_topo = elastic.plan_contraction(self.topo, dead)
        except RuntimeError as e:
            self.state = HALTED
            self._log(now, "halt", reason=str(e))
            raise SupervisorHalted(str(e)) from e
        new_active = elastic.reassign_data_hosts(self.active, dead,
                                                 new_topo.n_hosts)
        self._recover(now, new_topo, new_active, reason)

    # ------------------------------------------------------------------
    def tick(self, now: float,
             step_times: Optional[Dict[int, float]] = None)\
            -> List[RecoveryEvent]:
        """One supervision round. Returns the events this tick
        generated; mutates the world through ``actions`` when a
        recovery runs. Raises ``SupervisorHalted`` when unrecoverable."""
        if self.state == HALTED:
            raise SupervisorHalted("supervisor already halted")
        n_before = len(self.events)
        survey = self.monitor.survey(now)

        # -- death detection (scoped to the active set: dropped-idle
        # hosts go heartbeat-silent by design and are not failures) --
        dead = [h for h in self.active
                if h not in survey or not survey[h].get("alive")]
        if dead:
            for h in dead:
                self._log(now, "dead", host=h,
                          error=survey.get(h, {}).get("error"))
            self._contract(now, dead, "contract")
            return self.events[n_before:]

        # -- straggler grace/eviction --
        if step_times:
            active_times = {h: t for h, t in step_times.items()
                            if h in self.active}
            slow = detect_stragglers(active_times,
                                     self.cfg.straggler_mad_factor)
            for h in list(self._straggler_count):
                if h not in slow:
                    del self._straggler_count[h]
            evicted = None
            for h in slow:
                self._straggler_count[h] = self._straggler_count.get(h, 0) + 1
                self._log(now, "straggler", host=h,
                          consecutive=self._straggler_count[h],
                          step_time=active_times[h])
                if self._straggler_count[h] >= self.cfg.straggler_grace \
                        and evicted is None:
                    evicted = h
            if evicted is not None:
                self._log(now, "evict", host=evicted)
                self._contract(now, [evicted], "evict")
                return self.events[n_before:]
            self.state = DEGRADED if slow else RUNNING

        # -- expansion: fresh heartbeats from non-active hosts --
        if self.cfg.allow_expansion:
            fresh = [h for h, p in survey.items()
                     if p.get("alive") and h not in self.active]
            if fresh:
                pool = sorted(set(self.active) | set(fresh))
                new_topo = elastic.plan_expansion(self.topo, len(pool))
                if new_topo.n_hosts > len(self.active):
                    self._log(now, "returned", hosts=sorted(fresh))
                    self._recover(now, new_topo,
                                  pool[:new_topo.n_hosts], "expand")
        return self.events[n_before:]
