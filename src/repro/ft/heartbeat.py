"""Failure detection + straggler mitigation primitives.

On a real cluster each host runs a `HeartbeatMonitor`; the launcher
restarts from the last checkpoint with the surviving host set when a
deadline is missed (elastic contraction — see ft/elastic.py). Here the
logic is exercised by unit tests and the trainer's simulated-failure
hooks: the algorithms are the deliverable, the transport is a file
(NFS-style) or in-process dict.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class HeartbeatConfig:
    interval_s: float = 10.0
    deadline_s: float = 60.0


class HeartbeatMonitor:
    """File-based heartbeats: host i touches <dir>/host_i.json with its
    step + wall time; any reader can compute the dead set."""

    def __init__(self, directory: str, host_id: int, cfg: HeartbeatConfig):
        self.dir = directory
        self.host_id = host_id
        self.cfg = cfg
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int, now: Optional[float] = None) -> None:
        payload = {"step": step, "time": now or time.time()}
        path = os.path.join(self.dir, f"host_{self.host_id:05d}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)

    def survey(self, now: Optional[float] = None) -> Dict[int, Dict]:
        """One entry per heartbeat file. A torn, empty, or otherwise
        unreadable heartbeat is a *dead* host, not a crashed survey —
        the monitor is exactly the thing that must keep working while
        hosts are failing. The parse error is recorded in the payload
        (``payload["error"]``) so the supervisor can log why. In-flight
        ``.tmp`` files from ``beat``'s atomic write are skipped (the
        committed file is the heartbeat); files whose name carries no
        parseable host id are skipped (they are not heartbeats)."""
        now = now or time.time()
        out = {}
        for name in os.listdir(self.dir):
            if not name.startswith("host_") or name.endswith(".tmp"):
                continue
            try:
                hid = int(name.split("_")[1].split(".")[0])
            except (IndexError, ValueError):
                continue            # misnamed: not a heartbeat file
            try:
                with open(os.path.join(self.dir, name)) as f:
                    payload = json.load(f)
                stale = now - float(payload["time"])
                payload["alive"] = stale < self.cfg.deadline_s
            except (OSError, ValueError, KeyError, TypeError) as e:
                payload = {"alive": False,
                           "error": f"{type(e).__name__}: {e}"}
            out[hid] = payload
        return out

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        return sorted(h for h, p in self.survey(now).items()
                      if not p["alive"])


def detect_stragglers(step_times_s: Dict[int, float],
                      mad_factor: float = 3.0) -> List[int]:
    """Median-absolute-deviation outlier detection over per-host step
    times. Returns host ids slower than median + mad_factor·MAD."""
    if len(step_times_s) < 3:
        return []
    times = sorted(step_times_s.values())
    n = len(times)
    med = times[n // 2] if n % 2 else 0.5 * (times[n // 2 - 1] + times[n // 2])
    devs = sorted(abs(t - med) for t in times)
    mad = devs[n // 2] if n % 2 else 0.5 * (devs[n // 2 - 1] + devs[n // 2])
    thresh = med + mad_factor * max(mad, 1e-9)
    return sorted(h for h, t in step_times_s.items() if t > thresh)
