"""repro.serve"""
