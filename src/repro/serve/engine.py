"""Batched serving engine: continuous-batching-lite request loop.

Requests (prompt token lists) are padded into a fixed batch; prefill
fills the KV caches, then greedy/temperature decode runs step-locked
for the whole batch with per-slot stop tracking. Finished slots are
refilled from the queue (slot recycling = the continuous-batching core
idea, at step granularity)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.nn.param import unbox


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    out: Optional[List[int]] = None


class Engine:
    def __init__(self, arch_id: str, cfg, params, *, batch_slots: int = 4,
                 temperature: float = 0.0, seed: int = 0):
        self.aspec = registry.get(arch_id)
        self.cfg = cfg
        self.mod = registry.family_module(self.aspec)
        self.params = params
        self.fwd = jax.jit(registry.make_forward_tokens(self.aspec, cfg))
        self.slots = batch_slots
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)

    def _sample(self, logits) -> jax.Array:
        logits = logits[:, -1, : self.cfg.vocab]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self.rng, sub = jax.random.split(self.rng)
        return jax.random.categorical(sub, logits / self.temperature, axis=-1)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Process all requests; returns them with .out filled."""
        queue = list(requests)
        B = self.slots
        max_len = self.cfg.max_cache_len
        while queue:
            active = queue[:B]
            queue = queue[B:]
            n = len(active)
            plen = max(len(r.prompt) for r in active)
            ids = np.zeros((B, plen), np.int32)
            for i, r in enumerate(active):
                ids[i, plen - len(r.prompt):] = r.prompt  # left-pad
            caches = self.mod.init_caches(B, self.cfg)
            logits, caches = self.fwd(self.params,
                                      {"ids": jnp.asarray(ids)}, caches, 0)
            tok = self._sample(logits)
            outs = [[int(tok[i])] for i in range(n)]
            steps = max(r.max_new for r in active)
            for t in range(1, min(steps, max_len - plen)):
                logits, caches = self.fwd(self.params,
                                          {"ids": tok[:, None]},
                                          caches, plen + t - 1)
                tok = self._sample(logits)
                for i in range(n):
                    if len(outs[i]) < active[i].max_new:
                        outs[i].append(int(tok[i]))
            for i, r in enumerate(active):
                r.out = outs[i][: r.max_new]
        return requests
