"""Per-tenant adapter store: thousands of LoRA states stacked on a
tenant axis (DESIGN.md §14).

Every leaf of the single-tenant adapter tree (typically a dict of
``nn.lora.LoraPair``s) is stored stacked as ``(capacity, ...)``; a
host-side slot table maps tenant id → row. Admission writes a
deterministic fresh state (``init_fn(fold_in(key, tenant_id))`` — so a
re-admitted tenant that was never trained restarts bit-identically),
eviction frees the row, and ``gather``/``scatter`` move the per-batch
active set in/out as one ``take``/one scatter.

Checkpointing writes the COMPACTED active set (rows sorted by tenant
id) plus the tenant list as manifest ``extra`` — capacity is a runtime
sizing choice, not state. ``restore`` repacks the survivors into rows
``[0..n)`` in tenant-id order: renumbering is part of the contract,
and it is bit-exact (per-tenant state round-trips byte-identically
regardless of which slot it lands in; the ckpt test pins this with
the soak harness's sha256 tree digest).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class AdapterStore:
    """Slot-allocated stacked store of per-tenant adapter trees.

    init_fn:  ``key -> adapter pytree`` (single-tenant shapes). Called
              once at construction for shapes, and per admission with
              the tenant's folded key.
    capacity: max resident tenants (slot count).
    key:      master PRNG key; tenant t's init key is
              ``fold_in(key, t)``.
    """

    def __init__(self, init_fn: Callable, capacity: int, key):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.init_fn = init_fn
        self.capacity = int(capacity)
        self.key = key
        template = init_fn(key)
        self.stacked = jax.tree_util.tree_map(
            lambda l: jnp.zeros((self.capacity,) + tuple(l.shape), l.dtype),
            template)
        self.slots = np.full((self.capacity,), -1, dtype=np.int64)
        self._slot_of: dict = {}

    # -- bookkeeping -----------------------------------------------------
    @property
    def n_active(self) -> int:
        return len(self._slot_of)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_active

    @property
    def tenants(self) -> np.ndarray:
        """Sorted ids of resident tenants."""
        return np.array(sorted(self._slot_of), dtype=np.int64)

    def has(self, tenant_id: int) -> bool:
        return int(tenant_id) in self._slot_of

    def slot_of(self, tenant_id: int) -> int:
        return self._slot_of[int(tenant_id)]

    # -- admission / eviction (the serve engine's slot recycling) --------
    def admit(self, tenant_id: int) -> int:
        """Ensure ``tenant_id`` is resident; returns its slot. A fresh
        admission initializes the row from the tenant's folded key."""
        tid = int(tenant_id)
        if tid < 0:
            raise ValueError(f"tenant ids must be non-negative, got {tid}")
        if tid in self._slot_of:
            return self._slot_of[tid]
        free = np.flatnonzero(self.slots < 0)
        if free.size == 0:
            raise RuntimeError(
                f"adapter store is full ({self.capacity} slots); evict a "
                f"tenant before admitting {tid}")
        slot = int(free[0])
        state = self.init_fn(jax.random.fold_in(self.key, tid))
        self.stacked = jax.tree_util.tree_map(
            lambda s, l: s.at[slot].set(l.astype(s.dtype)),
            self.stacked, state)
        self.slots[slot] = tid
        self._slot_of[tid] = slot
        return slot

    def evict(self, tenant_id: int) -> None:
        """Free the tenant's slot (its state is dropped — checkpoint
        first if it must survive)."""
        tid = int(tenant_id)
        slot = self._slot_of.pop(tid, None)
        if slot is None:
            return
        self.slots[slot] = -1
        # zero the row so freed state never leaks into a later gather
        self.stacked = jax.tree_util.tree_map(
            lambda s: s.at[slot].set(jnp.zeros_like(s[slot])), self.stacked)

    # -- batch movement ---------------------------------------------------
    def _rows(self, tenant_ids) -> jax.Array:
        rows = []
        for t in np.asarray(tenant_ids).reshape(-1):
            slot = self._slot_of.get(int(t))
            if slot is None:
                raise KeyError(f"tenant {int(t)} is not resident; admit() "
                               f"it first")
            rows.append(slot)
        return jnp.asarray(np.array(rows, dtype=np.int32))

    def gather(self, tenant_ids):
        """Adapter tree with rows for ``tenant_ids`` stacked leading —
        the per-batch active set the Engine trains."""
        rows = self._rows(tenant_ids)
        return jax.tree_util.tree_map(
            lambda s: jnp.take(s, rows, axis=0), self.stacked)

    def scatter(self, tenant_ids, tree) -> None:
        """Write updated rows back (inverse of ``gather``)."""
        rows = self._rows(tenant_ids)
        self.stacked = jax.tree_util.tree_map(
            lambda s, v: s.at[rows].set(v.astype(s.dtype)),
            self.stacked, tree)

    # -- checkpointing ----------------------------------------------------
    def save(self, manager, step: int, *, block: bool = True) -> None:
        """Write the compacted active set (rows in tenant-id order) via
        ``ckpt.CheckpointManager``; the tenant list rides in the
        manifest ``extra``."""
        tenants = self.tenants
        compact = self.gather(tenants)
        manager.save(step, compact, extra={
            "tenancy": {"tenants": [int(t) for t in tenants]}},
            block=block)

    def restore(self, manager, step: Optional[int] = None) -> Sequence[int]:
        """Load a checkpoint and repack the survivors into slots
        ``[0..n)`` in tenant-id order (renumbering — bit-exact per
        tenant). Returns the restored tenant ids."""
        if step is None:
            step = manager.latest_step()
        compact, extra = manager.restore(step, self.stacked)
        tenants = [int(t) for t in extra["tenancy"]["tenants"]]
        n = len(tenants)
        if n > self.capacity:
            raise ValueError(
                f"checkpoint holds {n} tenants but the store has only "
                f"{self.capacity} slots; restore into a larger store")
        self.slots = np.full((self.capacity,), -1, dtype=np.int64)
        self._slot_of = {}
        # compact leaves arrive shaped (n, ...) from the manifest
        self.stacked = jax.tree_util.tree_map(
            lambda s, c: jnp.zeros_like(s).at[:n].set(c.astype(s.dtype)),
            self.stacked, compact)
        for i, t in enumerate(tenants):
            self.slots[i] = t
            self._slot_of[t] = i
        return tenants
