"""Multi-tenant LoRA fine-tuning service (DESIGN.md §14).

One ``TenantService.step`` takes an interleaved mixed-tenant batch and
runs EVERYTHING in one fused pass, regardless of how many tenants
share the batch:

  1. ``tenancy.batch.assemble`` sorts examples by tenant (tenant =
     segment; the segmented estimator sees sorted runs);
  2. the per-batch active adapter set is gathered from the
     ``AdapterStore`` — the Engine's *params* are exactly those rows,
     so AD's scatter-add over the per-example gather IS the per-tenant
     gradient reduction;
  3. the loss closure expands rows to per-example adapter slices
     (``take`` by the batch's ``tenant_index``) and calls the user
     loss; the LoRA factors go through ``tap.dense_batched``, so one
     segmented launch computes per-example norms across all tenants;
  4. ``Clip(C)`` clips per example inside the fused pass;
     ``Noise(σ, rng, segments=tenant_ids)`` draws each tenant's noise
     from ``fold_in(rng, tenant_id)`` — each resident tenant's DP
     accounting is independent of who else shares the batch;
  5. SGD on the active rows, scattered back into the store.

Between steps the service admits queued tenants into free slots and
evicts on request — the serve engine's slot-recycling idiom
(``serve/engine.py``) applied to adapter residency; checkpointing
delegates to ``AdapterStore.save``/``restore`` (compacted, renumbered,
bit-exact per tenant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.tenancy import batch as tbatch
from repro.tenancy.adapters import AdapterStore


@dataclasses.dataclass(frozen=True)
class TenantStepResult:
    """One service step's outputs, per-tenant views included."""
    loss: jax.Array                    # scalar Σ over the batch
    loss_vec: jax.Array                # (B,) sorted-example losses
    sq_norms: Any                      # (B, G) or (B, S) accumulator
    clip_coef: Optional[jax.Array]     # per-example (or -token) c_j
    tenant_ids: np.ndarray             # (T,) tenants in this batch
    tenant_loss: jax.Array             # (T,) Σ loss per tenant
    tenant_count: jax.Array            # (T,) examples per tenant
    tenant_clip_mean: Optional[jax.Array]  # (T,) mean clip coefficient
    tenant_clip_min: Optional[jax.Array]   # (T,) min clip coefficient
    aux: Any = None


class TenantService:
    """Elastic multi-tenant fine-tuning over one ``AdapterStore``.

    loss_fn:   ``(per_example_adapters, data_batch, tap) -> (loss_vec,
               aux)`` — per_example_adapters is the adapter tree with a
               leading (B,) axis (example j's row is its tenant's
               state); route its factors through ``tap.dense_batched``
               (``nn.linear.linear`` does, for trees of LoRA sites).
    store:     the ``AdapterStore`` holding resident tenants.
    clip_norm: per-example clip threshold C.
    noise_std: DP noise multiplier σ (0 disables noise).
    noise_scale: explicit sensitivity (required at token granularity,
               where C does not bound an example's total contribution).
    lr:        SGD learning rate applied to the active rows.
    """

    def __init__(self, store: AdapterStore, loss_fn: Callable, *,
                 clip_norm: float, noise_std: float = 0.0,
                 noise_scale: Optional[float] = None, lr: float = 0.1,
                 spec: Optional[PexSpec] = None, mesh=None,
                 data_axes: Sequence[str] = ("data",),
                 granularity: str = "example",
                 ckpt_manager=None):
        self.store = store
        self.loss_fn = loss_fn
        self.clip_norm = float(clip_norm)
        self.noise_std = float(noise_std)
        self.noise_scale = noise_scale
        self.lr = float(lr)
        self.granularity = granularity
        self.engine = Engine(spec, mesh=mesh, data_axes=data_axes,
                             granularity=granularity)
        self.ckpt_manager = ckpt_manager
        self.pending: list = []
        self.steps_run = 0

    # -- admission (serve/engine.py slot recycling) -----------------------
    def submit(self, *tenant_ids) -> None:
        """Queue tenants for admission at the next step boundary."""
        for t in tenant_ids:
            if not self.store.has(t) and int(t) not in self.pending:
                self.pending.append(int(t))

    def admit_pending(self) -> Sequence[int]:
        """Admit queued tenants into the free slots (head of the queue
        first; the rest wait — the serve engine's recycling loop)."""
        active = self.pending[:self.store.n_free]
        self.pending = self.pending[len(active):]
        for t in active:
            self.store.admit(t)
        return active

    def evict(self, tenant_id: int) -> None:
        self.store.evict(tenant_id)

    # -- the fused step ----------------------------------------------------
    def consumers(self, tb: tbatch.TenantBatch, rng=None) -> list:
        """The plan for one mixed-tenant step: per-example Clip, and —
        when σ > 0 — per-tenant segmented Noise."""
        cons = [plan_mod.Clip(self.clip_norm, granularity=self.granularity)]
        if self.noise_std > 0.0:
            scale = self.noise_scale
            if scale is None:
                if self.granularity == "token":
                    raise ValueError(
                        "token-granularity DP needs an explicit "
                        "noise_scale: per-token clipping bounds each "
                        "token term by C, not the example total")
                scale = self.clip_norm
            cons.append(plan_mod.Noise(self.noise_std, rng, scale=scale,
                                       segments=tb.segments()))
        return cons

    def _closure(self):
        loss_fn = self.loss_fn

        def loss(adapters, eb, tap):
            idx = eb["tenant_index"]
            per_ex = jax.tree_util.tree_map(
                lambda v: jnp.take(v, idx, axis=0), adapters)
            data = {k: v for k, v in eb.items() if k != "tenant_index"}
            if tuple(data) == ("data",):
                data = data["data"]
            return loss_fn(per_ex, data, tap)

        return loss

    def step(self, batch, tenant_ids, *, rng=None,
             apply_updates: bool = True,
             seq: Optional[int] = None) -> TenantStepResult:
        """One fused mixed-tenant DP step. ``batch`` is any (B, ...)
        pytree; ``tenant_ids`` (B,) owners per example. Unknown tenants
        are admitted on demand (after the pending queue drains)."""
        if self.noise_std > 0.0 and rng is None:
            raise ValueError("noise_std > 0 needs rng= (the master step "
                             "key; tenant keys are folded from it)")
        self.admit_pending()
        tb = tbatch.assemble(batch, tenant_ids)
        for t in tb.unique_tenants:
            self.store.admit(int(t))
        active = self.store.gather(tb.unique_tenants)

        res = self.engine.step(self._closure(), active, tb.batch,
                               self.consumers(tb, rng), seq=seq)
        if apply_updates:
            updated = jax.tree_util.tree_map(
                lambda a, g: a - self.lr * g.astype(a.dtype),
                active, res.grads)
            self.store.scatter(tb.unique_tenants, updated)
        self.steps_run += 1

        t_idx, n_t = tb.tenant_index, tb.n_tenants
        cc = res.clip_coef
        per_ex_cc = cc if cc is not None and cc.ndim == 1 else None
        return TenantStepResult(
            loss=res.loss, loss_vec=res.loss_vec, sq_norms=res.sq_norms,
            clip_coef=cc, tenant_ids=tb.unique_tenants,
            tenant_loss=tbatch.per_tenant_sum(
                res.loss_vec.astype(jnp.float32), t_idx, n_t),
            tenant_count=tbatch.per_tenant_count(t_idx, n_t),
            tenant_clip_mean=None if per_ex_cc is None
            else tbatch.per_tenant_mean(per_ex_cc, t_idx, n_t),
            tenant_clip_min=None if per_ex_cc is None
            else tbatch.per_tenant_min(per_ex_cc, t_idx, n_t),
            aux=res.aux)

    # -- checkpointing -----------------------------------------------------
    def save(self, step: Optional[int] = None, *, block: bool = True):
        if self.ckpt_manager is None:
            raise ValueError("construct the service with ckpt_manager= "
                             "to checkpoint")
        self.store.save(self.ckpt_manager,
                        self.steps_run if step is None else step,
                        block=block)

    def restore(self, step: Optional[int] = None) -> Sequence[int]:
        if self.ckpt_manager is None:
            raise ValueError("construct the service with ckpt_manager= "
                             "to restore")
        return self.store.restore(self.ckpt_manager, step)
