"""Multi-tenant LoRA fine-tuning (DESIGN.md §14).

Thousands of users each own a low-rank adapter; one fused pass per
step computes per-example gradient norms, per-example clipping, and
per-tenant DP noise across every tenant in the batch — tenants are
segments, exactly like MoE experts (PR 4's sort-and-run machinery).
"""
from repro.tenancy.adapters import AdapterStore
from repro.tenancy.batch import (TenantBatch, assemble, per_tenant_count,
                                 per_tenant_max, per_tenant_mean,
                                 per_tenant_min, per_tenant_sum)
from repro.tenancy.service import TenantService, TenantStepResult

__all__ = [
    "AdapterStore", "TenantBatch", "assemble", "TenantService",
    "TenantStepResult", "per_tenant_count", "per_tenant_max",
    "per_tenant_mean", "per_tenant_min", "per_tenant_sum",
]
