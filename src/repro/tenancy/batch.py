"""Mixed-tenant batch assembly — tenant = segment (DESIGN.md §14).

A serving step receives examples from many tenants interleaved.
``assemble`` sorts them by tenant id (host-side, stable) so that:

  * each tenant's examples are one contiguous run — the shape the
    sort-based segmented kernel (``kernels/segmented_norm.py``) was
    built for: the ``dense_batched`` tap flattens tokens and runs the
    segmented-direct estimator with example ids as segments, and
    tenant-sorted examples mean tenant-sorted (already-run-encoded)
    segment tables, so ONE launch covers every tenant in the batch;
  * the per-example → per-tenant maps (``tenant_index``,
    ``unique_tenants``, ``counts``) are plain run-length tables, and
    per-tenant reductions over per-example stats (clip-coefficient
    statistics, per-tenant losses) are ``segment_sum`` over sorted
    ids — the cheapest possible form.

``tenant_index`` rides INSIDE the assembled batch pytree (key
``"tenant_index"``) so it shards with the examples under
``shard_map`` — the loss closure gathers each example's adapter row
with its shard-local index, never a global one.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantBatch:
    """One assembled mixed-tenant batch.

    batch:          tenant-sorted batch pytree with a ``tenant_index``
                    (B,) int32 leaf added (position j's index into
                    ``unique_tenants``) — feed this to the Engine.
    tenant_ids:     (B,) sorted tenant id per example (numpy, host).
    unique_tenants: (T,) sorted distinct tenant ids in the batch.
    counts:         (T,) examples per tenant.
    perm:           (B,) sorted position i holds original example
                    ``perm[i]`` (use to sort any extra per-example
                    array the same way).
    inv_perm:       (B,) original example j sits at sorted position
                    ``inv_perm[j]`` (use to un-sort results).
    """
    batch: Any
    tenant_ids: np.ndarray
    unique_tenants: np.ndarray
    counts: np.ndarray
    perm: np.ndarray
    inv_perm: np.ndarray

    @property
    def n_tenants(self) -> int:
        return int(self.unique_tenants.shape[0])

    @property
    def batch_size(self) -> int:
        return int(self.tenant_ids.shape[0])

    @property
    def tenant_index(self) -> jax.Array:
        return self.batch["tenant_index"]

    def segments(self) -> jax.Array:
        """(T,) tenant ids as a device array — the ``Noise.segments``
        argument (per-tenant noise keyed by ``fold_in(rng, id)``)."""
        return jnp.asarray(self.unique_tenants, dtype=jnp.int32)


def assemble(batch, tenant_ids) -> TenantBatch:
    """Sort a mixed-tenant batch by tenant id (stable, host-side).

    ``batch`` is any pytree of (B, ...) arrays; ``tenant_ids`` is a
    (B,) int array-like of the owning tenant per example. Stability
    preserves each tenant's internal example order, so re-assembling
    the same batch is deterministic.
    """
    tids = np.asarray(tenant_ids)
    if tids.ndim != 1:
        raise ValueError(f"tenant_ids must be (B,), got {tids.shape}")
    if tids.size and tids.min() < 0:
        raise ValueError("tenant ids must be non-negative (negative ids "
                         "are reserved for free adapter slots)")
    perm = np.argsort(tids, kind="stable")
    sorted_ids = tids[perm]
    unique, inverse, counts = np.unique(sorted_ids, return_inverse=True,
                                        return_counts=True)
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(perm.size)
    perm_j = jnp.asarray(perm)
    sorted_batch = jax.tree_util.tree_map(
        lambda v: jnp.take(v, perm_j, axis=0), batch)
    if not isinstance(sorted_batch, dict):
        sorted_batch = {"data": sorted_batch}
    else:
        sorted_batch = dict(sorted_batch)
    if "tenant_index" in sorted_batch:
        raise ValueError("batch already carries a 'tenant_index' leaf; "
                         "assemble() owns that key")
    sorted_batch["tenant_index"] = jnp.asarray(inverse, dtype=jnp.int32)
    return TenantBatch(sorted_batch, sorted_ids, unique, counts, perm,
                       inv_perm)


# ---------------------------------------------------------------------------
# per-tenant reductions over per-example stats (sorted segments)
# ---------------------------------------------------------------------------

def per_tenant_sum(values, tenant_index, n_tenants: int) -> jax.Array:
    """Σ over each tenant's examples. ``values`` (B, ...); sorted
    ``tenant_index`` makes this the fast segment-sum path."""
    return jax.ops.segment_sum(values, tenant_index, n_tenants,
                               indices_are_sorted=True)


def per_tenant_count(tenant_index, n_tenants: int) -> jax.Array:
    return per_tenant_sum(jnp.ones_like(tenant_index, dtype=jnp.int32),
                          tenant_index, n_tenants)


def per_tenant_mean(values, tenant_index, n_tenants: int) -> jax.Array:
    s = per_tenant_sum(values.astype(jnp.float32), tenant_index, n_tenants)
    c = per_tenant_count(tenant_index, n_tenants).astype(jnp.float32)
    shape = (-1,) + (1,) * (s.ndim - 1)
    return s / jnp.maximum(c.reshape(shape), 1.0)


def per_tenant_min(values, tenant_index, n_tenants: int) -> jax.Array:
    return jax.ops.segment_min(values, tenant_index, n_tenants,
                               indices_are_sorted=True)


def per_tenant_max(values, tenant_index, n_tenants: int) -> jax.Array:
    return jax.ops.segment_max(values, tenant_index, n_tenants,
                               indices_are_sorted=True)
