"""Hardware profiles — the roofline and cost-model denominators.

Historically this module was five flat TPU v5e numbers; every roofline
ratio and every ``analysis.cost`` CostReport divides by them, so a
report is meaningless unless it *names* the hardware it assumed.
``HardwareProfile`` makes the denominators a value, ``PROFILES`` the
named registry, and the original module-level v5e constants remain as
the default profile's fields (``roofline.analysis`` and older callers
read them directly).

Numbers are public peak specs (per chip), not measured: the cost model
composes them with measured probe metrics where available.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Per-chip peak numbers a roofline/cost estimate divides by."""
    name: str
    peak_flops_bf16: float     # FLOP/s, dense bf16
    hbm_bw: float              # B/s
    ici_bw: float              # B/s per link
    chips_per_pod: int
    hbm_bytes: float           # capacity, for fit checks

    def describe(self) -> str:
        return (f"{self.name}: {self.peak_flops_bf16 / 1e12:.0f} TF/s, "
                f"{self.hbm_bw / 1e9:.0f} GB/s HBM, "
                f"{self.ici_bw / 1e9:.0f} GB/s ICI, "
                f"{self.hbm_bytes / 1e9:.0f} GB")


PROFILES: Dict[str, HardwareProfile] = {
    "tpu-v5e": HardwareProfile(
        name="tpu-v5e", peak_flops_bf16=197e12, hbm_bw=819e9,
        ici_bw=50e9, chips_per_pod=256, hbm_bytes=16e9),
    "tpu-v5p": HardwareProfile(
        name="tpu-v5p", peak_flops_bf16=459e12, hbm_bw=2765e9,
        ici_bw=100e9, chips_per_pod=8960, hbm_bytes=95e9),
    "tpu-v4": HardwareProfile(
        name="tpu-v4", peak_flops_bf16=275e12, hbm_bw=1228e9,
        ici_bw=50e9, chips_per_pod=3072, hbm_bytes=32e9),
    "tpu-v6e": HardwareProfile(
        name="tpu-v6e", peak_flops_bf16=918e12, hbm_bw=1640e9,
        ici_bw=90e9, chips_per_pod=256, hbm_bytes=32e9),
}

DEFAULT_PROFILE = "tpu-v5e"


def get_profile(name: str) -> HardwareProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hardware profile {name!r}; known: "
                       f"{sorted(PROFILES)}") from None


# -- legacy flat constants (the default tpu-v5e profile) --------------------
_DEF = PROFILES[DEFAULT_PROFILE]
PEAK_FLOPS_BF16 = _DEF.peak_flops_bf16
HBM_BW = _DEF.hbm_bw
ICI_BW = _DEF.ici_bw
CHIPS_PER_POD = _DEF.chips_per_pod
HBM_BYTES = _DEF.hbm_bytes
