"""TPU v5e hardware constants (per chip) — the roofline denominators."""

PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link
CHIPS_PER_POD = 256
HBM_BYTES = 16e9               # capacity, for fit checks
