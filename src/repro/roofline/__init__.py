"""repro.roofline"""
