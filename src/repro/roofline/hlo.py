"""Collective-byte accounting from compiled HLO text.

``cost_analysis`` has no collective term, so we parse the module: every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes its *operand* bytes (falling back to
result bytes when the operand definition isn't resolvable, e.g. fusion
parameters). Ops inside ``while`` bodies appear once — the caller
multiplies by trip count via the probe-extrapolation scheme
(roofline/analysis.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(%?[\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind over the whole module."""
    # pass 1: bytes of every named value
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, type_str, _ = m.groups()
            sizes[name.lstrip("%")] = _type_bytes(type_str)

    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        # operand list: first (...) after the op name
        rest = line[m.end():]
        paren = rest.find("(")
        operand_bytes = 0
        if paren >= 0:
            depth, j = 0, paren
            for j in range(paren, len(rest)):
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operands = rest[paren + 1:j]
            for tok in re.findall(r"%?([\w.\-]+)", operands):
                if tok in sizes:
                    operand_bytes += sizes[tok]
        if operand_bytes == 0:
            operand_bytes = _type_bytes(type_str)   # fallback: result bytes
        out[kind] += operand_bytes
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return dict(out)


def collective_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                if not op.endswith("-done"):     # count start+done pairs once
                    out[c] += 1
    return dict(out)


def compiled_cost(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) from a compiled executable's
    cost_analysis, tolerating the jax-0.4.x list-of-one-dict form and
    absent analyses (returns zeros)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))
