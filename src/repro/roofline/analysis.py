"""Roofline terms per (arch × shape × mesh) — deliverable (g).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` counts a ``while`` body once (measured in-container),
so the full-model program is compiled with layers under ``lax.scan``
(the required compile proof + memory analysis) while FLOPs/bytes/
collective-bytes come from small *unrolled* probe programs — 1–3 layer
variants at full width/shape/mesh — linearly extrapolated by the
arch-specific ``combine`` (exact for homogeneous stacks; zamba2/
seamless use 3-probe solves for their two block types).

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·tokens (serve); the
ratio MODEL_FLOPS/HLO_FLOPs exposes remat/stat/dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional

import jax

from repro.configs.common import SHAPES, ShapeSpec
from repro.roofline import constants as C


def probe_metrics(res) -> Dict[str, float]:
    """The linearly-extrapolatable metrics of one compiled probe."""
    return {
        "flops": res.flops,
        "bytes": res.bytes_accessed,
        "coll_bytes": res.coll_bytes.get("total", 0.0),
        "coll_ar": res.coll_bytes.get("all-reduce", 0.0),
        "coll_ag": res.coll_bytes.get("all-gather", 0.0),
        "coll_rs": res.coll_bytes.get("reduce-scatter", 0.0),
        "coll_a2a": res.coll_bytes.get("all-to-all", 0.0),
        "coll_cp": res.coll_bytes.get("collective-permute", 0.0),
    }


def active_params(param_sds_tree) -> float:
    """Parameters touched per token: routed-expert leaves count at
    top_k/n_experts (identified by path '.moe' + 3 expert dims)."""
    # handled by caller with arch context; see n_active_for
    raise NotImplementedError


def n_active_for(arch_id: str, n_total: float, cfg) -> float:
    # embedding tables are gathers, not matmuls — exclude from the
    # 6ND/2ND model-flops count (MFU convention); the lm_head matmul stays
    from repro.dist.sharding import pad_to
    vocab_p = pad_to(cfg.vocab, 16)
    n = n_total - vocab_p * cfg.d_model
    moe = getattr(cfg, "moe", None)
    if moe is None:
        return n
    # routed expert params per layer
    n_routed_layers = cfg.n_layers - getattr(cfg, "n_dense_prefix", 0)
    routed = n_routed_layers * moe.n_experts * 3 * cfg.d_model * moe.d_ff
    active_fraction = moe.top_k / moe.n_experts
    return n - routed * (1.0 - active_fraction)


def model_flops(shape: ShapeSpec, n_active: float) -> float:
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq * shape.batch
    tokens = shape.batch * (shape.seq if shape.kind == "prefill" else 1)
    return 2.0 * n_active * tokens


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    bytes: float
    coll_bytes: float
    model_flops: float
    useful_ratio: float       # MODEL_FLOPS / HLO_FLOPs
    peak_gb_per_dev: float
    bottleneck: str = ""
    roofline_fraction: float = 0.0   # max-term share of total (≤1; higher = closer to a single clean roof)

    def finish(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        tot = sum(terms.values())
        self.roofline_fraction = terms[self.bottleneck] / tot if tot else 0.0
        return self


def build_roofline(arch: str, shape_name: str, mesh_name: str,
                   metrics: Dict[str, float], model_fl: float,
                   peak_bytes: float, chips: int = 256) -> Roofline:
    fl = metrics["flops"]
    by = metrics["bytes"]
    cb = metrics["coll_bytes"]
    r = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        t_compute=fl / (chips * C.PEAK_FLOPS_BF16),
        t_memory=by / (chips * C.HBM_BW),
        t_collective=cb / (chips * C.ICI_BW),
        flops=fl, bytes=by, coll_bytes=cb, model_flops=model_fl,
        useful_ratio=model_fl / fl if fl else 0.0,
        peak_gb_per_dev=peak_bytes / 1e9)
    return r.finish()


def mfu(r: Roofline) -> float:
    """Model-FLOPs utilization implied by the roofline terms: useful
    flops / (chips × peak × max-term-time)."""
    t = max(r.t_compute, r.t_memory, r.t_collective)
    if t <= 0:
        return 0.0
    return r.model_flops / (256 * C.PEAK_FLOPS_BF16 * t)
