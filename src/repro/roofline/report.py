"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/dryrun and experiments/roofline.

    PYTHONPATH=src python -m repro.roofline.report > experiments/report.md
"""
from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

from repro.configs.common import SHAPES

ARCH_ORDER = ["qwen2-vl-7b", "zamba2-7b", "llama3.2-1b", "qwen2-7b",
              "minitron-4b", "gemma2-9b", "rwkv6-3b", "seamless-m4t-medium",
              "deepseek-v2-236b", "phi3.5-moe"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt(x, unit=""):
    if x == 0:
        return "0"
    for div, suf in [(1e15, "P"), (1e12, "T"), (1e9, "G"), (1e6, "M"),
                     (1e3, "k")]:
        if abs(x) >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


def _ms(x):
    return f"{x * 1e3:.2f}"


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        j = json.load(open(f))
        out[os.path.basename(f)[:-5]] = j
    return out


def dryrun_table(dr):
    lines = ["| arch | shape | mesh | status | compile s | params/dev | "
             "state/dev | temp/dev | peak/dev | HLO flops | coll bytes | "
             "AR/AG/RS/A2A/CP counts |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shp in SHAPE_ORDER:
            for mesh in ["16x16", "2x16x16"]:
                k = f"{arch}__{shp}__{mesh}"
                if k not in dr:
                    continue
                d = dr[k]
                if d.get("skipped"):
                    lines.append(f"| {arch} | {shp} | {mesh} | SKIP¹ | – | – "
                                 f"| – | – | – | – | – | – |")
                    continue
                cc = d.get("coll_counts", {})
                counts = "/".join(str(cc.get(c, 0)) for c in
                                  ["all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"])
                lines.append(
                    f"| {arch} | {shp} | {mesh} | "
                    f"{'OK' if d['ok'] else 'FAIL'} | {d['compile_s']:.1f} | "
                    f"{d['param_bytes_per_dev'] / 1e9:.2f}G | "
                    f"{d['state_bytes_per_dev'] / 1e9:.2f}G | "
                    f"{d['temp_bytes_per_dev'] / 1e9:.2f}G | "
                    f"**{d['peak_bytes_per_dev'] / 1e9:.2f}G** | "
                    f"{_fmt(d['flops'])} | "
                    f"{_fmt(d['coll_bytes'].get('total', 0), 'B')} | "
                    f"{counts} |")
    return "\n".join(lines)


def roofline_table(rf, tag=""):
    lines = ["| arch | shape | compute ms | memory ms | coll ms | "
             "bottleneck | roof-frac | MODEL_FLOPS | HLO_FLOPs | useful | "
             "MFU-bound | peak GB | next lever |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shp in SHAPE_ORDER:
            k = f"{arch}__{shp}" + (f"__{tag}" if tag else "")
            if k not in rf:
                continue
            d = rf[k]
            lines.append(
                f"| {arch} | {shp} | {_ms(d['t_compute'])} | "
                f"{_ms(d['t_memory'])} | {_ms(d['t_collective'])} | "
                f"**{d['bottleneck']}** | {d['roofline_fraction']:.2f} | "
                f"{_fmt(d['model_flops'])} | {_fmt(d['flops'])} | "
                f"{d['useful_ratio']:.2f} | {d['mfu_bound']:.3f} | "
                f"{d['peak_gb_per_dev']:.1f} | {lever(d)} |")
    return "\n".join(lines)


def lever(d) -> str:
    """One sentence: what would move the dominant term down."""
    b = d["bottleneck"]
    cb = d.get("coll_breakdown", {})
    if b == "collective":
        top = max(cb, key=cb.get) if cb else "coll_ar"
        name = {"coll_ar": "all-reduce", "coll_ag": "all-gather",
                "coll_rs": "reduce-scatter", "coll_a2a": "all-to-all",
                "coll_cp": "collective-permute"}[top]
        return (f"dominant {name}: reshard to cut it (FSDP "
                f"reduce-scatter / replicate small params / fuse collectives)")
    if b == "memory":
        if d["useful_ratio"] < 0.5:
            return ("HLO bytes ≫ useful: fuse stat reductions (Pallas "
                    "gram/rowsumsq) and drop remat re-reads")
        return "increase arithmetic intensity: larger per-device tiles / batch"
    if d["useful_ratio"] < 0.4:
        return ("flops overhead (remat + stats): adaptive gram estimator "
                "and selective remat")
    return "near compute roof: only kernel-level MXU utilization remains"


def main():
    dr = load("experiments/dryrun")
    rf = load("experiments/roofline")
    print("## §Dry-run (all 40 cells × 2 meshes)\n")
    print(dryrun_table(dr))
    print("\n¹ documented skip (see DESIGN.md §6).\n")
    print("\n## §Roofline (single-pod 16×16, 256 chips)\n")
    print(roofline_table({k: v for k, v in rf.items() if "__opt" not in k
                          and "__" in k}))


if __name__ == "__main__":
    main()
