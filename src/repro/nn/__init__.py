"""repro.nn"""
