"""Gated / plain MLPs: SwiGLU (llama/qwen), GeGLU (gemma), ReLU² (minitron)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn.linear import init_linear, linear


@dataclasses.dataclass(frozen=True)
class MlpCfg:
    d_model: int
    d_ff: int
    act: str = "silu"        # silu | gelu | relu2
    gated: bool = True


def _act(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: MlpCfg, *, dtype):
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], cfg.d_model, cfg.d_ff, dtype=dtype,
                           axes=("embed", "mlp"))}
    if cfg.gated:
        p["gate"] = init_linear(ks[1], cfg.d_model, cfg.d_ff, dtype=dtype,
                                axes=("embed", "mlp"))
    p["down"] = init_linear(ks[2], cfg.d_ff, cfg.d_model, dtype=dtype,
                            axes=("mlp", "embed"))
    return p


def mlp(p, x, *, tap: Tap, cfg: MlpCfg, group: str = "mlp"):
    up = linear(p["up"], x, tap=tap, group=group)
    if cfg.gated:
        g = linear(p["gate"], x, tap=tap, group=group)
        h = _act(cfg.act)(g) * up
    else:
        h = _act(cfg.act)(up)
    h = shard(h, "batch", None, "mlp_act")
    y = linear(p["down"], h, tap=tap, group=group)
    return shard(y, "batch", None, "embed_act")
