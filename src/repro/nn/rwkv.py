"""RWKV6 ("Finch") — attention-free time-mix with data-dependent decay.

Per head (dim N): state S ∈ ℝ^{N×N};
    o_t = r_t · (S_{t-1} + diag(u)·k_t v_tᵀ)
    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ
with w_t = exp(-exp(w0 + tanh(x w1) w2)) — the data-dependent decay
LoRA — and ddlerp token-shift mixing for the r/k/v/g/w streams.

Decode is one step of the same recurrence: O(1) state, which is why
rwkv6 runs the `long_500k` cell. All matrix params (r/k/v/g/o, LoRA
A/B, channel-mix, decay LoRA) are tapped; the small per-channel vectors
(μ's, w0, u) are outside the pex scope (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn import param as pm
from repro.nn.linear import init_linear, linear
from repro.nn.norms import init_layernorm, layernorm


@dataclasses.dataclass(frozen=True)
class RwkvCfg:
    d_model: int
    d_ff: int
    head_dim: int = 64
    mix_lora: int = 32
    decay_lora: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


_STREAMS = 5  # r, k, v, g, w


def init_rwkv_tmix(key, cfg: RwkvCfg, *, dtype):
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    return {
        "mu": pm.zeros((_STREAMS + 1, d), dtype, (None, "embed")),
        "mix_a": init_linear(ks[0], d, _STREAMS * cfg.mix_lora, dtype=dtype,
                             axes=("embed", None), std=0.02),
        "mix_b": pm.normal(ks[1], (_STREAMS, cfg.mix_lora, d), dtype,
                           (None, None, "embed"), std=0.02),
        "wr": init_linear(ks[2], d, d, dtype=dtype, axes=("embed", "heads")),
        "wk": init_linear(ks[3], d, d, dtype=dtype, axes=("embed", "heads")),
        "wv": init_linear(ks[4], d, d, dtype=dtype, axes=("embed", "heads")),
        "wg": init_linear(ks[5], d, d, dtype=dtype, axes=("embed", "heads")),
        "wo": init_linear(ks[6], d, d, dtype=dtype, axes=("heads", "embed")),
        "w0": pm.constant(-6.0, (d,), jnp.float32, (None,)),
        "decay_a": init_linear(ks[7], d, cfg.decay_lora, dtype=dtype,
                               axes=("embed", None), std=0.02),
        "decay_b": init_linear(ks[8], cfg.decay_lora, d, dtype=dtype,
                               axes=(None, "embed"), std=0.02),
        "u": pm.zeros((d,), jnp.float32, (None,)),
        "ln_x": init_layernorm(d, dtype=dtype),
    }


def init_rwkv_cmix(key, cfg: RwkvCfg, *, dtype):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "mu": pm.zeros((2, d), dtype, (None, "embed")),
        "wk": init_linear(ks[0], d, cfg.d_ff, dtype=dtype, axes=("embed", "mlp")),
        "wr": init_linear(ks[1], d, d, dtype=dtype, axes=("embed", "embed2")),
        "wv": init_linear(ks[2], cfg.d_ff, d, dtype=dtype, axes=("mlp", "embed")),
    }


def init_rwkv_state(batch: int, cfg: RwkvCfg, *, dtype):
    d = cfg.d_model
    return {"tm_shift": jnp.zeros((batch, d), dtype),
            "cm_shift": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                             jnp.float32)}


def _token_shift(x, prev: Optional[jax.Array]):
    """xx_t = x_{t-1}; prev = last token of the previous segment."""
    b, s, d = x.shape
    first = jnp.zeros((b, 1, d), x.dtype) if prev is None else prev[:, None]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def rwkv_tmix(p, x, *, tap: Tap, cfg: RwkvCfg, state=None,
              group: str = "rwkv"):
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    xx = _token_shift(x, state["tm_shift"] if state is not None else None)
    dx = xx - x

    # ddlerp: base mix, then per-stream LoRA refinement
    xbase = x + dx * p["mu"][_STREAMS]
    la = linear(p["mix_a"], xbase, tap=tap, group=group)
    la = jnp.tanh(la).reshape(b, s, _STREAMS, cfg.mix_lora)
    mixed = []
    for i in range(_STREAMS):  # per-stream LoRA-B, tapped
        lb_i = tap.dense(la[:, :, i], p["mix_b"][i], group=group)
        mixed.append(x + dx * (p["mu"][i] + lb_i))
    xr, xk, xv, xg, xw = mixed

    r = linear(p["wr"], xr, tap=tap, group=group)
    k = linear(p["wk"], xk, tap=tap, group=group)
    v = linear(p["wv"], xv, tap=tap, group=group)
    g = linear(p["wg"], xg, tap=tap, group=group)

    dw = linear(p["decay_a"], xw, tap=tap, group=group)
    dw = linear(p["decay_b"], jnp.tanh(dw), tap=tap, group=group)
    w = jnp.exp(-jnp.exp(p["w0"] + dw.astype(jnp.float32)))      # (B,S,d)

    r_ = r.reshape(b, s, nh, hd).astype(jnp.float32)
    k_ = k.reshape(b, s, nh, hd).astype(jnp.float32)
    v_ = v.reshape(b, s, nh, hd).astype(jnp.float32)
    w_ = w.reshape(b, s, nh, hd)
    u_ = p["u"].reshape(nh, hd)

    s0 = state["wkv"] if state is not None else \
        jnp.zeros((b, nh, hd, hd), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,nh,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B,nh,hd,hd)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, S + u_[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o_t

    s_final, o = jax.lax.scan(
        step, s0, tuple(jnp.moveaxis(a, 1, 0) for a in (r_, k_, v_, w_)))
    o = jnp.moveaxis(o, 0, 1).reshape(b, s, d).astype(x.dtype)

    o = layernorm(p["ln_x"], o, tap=tap)  # group-norm surrogate
    o = o * jax.nn.silu(g)
    y = linear(p["wo"], o, tap=tap, group=group)
    y = shard(y, "batch", None, "embed_act")
    new_state = None
    if state is not None:
        new_state = {**state, "tm_shift": x[:, -1], "wkv": s_final}
    return y, new_state


def rwkv_cmix(p, x, *, tap: Tap, cfg: RwkvCfg, state=None,
              group: str = "rwkv"):
    xx = _token_shift(x, state["cm_shift"] if state is not None else None)
    dx = xx - x
    xk = x + dx * p["mu"][0]
    xr = x + dx * p["mu"][1]
    k = linear(p["wk"], xk, tap=tap, group=group)
    k = jnp.square(jax.nn.relu(k))
    kv = linear(p["wv"], k, tap=tap, group=group)
    r = linear(p["wr"], xr, tap=tap, group=group)
    y = jax.nn.sigmoid(r) * kv
    new_state = None
    if state is not None:
        new_state = {**state, "cm_shift": x[:, -1]}
    return shard(y, "batch", None, "embed_act"), new_state
