"""Token embedding + LM head, with vocab padding for TP divisibility.

Padded vocab rows are zero-init and their logits are masked to -inf, so
losses, gradients and per-example stats are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import Tap
from repro.dist.sharding import pad_to, shard
from repro.nn import param as pm

NEG_INF = -1.0e30


@dataclasses.dataclass(frozen=True)
class VocabCfg:
    vocab: int
    d_model: int
    vocab_multiple: int = 16
    logit_softcap: Optional[float] = None   # gemma2 final softcap
    scale_by_sqrt_dim: bool = False         # gemma multiplies embeds by √d

    @property
    def vocab_p(self) -> int:
        return pad_to(self.vocab, self.vocab_multiple)


def init_embedding(key, cfg: VocabCfg, *, dtype):
    table = pm.normal(key, (cfg.vocab_p, cfg.d_model), dtype,
                      ("vocab", "embed"), std=0.02)
    if cfg.vocab_p != cfg.vocab:
        mask = (jnp.arange(cfg.vocab_p) < cfg.vocab).astype(dtype)
        table = pm.Boxed(table.value * mask[:, None], table.axes)
    return {"table": table}


def embed(p, ids, *, tap: Tap, cfg: VocabCfg,
          group: str = "embed") -> jax.Array:
    x = tap.embedding(p["table"], ids, group=group)
    if cfg.scale_by_sqrt_dim:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", None, "embed_act")


def init_lm_head(key, cfg: VocabCfg, *, dtype):
    w = pm.normal(key, (cfg.d_model, cfg.vocab_p), dtype,
                  ("embed", "vocab"), std=0.02)
    return {"w": w}


def lm_head(p, x, *, tap: Tap, cfg: VocabCfg,
            group: str = "head") -> jax.Array:
    t = tap if tap.spec.tap_head else taps.NULL
    logits = t.dense(x, p["w"], group=group,
                     method="direct" if t.live else None)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.vocab_p != cfg.vocab:
        mask = jnp.arange(cfg.vocab_p) < cfg.vocab
        logits = jnp.where(mask, logits, NEG_INF)
    return shard(logits, "batch", None, "vocab_act")


def per_example_xent(logits: jax.Array, labels: jax.Array,
                     label_mask: Optional[jax.Array] = None,
                     tap: Optional[Tap] = None) -> jax.Array:
    """Σ_t CE per example (paper §2: L^(j) over example j's targets).

    With a ``tap``, the (B, S) per-token loss map is registered via
    ``tap.token_loss`` before the reduction — an identity op that lets
    the plan layer seed its token-weighted reweighting backward
    (``Clip(C, granularity="token")``, DESIGN.md §9) through it.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if label_mask is not None:
        ll = ll * label_mask
    token_losses = -ll
    if tap is not None:
        token_losses = tap.token_loss(token_losses)
    return jnp.sum(token_losses, axis=tuple(range(1, token_losses.ndim)))
