"""Dense layers — every matmul in the framework routes through the
paper's tap so per-example norms are first-class everywhere."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.nn import param as pm


def init_linear(key, d_in: int, d_out: int, *, dtype, axes, bias: bool = False,
                std: Optional[float] = None):
    ks = jax.random.split(key, 2)
    p = {"w": pm.normal(ks[0], (d_in, d_out), dtype, axes, std)}
    if bias:
        p["b"] = pm.zeros((d_out,), dtype, (axes[-1],))
    return p


def linear(p, x, *, tap: Tap, group: str = "all",
           method: Optional[str] = None) -> jax.Array:
    """Instrumented affine map. Plain matmul when the tap is inert.

    A site carrying a ``"lora"`` entry (see ``nn.lora``) freezes the
    base weight/bias behind ``stop_gradient`` — no gradient, no
    per-example stat, classified `frozen` by pexlint — and adds the
    tapped low-rank delta on top.
    """
    if "lora" in p:
        from repro.nn import lora as _lora  # local: keep import cycle-free
        z = jnp.einsum("...i,io->...o", x, jax.lax.stop_gradient(p["w"]))
        if "b" in p:
            z = z + jax.lax.stop_gradient(p["b"])
        return z + _lora.delta(p["lora"], x, tap=tap, group=group,
                               method=method)
    z = tap.dense(x, p["w"], group=group, method=method)
    if "b" in p:
        z = tap.bias_add(z, p["b"], group=group)
    return z
