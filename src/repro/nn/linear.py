"""Dense layers — every matmul in the framework routes through the
paper's tap so per-example norms are first-class everywhere."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import taps
from repro.core.taps import PexSpec
from repro.nn import param as pm


def init_linear(key, d_in: int, d_out: int, *, dtype, axes, bias: bool = False,
                std: Optional[float] = None):
    ks = jax.random.split(key, 2)
    p = {"w": pm.normal(ks[0], (d_in, d_out), dtype, axes, std)}
    if bias:
        p["b"] = pm.zeros((d_out,), dtype, (axes[-1],))
    return p


def linear(p, x, acc, *, spec: PexSpec, group: str = "all",
           method: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    z, acc = taps.dense(x, p["w"], acc, spec=spec, group=group, method=method)
    if "b" in p:
        z, acc = taps.bias_add(z, p["b"], acc, spec=spec, group=group)
    return z, acc
