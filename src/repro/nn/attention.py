"""Multi-head attention family: GQA (+bias), sliding-window/global,
logit softcapping, M-RoPE, cross-attention, and a decode KV cache.

Covers llama3.2 / qwen2 / qwen2-vl / minitron / gemma2 / phi3.5-moe /
zamba2's shared block / seamless enc-dec. (MLA is nn/mla.py.)

Q heads are padded to ``head_multiple`` (the TP degree) when the true
count doesn't divide it — padded heads have zero in/out projections so
logits, gradients and per-example stats are exact (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import pad_to, shard
from repro.nn import param as pm
from repro.nn.linear import init_linear, linear
from repro.nn.rotary import apply_rope, mrope_angles, rope_angles

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    bias: bool = False                 # qwen2-style QKV bias
    softcap: Optional[float] = None    # gemma2 attn logit softcap
    window: Optional[int] = None       # sliding-window size (None = global)
    rope_theta: float = 10000.0
    rope_dim: Optional[int] = None     # partial rotary (None = full head_dim)
    mrope_sections: Optional[Tuple[int, ...]] = None
    attn_scale: Optional[float] = None # None → head_dim ** -0.5
    head_multiple: int = 16            # pad n_heads up to this multiple
    cross: bool = False                # cross-attention (kv from memory)
    causal: bool = True
    d_out: Optional[int] = None        # output dim if != d_model (zamba2)
    flash: bool = False                # Pallas flash kernel for the full-seq
                                       # causal path (TPU; interpret on CPU)

    @property
    def n_heads_p(self) -> int:
        return pad_to(self.n_heads, self.head_multiple)

    @property
    def scale(self) -> float:
        return self.attn_scale if self.attn_scale is not None \
            else self.head_dim ** -0.5


def init_attention(key, cfg: AttnCfg, *, dtype):
    ks = jax.random.split(key, 4)
    hq = cfg.n_heads_p * cfg.head_dim
    hkv = cfg.n_kv * cfg.head_dim
    p = {
        "wq": init_linear(ks[0], cfg.d_model, hq, dtype=dtype,
                          axes=("embed", "heads"), bias=cfg.bias),
        "wk": init_linear(ks[1], cfg.d_model, hkv, dtype=dtype,
                          axes=("embed", "kv_heads"), bias=cfg.bias),
        "wv": init_linear(ks[2], cfg.d_model, hkv, dtype=dtype,
                          axes=("embed", "kv_heads"), bias=cfg.bias),
        "wo": init_linear(ks[3], hq, cfg.d_out or cfg.d_model, dtype=dtype,
                          axes=("heads", "embed"), bias=False),
    }
    if cfg.n_heads_p != cfg.n_heads:  # zero the padded head slices → exact
        hreal = cfg.n_heads * cfg.head_dim
        mask = (jnp.arange(hq) < hreal).astype(dtype)
        p["wq"]["w"] = pm.Boxed(p["wq"]["w"].value * mask[None, :],
                                p["wq"]["w"].axes)
        p["wo"]["w"] = pm.Boxed(p["wo"]["w"].value * mask[:, None],
                                p["wo"]["w"].axes)
    return p


def init_kv_cache(batch: int, max_len: int, cfg: AttnCfg, *, dtype):
    shape = (batch, max_len, cfg.n_kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _split_heads(x, n, d):
    return x.reshape(x.shape[0], x.shape[1], n, d)


def _attend(q, k, v, cfg: AttnCfg, q_offset, kv_len: Optional[jax.Array],
            local_flag: Optional[jax.Array] = None):
    """q (B,S,Hp,D), k/v (B,T,Hkv,D). q_offset: absolute position of
    q[.,0]; kv_len: number of valid cache rows (decode) or None.
    local_flag: traced bool — apply cfg.window only where True (gemma2's
    alternating local/global under a single layer scan)."""
    b, s, hp, d = q.shape
    t = k.shape[1]
    rep = hp // k.shape[2]
    qg = q.reshape(b, s, k.shape[2], rep, d)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg, k,
                        preferred_element_type=jnp.float32) * cfg.scale
    if cfg.softcap is not None:
        logits = cfg.softcap * jnp.tanh(logits / cfg.softcap)

    qpos = q_offset + jnp.arange(s)[:, None]        # (S,1)
    kpos = jnp.arange(t)[None, :]                   # (1,T)
    mask = jnp.ones((s, t), bool)
    if cfg.causal and not cfg.cross:
        mask &= kpos <= qpos
    if cfg.window is not None:
        in_window = (qpos - kpos) < cfg.window
        if local_flag is None:
            mask &= in_window
        else:
            mask &= in_window | ~local_flag
    if kv_len is not None:
        mask &= kpos < kv_len
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    attn = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkd->bskrd", attn, v)
    return out.reshape(b, s, hp * d)


def attention(p, x, *, tap: Tap, cfg: AttnCfg,
              positions: Optional[jax.Array] = None,
              memory: Optional[jax.Array] = None,
              cache=None, cache_index=None,
              local_flag: Optional[jax.Array] = None,
              group: str = "attn"):
    """Full-sequence (train/prefill) or incremental (decode) attention.

    positions: (S,) / (B,S) int, or (3,B,S) for M-RoPE.
    memory:    encoder output for cross-attention (cfg.cross).
    cache:     KV cache dict for decode; cache_index: write offset.
    Returns (y, new_cache).
    """
    b, s, _ = x.shape
    q = linear(p["wq"], x, tap=tap, group=group)
    kv_src = memory if cfg.cross else x
    k = linear(p["wk"], kv_src, tap=tap, group=group)
    v = linear(p["wv"], kv_src, tap=tap, group=group)
    q = _split_heads(q, cfg.n_heads_p, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv, cfg.head_dim)
    q = shard(q, "batch", None, "heads_act", None)
    k = shard(k, "batch", None, "kv_heads_act", None)
    v = shard(v, "batch", None, "kv_heads_act", None)

    if not cfg.cross:
        if positions is None:
            positions = jnp.arange(s)[None].repeat(b, 0) if cache_index is None \
                else (cache_index + jnp.arange(s))[None].repeat(b, 0)
        if cfg.mrope_sections is not None:
            if positions.ndim == 2:   # text-only fallback: t=h=w stream
                positions = jnp.broadcast_to(positions, (3,) + positions.shape)
            ang = mrope_angles(positions, cfg.rope_dim or cfg.head_dim,
                               cfg.rope_theta, cfg.mrope_sections)
        else:
            if positions.ndim == 1:
                positions = positions[None]
            ang = rope_angles(positions, cfg.rope_dim or cfg.head_dim,
                              cfg.rope_theta)
        q = apply_rope(q, ang, cfg.rope_dim)
        k = apply_rope(k, ang, cfg.rope_dim)

    kv_len = None
    q_offset = 0
    if cache is not None and not cfg.cross:
        # write new k/v rows at cache_index, attend over the full buffer
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, 1),
        }
        k, v = cache["k"], cache["v"]
        kv_len = cache_index + s
        q_offset = cache_index
    elif cfg.cross and cache is not None:
        # cross-attn cache: precomputed k/v of the encoder memory
        k, v = cache["k"], cache["v"]

    use_flash = (cfg.flash and cache is None and not cfg.cross
                 and cfg.causal and cfg.softcap is None
                 and local_flag is None and q.shape[1] % 128 == 0)
    if use_flash:
        from repro.kernels.ops import flash_attention_vjp
        yf = flash_attention_vjp(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
            jnp.moveaxis(v, 1, 2), scale=cfg.scale, window=cfg.window)
        y = jnp.moveaxis(yf, 1, 2).reshape(q.shape[0], q.shape[1], -1)
    else:
        y = _attend(q, k, v, cfg, q_offset, kv_len, local_flag)
    y = linear(p["wo"], y, tap=tap, group=group)
    y = shard(y, "batch", None, "embed_act")
    return y, cache
