"""Mixture-of-Experts with capacity-based dispatch (GShard-style) and
expert parallelism over the `model` mesh axis.

Token→expert routing is computed with a sort (no (T·K, E) one-hot):
argsort by expert id gives each token its slot rank inside its expert;
rows past the static capacity drop out via scatter ``mode="drop"``.
Per-example gradient norms stay exact through the shuffle: every
capacity slot carries its example id AND its source token position, and
the expert matmuls use the expert taps (``tap.dense_expert_grouped``) —
segmented-direct stats at example granularity, per-slot factorized
stats scattered by token position at token granularity.

Covers deepseek-v2 (160 routed + 2 shared, top-6, softmax gate without
renorm) and phi3.5-moe (16 experts, top-2, renormalized gate).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn import param as pm
from repro.nn.linear import init_linear, linear
from repro.nn.mlp import MlpCfg, init_mlp, mlp, _act


@dataclasses.dataclass(frozen=True)
class MoeCfg:
    d_model: int
    d_ff: int                    # per-expert hidden
    n_experts: int
    top_k: int
    n_shared: int = 0            # deepseek shared experts (merged into one MLP)
    capacity_factor: float = 1.25
    act: str = "silu"
    renorm_topk: bool = False    # phi/mixtral renormalize selected gates
    routed_scale: float = 1.0    # deepseek routed_scaling_factor
    # grouped local dispatch (GShard-style): each group (aligned with a
    # data shard) scatters its own tokens into its own capacity slice, so
    # dispatch/combine never cross devices — the global-scatter path makes
    # GSPMD replicate the (E, C, d) buffer and all-reduce it (measured:
    # 87% of deepseek train collective bytes). 1 = global scatter.
    dispatch_groups: int = 1

    def capacity(self, n_tokens: int) -> int:
        c = int(self.capacity_factor * n_tokens * self.top_k
                / self.n_experts) + 1
        return max(8, ((c + 7) // 8) * 8)


def init_moe(key, cfg: MoeCfg, *, dtype):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": init_linear(ks[0], d, e, dtype=jnp.float32,
                              axes=("embed", None), std=0.02),
        # EP owns the expert axis; inner dims use dedicated logical axes so
        # they never double-map the model axis
        "gate": pm.normal(ks[1], (e, d, f), dtype,
                          ("experts", "embed", "expert_ff"), std=d ** -0.5),
        "up": pm.normal(ks[2], (e, d, f), dtype,
                        ("experts", "embed", "expert_ff"), std=d ** -0.5),
        "down": pm.normal(ks[3], (e, f, d), dtype,
                          ("experts", "expert_ff", "embed"), std=f ** -0.5),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(
            ks[4], MlpCfg(d, cfg.n_shared * f, act=cfg.act), dtype=dtype)
    return p


def _route(cfg: MoeCfg, logits: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """logits (T, E) → (gates (T,K), expert idx (T,K))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renorm_topk:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates * cfg.routed_scale, idx


def moe(p, x, *, tap: Tap, cfg: MoeCfg, group: str = "moe",
        example_ids: Optional[jax.Array] = None):
    """x: (B, S, d). example_ids: (B,) int (defaults to arange(B))."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    ng = cfg.dispatch_groups if t % cfg.dispatch_groups == 0 and \
        b % cfg.dispatch_groups == 0 else 1
    tg = t // ng
    cap = cfg.capacity(tg)

    # router tap sees (B, S, ·) so its per-example stats stay exact
    logits = linear(p["router"], x.astype(jnp.float32), tap=tap, group=group)
    gates, eidx = _route(cfg, logits.reshape(t, -1))        # (T,K)

    if example_ids is None:
        example_ids = jnp.arange(b, dtype=jnp.int32)
    bg = b // ng                                            # examples/group
    tok_example = jnp.repeat(example_ids, s)                # (T,)
    rel_example = (tok_example % bg).reshape(ng, tg)        # group-local ids

    # --- slot assignment via per-group sort --------------------------------
    # Everything below is expressed as BATCHED GATHERS over the group axis
    # (take_along_axis, axis=1): GSPMD partitions batched gathers on their
    # sharded batch dim, whereas the scatter formulation makes it replicate
    # the (E, C, d) buffer across the mesh and all-reduce it (measured:
    # 87% of deepseek train collective bytes; see EXPERIMENTS.md §Perf).
    e_dim = cfg.n_experts
    flat_e = eidx.reshape(ng, tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k), (ng, tg * k))
    flat_gate = gates.reshape(ng, tg * k)

    def assign(e_row):
        order = jnp.argsort(e_row, stable=True)
        sorted_e = e_row[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e_dim + 1))
        pos = jnp.arange(tg * k, dtype=jnp.int32) - starts[sorted_e]
        return order, sorted_e, pos, starts

    order, sorted_e, pos, starts = jax.vmap(assign)(flat_e)
    src_tok = jnp.take_along_axis(flat_tok, order, axis=1)   # (G, Tg·K)

    # slot (e, c) ← token: sorted position starts[e]+c, valid if c < count_e
    c_iota = jnp.arange(cap, dtype=jnp.int32)
    sorted_pos = starts[:, :-1, None] + c_iota[None, None, :]  # (G, E, cap)
    count = (starts[:, 1:] - starts[:, :-1])[..., None]        # (G, E, 1)
    slot_valid = c_iota[None, None, :] < jnp.minimum(count, cap)
    sorted_pos = jnp.minimum(sorted_pos, tg * k - 1).reshape(ng, e_dim * cap)
    tok_for_slot = jnp.take_along_axis(src_tok, sorted_pos, axis=1)
    tok_for_slot = jnp.where(slot_valid.reshape(ng, e_dim * cap),
                             tok_for_slot, tg)                 # tg ⇒ pad row

    # --- dispatch: batched gather from zero-padded local tokens -------------
    xg = x.reshape(ng, tg, d)
    xg_pad = jnp.concatenate([xg, jnp.zeros((ng, 1, d), x.dtype)], axis=1)
    buf = jnp.take_along_axis(xg_pad, tok_for_slot[..., None], axis=1)
    buf = buf.reshape(ng, e_dim, cap, d)
    rel_pad = jnp.concatenate(
        [rel_example, jnp.full((ng, 1), bg, jnp.int32)], axis=1)
    seg = jnp.take_along_axis(rel_pad, tok_for_slot, axis=1)
    seg = seg.reshape(ng, e_dim, cap)
    # the dispatch sort already knows each slot's source token — carry it
    # so TokenLayout taps can scatter slot stats back to (B, S) positions
    # (tg ⇒ padding slot; group g covers flat tokens [g·tg, (g+1)·tg))
    tok = tok_for_slot.reshape(ng, e_dim, cap)
    buf = shard(buf, "moe_groups", "experts", "capacity", None)

    # --- expert MLP (tapped; stats via group-local segmented-direct) --------
    g = tap.dense_expert_grouped(buf, p["gate"], seg, bg, tok, group=group)
    u = tap.dense_expert_grouped(buf, p["up"], seg, bg, tok, group=group)
    h = (_act(cfg.act)(g) * u).astype(x.dtype)
    y_buf = tap.dense_expert_grouped(h, p["down"], seg, bg, tok, group=group)
    y_buf = shard(y_buf, "moe_groups", "experts", "capacity", None)

    # --- combine: batched gather back (dropped slots → zero pad row) --------
    slot_sorted = jnp.where(pos < cap, sorted_e * cap + pos, e_dim * cap)
    inv = jnp.argsort(order, axis=1)
    slot_orig = jnp.take_along_axis(slot_sorted, inv, axis=1)  # (G, Tg·K)
    y_flat = jnp.concatenate(
        [y_buf.reshape(ng, e_dim * cap, d),
         jnp.zeros((ng, 1, d), y_buf.dtype)], axis=1)
    slot_y = jnp.take_along_axis(y_flat, slot_orig[..., None], axis=1)
    contrib = slot_y * flat_gate[..., None].astype(x.dtype)
    y = jnp.sum(contrib.reshape(t, k, d), axis=1)

    if cfg.n_shared:
        ys = mlp(p["shared"], x, tap=tap,
                 cfg=MlpCfg(d, cfg.n_shared * cfg.d_ff, act=cfg.act),
                 group=group)
        y = y.reshape(b, s, d) + ys
    else:
        y = y.reshape(b, s, d)
    return shard(y, "batch", None, "embed_act")


def load_balance_loss(cfg: MoeCfg, logits: jax.Array) -> jax.Array:
    """Switch-style aux loss (batch-coupled ⇒ off by default when exact
    per-example norms are required; see DESIGN.md §5)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.top_k)
    onehot = jax.nn.one_hot(idx, cfg.n_experts).sum(axis=-2)
    f = jnp.mean(onehot, axis=0)
    p_mean = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * p_mean)
