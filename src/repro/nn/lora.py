"""LoRA adapters over the tapped linear layers (DESIGN.md §14).

A LoRA site replaces the trainable weight of one ``nn.linear`` site
with a frozen base plus a low-rank trainable delta:

    z = x @ stop_grad(W)  +  (α/r) · (x @ A) @ B

Both factors route through the tap (``tap.dense`` — or
``tap.dense_batched`` when the factors carry a leading per-example
axis, the multi-tenant gather), so per-example gradient norms on the
adapters are exact and nearly free: the factors are rank-r ``(p, r)``
/ ``(r, p)`` matrices — precisely the regime where the paper's
factorized/direct estimators win (arxiv 1510.01799 §4–§5). The base
weight is wrapped in ``stop_gradient`` so it contributes no gradient
and no stat; ``analysis.coverage`` classifies it frozen, not
untapped-ERROR.

The (α/r) scale is applied AFTER the second factor so the cotangent
arriving at each tap already carries it — the accumulated stats
describe the true (scaled) adapter gradients.

``LoraPair`` is a registered pytree node (children ``(a, b)``, static
``alpha``), so adapters survive ``tree_map``, ``lax.scan`` slicing,
``unbox``, optimizers, and checkpointing unchanged.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.nn import param as pm

#: default sites: every projection the transformer family routes
#: through ``nn.linear`` (attention + MLP)
DEFAULT_SITES = ("wq", "wk", "wv", "wo", "up", "gate", "down")


@dataclasses.dataclass(frozen=True)
class LoraCfg:
    """Static LoRA policy (hashable; lives inside ``LMConfig``).

    rank:            default adapter rank r.
    alpha:           scale numerator — the delta is (α/r)·AB.
    sites:           site names (the dict key holding the ``{"w": ...}``
                     linear params) that get adapters.
    rank_overrides:  ((site, rank), ...) per-site rank exceptions.
    """
    rank: int = 8
    alpha: float = 16.0
    sites: Tuple[str, ...] = DEFAULT_SITES
    rank_overrides: Tuple[Tuple[str, int], ...] = ()

    def rank_for(self, site: str) -> int:
        for name, r in self.rank_overrides:
            if name == site:
                return r
        return self.rank


@jax.tree_util.register_pytree_node_class
class LoraPair:
    """One site's adapter factors: ``a`` (..., d_in, r), ``b``
    (..., r, d_out) — arrays or ``Boxed`` leaves, with any shared
    leading axes (stacked layers, tenant rows)."""

    __slots__ = ("a", "b", "alpha")

    def __init__(self, a, b, alpha: float):
        self.a = a
        self.b = b
        self.alpha = float(alpha)

    def tree_flatten(self):
        return (self.a, self.b), self.alpha

    @classmethod
    def tree_unflatten(cls, alpha, children):
        return cls(children[0], children[1], alpha)

    @property
    def rank(self) -> int:
        val = self.a.value if pm.is_boxed(self.a) else self.a
        return val.shape[-1]

    def __repr__(self):
        sa = getattr(self.a, "shape", None) or getattr(
            getattr(self.a, "value", None), "shape", None)
        return f"LoraPair(a={sa}, alpha={self.alpha})"


def init_pair(key, d_in: int, d_out: int, rank: int, alpha: float, *,
              dtype=jnp.float32, lead: Tuple[int, ...] = (),
              w_axes: Optional[Tuple] = None, boxed: bool = True,
              b_std: Optional[float] = None) -> LoraPair:
    """Standard LoRA init: A ~ N(0, 1/√d_in), B = 0 (delta starts at
    zero). ``lead`` prepends shared axes (stacked layers / tenant
    capacity); ``b_std`` > 0 draws B randomly instead (tests that need
    non-zero adapter gradients from step 0). ``boxed=False`` returns
    plain arrays (the tenancy store works unboxed)."""
    ka, kb = jax.random.split(key)
    ax = w_axes if w_axes is not None else (None,) * (len(lead) + 2)
    a = pm.normal(ka, lead + (d_in, rank), dtype,
                  (None,) * len(lead) + (ax[-2], None),
                  std=1.0 / math.sqrt(max(1, d_in)))
    if b_std and b_std > 0.0:
        b = pm.normal(kb, lead + (rank, d_out), dtype,
                      (None,) * len(lead) + (None, ax[-1]), std=b_std)
    else:
        b = pm.zeros(lead + (rank, d_out), dtype,
                     (None,) * len(lead) + (None, ax[-1]))
    if not boxed:
        return LoraPair(a.value, b.value, alpha)
    return LoraPair(a, b, alpha)


def delta(pair: LoraPair, x, *, tap: Tap, group: str = "all",
          method: Optional[str] = None) -> jax.Array:
    """(α/r)·(x @ A) @ B through the tap. Factors with a leading
    per-example axis (``a.ndim == 3`` against 2-D site weights — the
    multi-tenant gathered form) go through ``tap.dense_batched``; the
    shared form through ``tap.dense``. The scale multiplies the
    *output*, so the taps see the true scaled cotangents."""
    a, b = pair.a, pair.b
    scale = pair.alpha / pair.rank
    if a.ndim == x.ndim == 2 or (a.ndim == 3 and x.ndim == 3
                                 and a.shape[0] == x.shape[0]):
        # ambiguous only in the (a 3-D, x 3-D) case: leading axes match
        # ⇒ per-example factors
        batched = a.ndim == 3
    else:
        batched = a.ndim == x.ndim
    if batched and a.ndim >= 3:
        h_r = tap.dense_batched(x, a, group=group, method=method)
        d = tap.dense_batched(h_r, b, group=group, method=method)
    else:
        h_r = tap.dense(x, a, group=group, method=method)
        d = tap.dense(h_r, b, group=group, method=method)
    return scale * d


def attach(params, cfg: LoraCfg, key, *, dtype=jnp.float32):
    """Add ``"lora"`` entries to every matching linear-site dict of a
    (possibly stacked) parameter tree. A site matches when its dict
    key is in ``cfg.sites`` and it holds a ``"w"`` leaf; the factors
    inherit the weight's leading (stacked-layer) axes. Keys are
    derived deterministically from the site path, so attach order and
    dict iteration order don't matter."""
    def rec(node, path):
        if isinstance(node, dict):
            out = {}
            for name in node:
                child = node[name]
                if (name in cfg.sites and isinstance(child, dict)
                        and "w" in child):
                    w = child["w"]
                    val = w.value if pm.is_boxed(w) else w
                    axes = w.axes if pm.is_boxed(w) \
                        else (None,) * val.ndim
                    site_key = key
                    for part in path + (name,):
                        # crc32, not hash(): string hashing is
                        # per-process randomized, and attach keys must
                        # be stable across processes/restores
                        site_key = jax.random.fold_in(
                            site_key,
                            zlib.crc32(str(part).encode()) & 0x7FFFFFFF)
                    pair = init_pair(
                        site_key, val.shape[-2], val.shape[-1],
                        cfg.rank_for(name), cfg.alpha, dtype=dtype,
                        lead=tuple(val.shape[:-2]), w_axes=axes,
                        boxed=pm.is_boxed(w))
                    out[name] = dict(child)
                    out[name]["lora"] = pair
                else:
                    out[name] = rec(child, path + (name,))
            return out
        if isinstance(node, list):
            return [rec(c, path + (i,)) for i, c in enumerate(node)]
        return node

    return rec(params, ())


def adapter_tree(params):
    """Extract the trainable adapter subtree: {path: LoraPair} keyed by
    '/'-joined site paths — the tree the tenancy layer stores, trains,
    and checkpoints (base weights stay behind)."""
    out = {}

    def rec(node, path):
        if isinstance(node, LoraPair):
            out["/".join(str(p) for p in path)] = node
            return
        if isinstance(node, dict):
            for name, child in node.items():
                rec(child, path + (name,))
        elif isinstance(node, list):
            for i, child in enumerate(node):
                rec(child, path + (i,))

    rec(params, ())
    return out


def merge_adapters(params, adapters: dict):
    """Inverse of ``adapter_tree``: place each pair back at its path
    (returns a new tree; the input is not mutated)."""
    def rec(node, path):
        if isinstance(node, LoraPair):
            return adapters.get("/".join(str(p) for p in path), node)
        if isinstance(node, dict):
            return {k: rec(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, list):
            return [rec(v, path + (i,)) for i, v in enumerate(node)]
        return node

    return rec(params, ())
