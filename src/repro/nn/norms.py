"""RMSNorm / LayerNorm with tapped (elementwise) scale parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.nn import param as pm


def init_rmsnorm(d: int, *, dtype, plus_one: bool = False):
    # gemma parameterizes as (1 + g) with g init 0; others as g init 1
    init = pm.zeros if plus_one else pm.ones
    return {"g": init((d,), dtype, ("embed",)), }


def rmsnorm(p, x, *, tap: Tap, eps: float = 1e-6,
            plus_one: bool = False, group: str = "norm") -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    xn = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
    xn = xn.astype(dt)
    # gemma's (1+g): the stat/grad w.r.t. g is unchanged by the constant shift
    gval = (1.0 + p["g"].astype(jnp.float32)).astype(dt) if plus_one else p["g"].astype(dt)
    return tap.scale(xn, gval, group=group)


def init_layernorm(d: int, *, dtype):
    return {"g": pm.ones((d,), dtype, ("embed",)),
            "b": pm.zeros((d,), dtype, ("embed",))}


def layernorm(p, x, *, tap: Tap, eps: float = 1e-5,
              group: str = "norm") -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    xn = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt)
    y = tap.scale(xn, p["g"].astype(dt), group=group)
    return tap.bias_add(y, p["b"].astype(dt), group=group)
