"""Mamba2 (SSD) block for zamba2 — selective state-space with
multi-head state (headdim × d_state), scalar-per-head decay.

    h_t = exp(Δ_t·A) h_{t-1} + Δ_t·B_t ⊗ x_t          (per head)
    y_t = C_t·h_t + D ⊙ x_t

The recurrence runs as a `lax.scan` over time with an O(1) carry —
which is also exactly the decode path (one step of the same scan), so
`long_500k` decode needs no cache beyond the (B, H, hd, ds) state.

Dense projections are tapped; A_log/D/dt_bias go through the
elementwise/bias taps, so per-example norms cover the whole block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn import param as pm
from repro.nn.linear import init_linear, linear


@dataclasses.dataclass(frozen=True)
class SsmCfg:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def init_ssm(key, cfg: SsmCfg, *, dtype):
    ks = jax.random.split(key, 5)
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj → [z(di), x(di), B(ds), C(ds), dt(nh)]
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, 2 * di + 2 * ds + nh,
                               dtype=dtype, axes=("embed", "mlp")),
        "conv_w": pm.normal(ks[1], (cfg.conv_width, cfg.conv_dim), dtype,
                            (None, "mlp"), std=cfg.conv_width ** -0.5),
        "conv_b": pm.zeros((cfg.conv_dim,), dtype, ("mlp",)),
        "a_log": pm.constant(jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
                             (nh,), jnp.float32, (None,)),
        "d": pm.ones((nh,), jnp.float32, (None,)),
        "dt_bias": pm.zeros((nh,), jnp.float32, (None,)),
        "norm_g": pm.ones((di,), dtype, ("mlp",)),
        "out_proj": init_linear(ks[2], di, cfg.d_model, dtype=dtype,
                                axes=("mlp", "embed")),
    }


def init_ssm_state(batch: int, cfg: SsmCfg, *, dtype):
    return {"h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype)}


def _causal_conv(x, w, b, state: Optional[jax.Array]):
    """x (B,S,C) depthwise causal conv, width K. state: (B,K-1,C) tail of
    the previous segment (decode) or None (train, zero history)."""
    kw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kw)) + b
    new_state = xp[:, -(kw - 1):] if kw > 1 else None
    return out, new_state


def ssm(p, x, *, tap: Tap, cfg: SsmCfg,
        state=None, group: str = "ssm"):
    """x (B,S,d_model) → (y, new_state). Pass state for decode."""
    b, s, _ = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim

    zxbcdt = linear(p["in_proj"], x, tap=tap, group=group)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt = zxbcdt[..., -nh:]

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    bs = xbc[..., di:di + ds]
    cs = xbc[..., di + ds:]

    dt = tap.bias_add(dt.astype(jnp.float32), p["dt_bias"], group=group)
    dt = jax.nn.softplus(dt)                                      # (B,S,nh)
    a = -jnp.exp(p["a_log"])                                      # (nh,)
    decay = jnp.exp(dt * a)                                       # (B,S,nh)

    h0 = state["h"] if state is not None else \
        jnp.zeros((b, nh, hd, ds), jnp.float32)

    def step(h, inp):
        x_t, b_t, c_t, dt_t, dec_t = inp
        # h: (B,nh,hd,ds)
        dbx = jnp.einsum("bhd,bn,bh->bhdn", x_t.astype(jnp.float32),
                         b_t.astype(jnp.float32), dt_t)
        h = h * dec_t[:, :, None, None] + dbx
        y_t = jnp.einsum("bhdn,bn->bhd", h, c_t.astype(jnp.float32))
        return h, y_t

    xs_t = jnp.moveaxis(xs, 1, 0)
    bs_t = jnp.moveaxis(bs, 1, 0)
    cs_t = jnp.moveaxis(cs, 1, 0)
    dt_t = jnp.moveaxis(dt, 1, 0)
    dec_t = jnp.moveaxis(decay, 1, 0)
    h_final, ys = jax.lax.scan(step, h0, (xs_t, bs_t, cs_t, dt_t, dec_t))
    y = jnp.moveaxis(ys, 0, 1)                                    # (B,S,nh,hd)

    # skip connection D ⊙ x  (elementwise tap on the per-head D)
    y = y + xs.astype(jnp.float32) * p["d"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    # gated RMSNorm (mamba2's norm before out_proj)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
    y = tap.scale(yf.astype(x.dtype), p["norm_g"], group=group)

    y = linear(p["out_proj"], y, tap=tap, group=group)
    y = shard(y, "batch", None, "embed_act")
    new_state = {"h": h_final, "conv": new_conv} if state is not None else None
    return y, new_state
