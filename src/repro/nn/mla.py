"""Multi-head Latent Attention (DeepSeek-V2), TPU-adapted.

Prefill expands the latent KV for all positions (matmul-friendly).
Decode runs in *absorbed* form: scores are taken directly against the
cached 512-d latent + 64-d shared rope key, so the KV cache holds
(kv_lora + rope) = 576 floats/token instead of 2·H·D = 32768 — the
paper's (DeepSeek's) memory win, and the reason long decode cells fit.

Every projection is a tapped dense — the per-example norm machinery
sees MLA as five ordinary matmuls.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.taps import Tap
from repro.dist.sharding import shard
from repro.nn.attention import NEG_INF
from repro.nn.linear import init_linear, linear
from repro.nn.norms import init_rmsnorm, rmsnorm
from repro.nn.rotary import apply_rope, rope_angles


@dataclasses.dataclass(frozen=True)
class MlaCfg:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def scale(self) -> float:
        return (self.qk_nope + self.qk_rope) ** -0.5


def init_mla(key, cfg: MlaCfg, *, dtype):
    ks = jax.random.split(key, 7)
    h = cfg.n_heads
    return {
        "q_down": init_linear(ks[0], cfg.d_model, cfg.q_lora, dtype=dtype,
                              axes=("embed", "qlora")),
        "q_norm": init_rmsnorm(cfg.q_lora, dtype=dtype),
        "q_up": init_linear(ks[1], cfg.q_lora,
                            h * (cfg.qk_nope + cfg.qk_rope), dtype=dtype,
                            axes=("qlora", "heads")),
        "kv_down": init_linear(ks[2], cfg.d_model,
                               cfg.kv_lora + cfg.qk_rope, dtype=dtype,
                               axes=("embed", "kvlora")),
        "kv_norm": init_rmsnorm(cfg.kv_lora, dtype=dtype),
        "kv_up": init_linear(ks[3], cfg.kv_lora,
                             h * (cfg.qk_nope + cfg.v_dim), dtype=dtype,
                             axes=("kvlora", "heads")),
        "wo": init_linear(ks[4], h * cfg.v_dim, cfg.d_model, dtype=dtype,
                          axes=("heads", "embed")),
    }


def init_mla_cache(batch: int, max_len: int, cfg: MlaCfg, *, dtype):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope), dtype)}


def _project_q(p, x, tap, cfg, group):
    b, s, _ = x.shape
    q = linear(p["q_down"], x, tap=tap, group=group)
    q = rmsnorm(p["q_norm"], q, tap=tap)
    q = linear(p["q_up"], q, tap=tap, group=group)
    q = q.reshape(b, s, cfg.n_heads, cfg.qk_nope + cfg.qk_rope)
    return q[..., :cfg.qk_nope], q[..., cfg.qk_nope:]


def _latent_kv(p, x, tap, cfg, group):
    ckv = linear(p["kv_down"], x, tap=tap, group=group)
    c, krope = ckv[..., :cfg.kv_lora], ckv[..., cfg.kv_lora:]
    c = rmsnorm(p["kv_norm"], c, tap=tap)
    return c, krope


def mla_attention(p, x, *, tap: Tap, cfg: MlaCfg,
                  positions: Optional[jax.Array] = None,
                  cache=None, cache_index=None, group: str = "attn"):
    """Returns (y, new_cache). cache=None → full-seq (train/prefill);
    cache given → decode with the absorbed latent form."""
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, x, tap, cfg, group)
    c, krope = _latent_kv(p, x, tap, cfg, group)

    if positions is None:
        start = 0 if cache_index is None else cache_index
        positions = (start + jnp.arange(s))[None]
    ang = rope_angles(positions, cfg.qk_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, ang)
    krope = apply_rope(krope[:, :, None, :], ang)[:, :, 0, :]

    if cache is not None:
        cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], c.astype(cache["ckv"].dtype), cache_index, 1),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], krope.astype(cache["krope"].dtype), cache_index, 1),
        }
        c_all, krope_all = cache["ckv"], cache["krope"]
        kv_len = cache_index + s
        # absorbed: fold kv_up's nope-key block into q → score in latent space
        wkv = p["kv_up"]["w"].reshape(cfg.kv_lora, cfg.n_heads,
                                      cfg.qk_nope + cfg.v_dim)
        wk = wkv[..., :cfg.qk_nope]            # (kv_lora, H, nope)
        wv = wkv[..., cfg.qk_nope:]            # (kv_lora, H, v)
        q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, wk)
        scores = (jnp.einsum("bshl,btl->bhst", q_lat, c_all,
                             preferred_element_type=jnp.float32) +
                  jnp.einsum("bshd,btd->bhst", q_rope, krope_all,
                             preferred_element_type=jnp.float32)) * cfg.scale
        t = c_all.shape[1]
        qpos = cache_index + jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        mask = (kpos <= qpos) & (kpos < kv_len)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btl->bshl", attn, c_all)
        o = jnp.einsum("bshl,lhd->bshd", o_lat, wv)
    else:
        # expanded form for train/prefill
        kv = linear(p["kv_up"], c, tap=tap, group=group)
        kv = kv.reshape(b, s, cfg.n_heads, cfg.qk_nope + cfg.v_dim)
        k_nope, v = kv[..., :cfg.qk_nope], kv[..., cfg.qk_nope:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                      k_nope.shape[:3] + (cfg.qk_rope,))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        q = shard(q, "batch", None, "heads_act", None)
        k = shard(k, "batch", None, "heads_act", None)
        scores = jnp.einsum("bshd,bthd->bhst", q, k,
                            preferred_element_type=jnp.float32) * cfg.scale
        qpos = jnp.arange(s)[:, None]
        mask = jnp.arange(s)[None, :] <= qpos
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        attn = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", attn, v)

    y = linear(p["wo"], o.reshape(b, s, -1), tap=tap, group=group)
    return shard(y, "batch", None, "embed_act"), cache
