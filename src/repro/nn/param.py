"""Boxed parameters: value + logical sharding axes, as one pytree.

``Boxed`` registers axes as static aux-data, so ``jax.eval_shape`` over
an init function yields a Boxed tree of ShapeDtypeStructs that still
carries the sharding annotation — exactly what the multi-pod dry-run
needs (no device allocation, full sharding info).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Boxed:
    """A parameter leaf with logical axis names, e.g. ("embed", "mlp")."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[Optional[str], ...]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Boxed({shape}, axes={self.axes})"


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Boxed tree → plain value tree (idempotent on plain trees)."""
    return jax.tree_util.tree_map(
        lambda b: b.value if is_boxed(b) else b, tree, is_leaf=is_boxed)


def axes_of(tree):
    """Boxed tree → logical-axes tree (tuples as leaves)."""
    return jax.tree_util.tree_map(lambda b: b.axes, tree, is_leaf=is_boxed)


# --- initializers ----------------------------------------------------------

def normal(key, shape, dtype, axes, std: Optional[float] = None) -> Boxed:
    if std is None:  # fan-in scaling
        fan_in = shape[0] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(max(1, fan_in))
    return Boxed((std * jax.random.normal(key, shape, jnp.float32)).astype(dtype), axes)


def zeros(shape, dtype, axes) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype), axes)


def ones(shape, dtype, axes) -> Boxed:
    return Boxed(jnp.ones(shape, dtype), axes)


def constant(val, shape, dtype, axes) -> Boxed:
    return Boxed(jnp.full(shape, val, dtype), axes)


def count_params(tree) -> int:
    return sum(int(jnp.size(v)) if not hasattr(v, "shape") else int(math.prod(v.shape))
               for v in jax.tree_util.tree_leaves(unbox(tree)))
