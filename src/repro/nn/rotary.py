"""Rotary position embeddings: standard RoPE, partial RoPE (MLA's rope
sub-dim), and Qwen2-VL's multimodal M-RoPE (per-section t/h/w streams)."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def _inv_freq(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (..., S) int → angles (..., S, dim/2) f32."""
    inv = _inv_freq(dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def mrope_angles(positions: jax.Array, dim: int, theta: float,
                 sections: Sequence[int]) -> jax.Array:
    """M-RoPE: positions (3, B, S) — temporal/height/width streams.

    sections are in half-dim units and sum to dim/2 (qwen2-vl: 16/24/24
    at head_dim 128). Each frequency band takes its angle from its
    section's position stream.
    """
    assert positions.shape[0] == 3 and sum(sections) == dim // 2
    full = rope_angles(positions, dim, theta)        # (3, B, S, dim/2)
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        parts.append(full[i, ..., off:off + sec])
        off += sec
    return jnp.concatenate(parts, axis=-1)           # (B, S, dim/2)


def apply_rope(x: jax.Array, angles: jax.Array,
               rot_dim: Optional[int] = None) -> jax.Array:
    """x (B, S, H, D); angles (B, S, rot/2) or (S, rot/2). Rotates the
    first `rot_dim` features (default: all), half-split convention."""
    d = x.shape[-1]
    rot = rot_dim or d
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., :rot // 2], xr[..., rot // 2:]
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)   # (B,S,1,rot/2)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out
