"""Multi-tenant LoRA fine-tuning service (DESIGN.md §14).

The acceptance bar: per-tenant norms, clip coefficients and noised
adapter gradients from ONE fused mixed-tenant step must match a naive
per-tenant oracle (a loop running one single-tenant Engine per tenant)
for 100+ tenants sharing one batch — at example AND token granularity,
locally AND under shard_map — and the per-tenant DP noise must be
bit-exact against ``add_grad_noise`` on ``fold_in(rng, tenant_id)``.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import pex
from repro.analysis import coverage as cov
from repro.analysis import privacy as priv
from repro.core import passes
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec
from repro.launch.soak import tree_digest
from repro.nn import lora as lora_mod
from repro.nn import param as pm
from repro.nn.linear import linear
from repro.tenancy import (AdapterStore, TenantService, assemble,
                           per_tenant_count, per_tenant_sum)

KEY = jax.random.PRNGKey(0)
D, O, R, S = 6, 4, 2, 5
ALPHA = 8.0
C, SIGMA, LR = 1.0, 0.3, 0.1
W_BASE = jax.random.normal(KEY, (D, O)) * 0.3


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _init_fn(key):
    # b_std > 0: non-zero B so adapter grads are non-zero from step 0
    return {"site": lora_mod.init_pair(key, D, O, R, ALPHA, boxed=False,
                                       b_std=0.4)}


def _loss_fn(adapters, data, tap):
    p = {"w": W_BASE, "lora": adapters["site"]}
    z = linear(p, data["x"], tap=tap, group="all")
    tok = jnp.sum(jnp.square(z - data["y"]), axis=-1)
    tok = tap.token_loss(tok)
    return jnp.sum(tok, axis=1), {}


def _mixed_batch(n_tenants=120, max_per=4, seed=42):
    """Interleaved mixed-tenant batch: ragged 1..max_per examples per
    tenant, shuffled."""
    rs = np.random.RandomState(seed)
    tenants = rs.choice(np.arange(1000, 50_000), size=n_tenants,
                        replace=False)
    owner = np.concatenate(
        [np.full(rs.randint(1, max_per + 1), t) for t in tenants])
    rs.shuffle(owner)
    B = len(owner)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, D))
    y = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, O))
    return {"x": x, "y": y}, owner


# ---------------------------------------------------------------------------
# LoRA module units
# ---------------------------------------------------------------------------

def test_lora_pair_is_a_pytree():
    p = lora_mod.init_pair(KEY, D, O, R, ALPHA, boxed=False)
    doubled = jax.tree_util.tree_map(lambda l: 2 * l, p)
    assert isinstance(doubled, lora_mod.LoraPair)
    assert doubled.alpha == ALPHA and doubled.rank == R
    np.testing.assert_array_equal(np.asarray(doubled.a),
                                  2 * np.asarray(p.a))
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert len(leaves) == 2
    assert jax.tree_util.tree_unflatten(treedef, leaves).alpha == ALPHA


def test_lora_init_shapes_and_zero_delta():
    p = lora_mod.init_pair(KEY, D, O, R, ALPHA, boxed=False)
    assert p.a.shape == (D, R) and p.b.shape == (R, O)
    # default init: B == 0 so the adapter starts as the identity delta
    np.testing.assert_array_equal(np.asarray(p.b), 0.0)
    x = jax.random.normal(KEY, (3, D))
    np.testing.assert_array_equal(
        np.asarray(lora_mod.delta(p, x, tap=NULL)), 0.0)


def test_attach_adapter_tree_merge_roundtrip():
    params = {
        "blocks": {
            "attn": {"wq": {"w": pm.normal(KEY, (2, D, O),
                                           jnp.float32, (None, None, None))},
                     "scale": jnp.ones((D,))},
            "mlp": {"up": {"w": jax.random.normal(KEY, (D, O))}},
        },
    }
    cfg = lora_mod.LoraCfg(rank=3, alpha=6.0, sites=("wq", "up"),
                           rank_overrides=(("up", 2),))
    out = lora_mod.attach(params, cfg, KEY)
    wq = out["blocks"]["attn"]["wq"]["lora"]
    up = out["blocks"]["mlp"]["up"]["lora"]
    # stacked lead axis inherited from the weight; per-site rank override
    assert wq.a.value.shape == (2, D, 3)
    assert up.a.shape == (D, 2)
    assert "lora" not in out["blocks"]["attn"].get("scale", {})
    # attach is deterministic (crc32 path keys, not hash())
    out2 = lora_mod.attach(params, cfg, KEY)
    np.testing.assert_array_equal(np.asarray(wq.a.value),
                                  np.asarray(
        out2["blocks"]["attn"]["wq"]["lora"].a.value))
    # adapter_tree / merge_adapters round-trip
    at = lora_mod.adapter_tree(out)
    assert set(at) == {"blocks/attn/wq/lora", "blocks/mlp/up/lora"}
    bumped = {k: lora_mod.LoraPair(v.a, v.b, v.alpha + 1) if k.endswith(
        "wq/lora") else v for k, v in at.items()}
    merged = lora_mod.merge_adapters(out, bumped)
    assert merged["blocks"]["attn"]["wq"]["lora"].alpha == cfg.alpha + 1
    assert out["blocks"]["attn"]["wq"]["lora"].alpha == cfg.alpha


def test_frozen_base_gets_zero_grad():
    adapters = _init_fn(KEY)
    x = jax.random.normal(KEY, (3, S, D))
    y = jax.random.normal(jax.random.fold_in(KEY, 3), (3, S, O))

    def total(p):
        z = linear(p, x, tap=NULL)
        return jnp.sum(jnp.square(z - y))

    p = {"w": W_BASE, "lora": adapters["site"]}
    g = jax.grad(total)(p)
    np.testing.assert_array_equal(np.asarray(g["w"]), 0.0)
    assert float(jnp.sum(jnp.abs(g["lora"].a))) > 0.0


def test_dense_batched_norms_match_vmap_oracle():
    """Per-example LoRA factors (the gathered multi-tenant form):
    Engine norms == vmap-of-single-example oracle."""
    B = 7
    ks = jax.random.split(KEY, B)
    adapters = {"site": lora_mod.LoraPair(
        jnp.stack([_init_fn(k)["site"].a for k in ks]),
        jnp.stack([_init_fn(k)["site"].b for k in ks]), ALPHA)}
    batch, _ = _mixed_batch(n_tenants=B, max_per=1)
    eng = Engine(PexSpec())
    res = eng.step(_loss_fn, adapters, batch, [pex.Norms()])

    def single(a, ex):
        lv, _ = _loss_fn(
            jax.tree_util.tree_map(lambda l: l[None], a),
            jax.tree_util.tree_map(lambda l: l[None], ex), NULL)
        return lv[0]

    def sq(a, ex):
        g = jax.grad(single)(a, ex)
        return sum(jnp.sum(jnp.square(l))
                   for l in jax.tree_util.tree_leaves(g))

    want = jax.vmap(sq)(adapters, batch)
    np.testing.assert_allclose(np.asarray(res.sq_norms).sum(axis=1),
                               np.asarray(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# the acceptance bar: fused mixed-tenant step == per-tenant oracle loop
# ---------------------------------------------------------------------------

def _oracle_consumers(granularity, t, rng):
    clip = pex.Clip(C, granularity=granularity)
    noise = pex.Noise(SIGMA, jax.random.fold_in(rng, int(t)), scale=C)
    return [clip, noise]


@pytest.mark.parametrize("granularity", ["example", "token"])
@pytest.mark.parametrize("use_mesh", [False, True],
                         ids=["local", "shard_map"])
def test_service_matches_per_tenant_oracle(granularity, use_mesh):
    batch, owner = _mixed_batch(n_tenants=110, seed=7)
    rng = jax.random.PRNGKey(99)
    mesh = _mesh() if use_mesh else None

    store = AdapterStore(_init_fn, capacity=128,
                         key=jax.random.fold_in(KEY, 7))
    svc = TenantService(store, _loss_fn, clip_norm=C, noise_std=SIGMA,
                        noise_scale=C, lr=LR, mesh=mesh,
                        granularity=granularity)
    res = svc.step(batch, owner, rng=rng)
    assert res.tenant_ids.shape == (110,)
    np.testing.assert_array_equal(
        np.asarray(res.tenant_count),
        np.unique(owner, return_counts=True)[1])

    tb = assemble(batch, owner)
    oracle = Engine(PexSpec(), granularity=granularity)
    for t in tb.unique_tenants:
        idx = jnp.asarray(np.flatnonzero(owner == t))
        bt = jax.tree_util.tree_map(
            lambda v: jnp.take(v, idx, axis=0), batch)
        at = _init_fn(jax.random.fold_in(store.key, int(t)))
        r_t = oracle.step(_loss_fn, at, bt,
                          _oracle_consumers(granularity, t, rng))
        pos = np.flatnonzero(np.asarray(tb.tenant_ids) == t)
        got_n = np.asarray(res.sq_norms)[pos]
        want_n = np.asarray(r_t.sq_norms)
        np.testing.assert_allclose(got_n.sum(axis=1), want_n.sum(axis=1),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(res.clip_coef)[pos],
                                   np.asarray(r_t.clip_coef),
                                   rtol=1e-5, atol=1e-6)
        # noised per-tenant update: the store row must equal the
        # single-tenant SGD step (per-tenant DP independent of batchmates)
        new_t = jax.tree_util.tree_map(lambda a, g: a - LR * g, at,
                                       r_t.grads)
        got = store.gather(np.array([t]))
        np.testing.assert_allclose(np.asarray(got["site"].a[0]),
                                   np.asarray(new_t["site"].a),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got["site"].b[0]),
                                   np.asarray(new_t["site"].b),
                                   rtol=1e-5, atol=1e-6)
        ti = int(np.flatnonzero(res.tenant_ids == t)[0])
        np.testing.assert_allclose(np.asarray(res.tenant_loss)[ti],
                                   float(jnp.sum(r_t.loss_vec)),
                                   rtol=1e-5)


def test_segmented_noise_bitexact_vs_fold_in():
    """``add_grad_noise_segmented`` is BIT-identical to running
    ``add_grad_noise`` per tenant on ``fold_in(rng, tenant_id)``."""
    rng = jax.random.PRNGKey(5)
    tenants = np.array([3, 11, 12, 907], dtype=np.int64)
    tree = {"a": jax.random.normal(KEY, (len(tenants), D, R)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1),
                                   (len(tenants), R, O))}
    noised = passes.add_grad_noise_segmented(
        tree, SIGMA, C, rng, jnp.asarray(tenants, dtype=jnp.int32))
    for i, t in enumerate(tenants):
        row = {k: v[i][None] for k, v in tree.items()}
        want = passes.add_grad_noise(row, SIGMA, C,
                                     jax.random.fold_in(rng, int(t)))
        for k in tree:
            assert np.array_equal(np.asarray(noised[k][i]),
                                  np.asarray(want[k][0])), k


def test_token_granularity_requires_explicit_noise_scale():
    store = AdapterStore(_init_fn, capacity=4, key=KEY)
    svc = TenantService(store, _loss_fn, clip_norm=C, noise_std=SIGMA,
                        granularity="token")
    batch, owner = _mixed_batch(n_tenants=2, max_per=1)
    with pytest.raises(ValueError, match="noise_scale"):
        svc.step(batch, owner, rng=KEY)


def test_noise_requires_rng():
    store = AdapterStore(_init_fn, capacity=4, key=KEY)
    svc = TenantService(store, _loss_fn, clip_norm=C, noise_std=SIGMA)
    batch, owner = _mixed_batch(n_tenants=2, max_per=1)
    with pytest.raises(ValueError, match="rng"):
        svc.step(batch, owner)


# ---------------------------------------------------------------------------
# ragged-segment fuzz: thousands of tenants, degenerate shapes, kernel
# parity (Pallas runs in interpret mode on CPU — tier-1 safe)
# ---------------------------------------------------------------------------

def _vmap_sq_norms(adapters_per_ex, batch):
    def single(a, ex):
        lv, _ = _loss_fn(
            jax.tree_util.tree_map(lambda l: l[None], a),
            jax.tree_util.tree_map(lambda l: l[None], ex), NULL)
        return lv[0]

    def sq(a, ex):
        g = jax.grad(single)(a, ex)
        return sum(jnp.sum(jnp.square(l))
                   for l in jax.tree_util.tree_leaves(g))

    return jax.vmap(sq)(adapters_per_ex, batch)


@pytest.mark.parametrize("n_tenants,max_per,seed", [
    (1, 6, 0),        # single tenant owns the whole batch
    (33, 1, 1),       # tenant count == batch size (all singletons)
    (300, 3, 2),      # ragged mid-scale
    (2048, 1, 3),     # thousands of tenants in one batch
])
def test_fuzz_ragged_segments(n_tenants, max_per, seed):
    batch, owner = _mixed_batch(n_tenants=n_tenants, max_per=max_per,
                                seed=seed)
    rng = jax.random.PRNGKey(seed)
    store = AdapterStore(_init_fn, capacity=max(4, n_tenants),
                         key=jax.random.fold_in(KEY, seed))
    svc = TenantService(store, _loss_fn, clip_norm=C, noise_std=0.0,
                        lr=LR)
    res = svc.step(batch, owner, rng=rng, apply_updates=False)
    tb = assemble(batch, owner)
    per_ex = jax.tree_util.tree_map(
        lambda v: jnp.take(v, tb.tenant_index, axis=0),
        store.gather(tb.unique_tenants))
    sorted_batch = {k: v for k, v in tb.batch.items()
                    if k != "tenant_index"}
    want = _vmap_sq_norms(per_ex, sorted_batch)
    np.testing.assert_allclose(np.asarray(res.sq_norms).sum(axis=1),
                               np.asarray(want), rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("seg_method", ["xla", "pallas"])
def test_fuzz_kernel_parity(seg_method):
    """Pinned segmented backends agree with each other and with the
    numpy-level vmap oracle (Pallas interprets on CPU)."""
    batch, owner = _mixed_batch(n_tenants=64, max_per=3, seed=11)
    store = AdapterStore(_init_fn, capacity=64,
                         key=jax.random.fold_in(KEY, 4))
    spec = PexSpec(use_pallas=(seg_method == "pallas"),
                   seg_method=seg_method)
    svc = TenantService(store, _loss_fn, clip_norm=C, spec=spec, lr=LR)
    res = svc.step(batch, owner, apply_updates=False)
    tb = assemble(batch, owner)
    per_ex = jax.tree_util.tree_map(
        lambda v: jnp.take(v, tb.tenant_index, axis=0),
        store.gather(tb.unique_tenants))
    want = _vmap_sq_norms(per_ex, {k: v for k, v in tb.batch.items()
                                   if k != "tenant_index"})
    np.testing.assert_allclose(np.asarray(res.sq_norms).sum(axis=1),
                               np.asarray(want), rtol=2e-4, atol=1e-5)


def test_assemble_rejects_bad_ids():
    batch, owner = _mixed_batch(n_tenants=3, max_per=1)
    with pytest.raises(ValueError):
        assemble(batch, np.array([-1] * len(owner)))
    with pytest.raises(ValueError):
        assemble(batch, owner[None])  # 2-D ids
    with pytest.raises(ValueError):
        assemble({"tenant_index": batch["x"]}, owner)


def test_per_tenant_reductions():
    idx = jnp.asarray([0, 0, 1, 2, 2, 2], dtype=jnp.int32)
    v = jnp.asarray([1.0, 2.0, 5.0, 1.0, 2.0, 3.0])
    np.testing.assert_array_equal(np.asarray(per_tenant_sum(v, idx, 3)),
                                  [3.0, 5.0, 6.0])
    np.testing.assert_array_equal(np.asarray(per_tenant_count(idx, 3)),
                                  [2, 1, 3])


# ---------------------------------------------------------------------------
# admission / eviction / checkpoint renumbering
# ---------------------------------------------------------------------------

def test_store_slot_recycling_and_deterministic_readmission():
    store = AdapterStore(_init_fn, capacity=3, key=KEY)
    s5, s9 = store.admit(5), store.admit(9)
    assert store.admit(5) == s5  # idempotent
    first = tree_digest(store.gather([5]))
    store.admit(2)
    with pytest.raises(RuntimeError, match="full"):
        store.admit(77)
    store.evict(9)
    assert store.admit(77) == s9  # slot recycled
    # freed rows are zeroed, never leak into a later admission
    store.evict(77)
    store.admit(5)  # still resident; no-op
    store.evict(5)
    store.admit(5)
    assert tree_digest(store.gather([5])) == first  # bit-identical re-init


def test_service_pending_queue_head_first():
    store = AdapterStore(_init_fn, capacity=2, key=KEY)
    svc = TenantService(store, _loss_fn, clip_norm=C)
    svc.submit(10, 11, 12)
    assert svc.admit_pending() == [10, 11]
    assert svc.pending == [12]
    svc.evict(10)
    assert svc.admit_pending() == [12]


def test_ckpt_renumbering_is_bitexact(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    store = AdapterStore(_init_fn, capacity=8,
                         key=jax.random.fold_in(KEY, 13))
    svc = TenantService(store, _loss_fn, clip_norm=C, lr=LR,
                        ckpt_manager=mgr)
    batch, owner = _mixed_batch(n_tenants=5, max_per=2, seed=21)
    svc.step(batch, owner)  # trained state, not just init
    tenants = [int(t) for t in store.tenants]
    digests = {t: tree_digest(store.gather([t])) for t in tenants}
    svc.save(step=1)

    # scramble residency: evict some, admit others (slots renumber)
    store.evict(tenants[0])
    store.evict(tenants[2])
    store.admit(999_001)
    store.admit(999_002)

    restored = svc.restore()
    assert sorted(restored) == sorted(tenants)
    for t in tenants:
        assert tree_digest(store.gather([t])) == digests[t], t
    # survivors repacked into slots [0..n) in tenant-id order
    assert [int(t) for t in store.slots[:len(tenants)]] == sorted(tenants)
    assert all(s == -1 for s in store.slots[len(tenants):])


def test_restore_into_too_small_store_raises(tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    big = AdapterStore(_init_fn, capacity=4, key=KEY)
    for t in (1, 2, 3):
        big.admit(t)
    big.save(mgr, 0)
    small = AdapterStore(_init_fn, capacity=2, key=KEY)
    with pytest.raises(ValueError, match="slots"):
        small.restore(mgr)


# ---------------------------------------------------------------------------
# static analysis: coverage classifies frozen bases; privacy accepts the
# per-tenant Noise plan and understands literal fold_in lineage
# ---------------------------------------------------------------------------

def _toy_setup(n_tenants=6):
    batch, owner = _mixed_batch(n_tenants=n_tenants, max_per=2, seed=9)
    tb = assemble(batch, owner)
    store = AdapterStore(_init_fn, capacity=n_tenants,
                         key=jax.random.fold_in(KEY, 2))
    for t in tb.unique_tenants:
        store.admit(int(t))
    svc = TenantService(store, _loss_fn, clip_norm=C, noise_std=SIGMA,
                        noise_scale=C)
    return svc, tb, store.gather(tb.unique_tenants)


def test_coverage_classifies_frozen_base():
    svc, tb, active = _toy_setup()

    def loss_with_base(p, eb, tap):
        idx = eb["tenant_index"]
        per_ex = jax.tree_util.tree_map(
            lambda v: jnp.take(v, idx, axis=0), p["adapters"])
        z = linear({"w": p["base"], "lora": per_ex["site"]}, eb["x"],
                   tap=tap, group="all")
        tok = jnp.sum(jnp.square(z - eb["y"]), axis=-1)
        return jnp.sum(tap.token_loss(tok), axis=1), {}

    params = {"base": W_BASE, "adapters": active}
    rep = cov.trace_coverage(loss_with_base, params, tb.batch)
    assert rep.ok, rep.summary()
    by_status = {}
    for leaf in rep.leaves:
        by_status.setdefault(leaf.status, []).append(leaf.path)
    assert any("base" in p for p in by_status.get(cov.FROZEN, ())), \
        rep.summary()
    assert len(by_status.get(cov.TAPPED, ())) == 2  # the two factors


def test_privacy_accepts_per_tenant_noise_plan():
    svc, tb, active = _toy_setup()
    rng = jax.random.PRNGKey(3)
    rep = priv.check_step(svc._closure(), active, tb.batch,
                          svc.consumers(tb, rng))
    assert rep.ok, rep.summary()
    n_leaves = len(rep.leaves)
    noise = [m for m in rep.marks if m.tag == "noise"]
    assert len(noise) == n_leaves  # noise-exactly-once per leaf
    for leaf in rep.leaves:
        assert len(leaf.noise_tokens) == 1


def test_privacy_fold_in_literal_lineage():
    """Two fold_in calls with the SAME literal resolve to the same
    origin (true key reuse — detectable); different literals stay
    distinct (the per-tenant derivation pattern)."""
    def f(k):
        return (jax.random.fold_in(k, 7), jax.random.fold_in(k, 7),
                jax.random.fold_in(k, 8))

    jaxpr = jax.make_jaxpr(f)(jax.random.PRNGKey(0)).jaxpr
    producer = {}
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            if type(ov).__name__ != "DropVar":
                producer[ov] = eqn
    o = [priv._origin(v, producer, []) for v in jaxpr.outvars]
    assert o[0] == o[1], "identical literal folds must collide"
    assert o[0] != o[2], "distinct literal folds must stay distinct"
    # the fold datum is recorded on the slice-path
    assert ("fold", 7) in o[0][1] and ("fold", 8) in o[2][1]


# ---------------------------------------------------------------------------
# the one-fused-pass budget on the service step
# ---------------------------------------------------------------------------

def test_service_step_fits_one_forward_budget():
    from repro.analysis.plan_invariants import assert_backward_budget
    svc, tb, active = _toy_setup(n_tenants=8)
    rng = jax.random.PRNGKey(4)
    assert_backward_budget(svc._closure(), active, tb.batch,
                           svc.consumers(tb, rng), engine=svc.engine)


def test_transformer_lora_verify_deep():
    """The LoRA-fied transformer passes the full static verifier
    (coverage incl. frozen bases, privacy with Clip+Noise, determinism)
    and its per-example norms match the stop_gradient-aware oracle."""
    from repro.models import registry
    from tests.helpers import oracle_sq_norms, scope_filter, smoke_setup
    arch = "llama3.2-1b"
    aspec, cfg, mod, params, batch = smoke_setup(
        arch, cfg_edit=lambda c: dataclasses.replace(
            c, lora=lora_mod.LoraCfg(rank=2, alpha=4.0)))
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    eng = Engine(PexSpec(enabled=True), clip_norm=1.0)
    rep = eng.verify(loss_fn, params, batch,
                     [pex.Clip(1.0), pex.Noise(0.5, jax.random.PRNGKey(1))],
                     allow=registry.untapped_allowlist(arch))
    assert rep.ok, rep.summary()

    res = eng.step(loss_fn, params, batch, [pex.Norms()])
    want = oracle_sq_norms(aspec, cfg, params, batch, scope_filter(arch))
    np.testing.assert_allclose(np.asarray(res.sq_norms).sum(axis=1),
                               np.asarray(want), rtol=2e-4)
