"""Core machinery vs the paper-§3 naive oracle: every estimator, every
tap op, under jit / scan / remat, plus both clipping forms — all
through the v2 Tap collector (the v1 explicit-acc surface is gone)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clipping, naive
from repro.core.engine import Engine
from repro.core.taps import DISABLED, NULL, PexSpec, Tap


def _toy(B=4, S=6, D=8, H=10, V=12, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * 0.3,
        "w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * 0.3,
        "b1": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.1,
        "g": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.5 + 1.0,
        "w2": jnp.asarray(rng.normal(size=(H, V)), jnp.float32) * 0.3,
    }
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}

    def loss_fn(p, b, tap):
        h = tap.embedding(p["emb"], b["ids"])
        z = tap.dense(h, p["w1"])
        z = tap.bias_add(z, p["b1"])
        h = jax.nn.gelu(z)
        h = tap.scale(h, p["g"])
        logits = tap.dense(h, p["w2"])
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
        return -jnp.sum(ll, axis=-1), {}

    return params, batch, loss_fn


def _oracle(params, batch, loss_fn):
    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]
    return naive.per_example_sq_norms(single, params, batch)


@pytest.mark.parametrize("method", ["gram", "direct", "auto"])
def test_sequence_methods_exact(method):
    params, batch, loss_fn = _toy()
    res = Engine(PexSpec(enabled=True, method=method)).value_and_norms(
        loss_fn, params, batch)
    oracle = _oracle(params, batch, loss_fn)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1), oracle, rtol=2e-5)


def test_gram_pallas_matches():
    params, batch, loss_fn = _toy()
    res = Engine(PexSpec(enabled=True, method="gram",
                         use_pallas=True)).value_and_norms(
        loss_fn, params, batch)
    oracle = _oracle(params, batch, loss_fn)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1), oracle, rtol=2e-5)


def test_factorized_exact_for_mlp():
    """Paper §4 verbatim is exact in the paper's (rank-1 / MLP) setting."""
    rng = np.random.default_rng(1)
    B, D, H, O = 5, 7, 9, 4
    params = {"w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * 0.4,
              "w2": jnp.asarray(rng.normal(size=(H, O)), jnp.float32) * 0.4}
    batch = {"x": jnp.asarray(rng.normal(size=(B, D)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(B, O)), jnp.float32)}

    def loss_fn(p, b, tap):
        z = tap.dense(b["x"], p["w1"])
        z2 = tap.dense(jnp.tanh(z), p["w2"])
        return jnp.sum(jnp.square(z2 - b["y"]), -1), {}

    res = Engine(PexSpec(enabled=True,
                         method="factorized")).value_and_norms(
        loss_fn, params, batch)
    oracle = _oracle(params, batch, loss_fn)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1), oracle, rtol=2e-5)


def test_single_pass_grads_match_plain():
    params, batch, loss_fn = _toy()
    res = Engine(PexSpec(enabled=True, method="gram")).value_grads_and_norms(
        loss_fn, params, batch)

    def total(p):
        return jnp.sum(loss_fn(p, batch, NULL)[0])

    g = jax.grad(total)(params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], g[k], rtol=1e-5, atol=1e-6)


def test_twopass_clipping_matches_naive():
    params, batch, loss_fn = _toy()
    clip = 0.5
    res = Engine(PexSpec(enabled=True, method="gram"),
                 clip_norm=clip).clipped_step(loss_fn, params, batch)
    oracle = _oracle(params, batch, loss_fn)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]

    pex_g = naive.per_example_grads(single, params, batch)
    c = jnp.minimum(1.0, clip / (jnp.sqrt(oracle) + 1e-6))
    for k in params:
        want = jnp.einsum("b,b...->...", c, pex_g[k])
        np.testing.assert_allclose(res.grads[k], want, rtol=1e-4, atol=1e-6)


def test_onepass_paper_s6():
    """§6 one-pass: rescale Z̄, recompute W̄' = XᵀZ̄' only."""
    rng = np.random.default_rng(1)
    B, D, H, O = 5, 7, 9, 4
    params = {"w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * 0.4,
              "w2": jnp.asarray(rng.normal(size=(H, O)), jnp.float32) * 0.4}
    batch = {"x": jnp.asarray(rng.normal(size=(B, D)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(B, O)), jnp.float32)}

    def forward(p, tp, b):
        hs = {"w1": b["x"]}
        z1 = b["x"] @ p["w1"] + tp["w1"]
        h1 = jnp.tanh(z1)
        hs["w2"] = h1
        z2 = h1 @ p["w2"] + tp["w2"]
        return jnp.sum(jnp.square(z2 - b["y"]), -1), hs

    shapes = {"w1": (B, H), "w2": (B, O)}
    _, sq, wbar = clipping.onepass_clipped_weight_grads(
        forward, params, batch, shapes, clip_norm=0.7)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda v: v[None], ex)
        tz = {k: jnp.zeros((1,) + s[1:]) for k, s in shapes.items()}
        return forward(p, tz, b1)[0][0]

    oracle = naive.per_example_sq_norms(single, params, batch)
    np.testing.assert_allclose(sq, oracle, rtol=1e-5)
    pex_g = naive.per_example_grads(single, params, batch)
    c = jnp.minimum(1.0, 0.7 / (jnp.sqrt(oracle) + 1e-6))
    for k in params:
        want = jnp.einsum("b,b...->...", c, pex_g[k])
        np.testing.assert_allclose(wbar[k], want, rtol=1e-4, atol=1e-6)


def test_under_jit_scan_remat():
    from repro import pex as pexns
    rng = np.random.default_rng(2)
    B, S, D, V = 4, 6, 8, 12
    params = {"emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * .3,
              "ws": jnp.asarray(rng.normal(size=(3, D, D)), jnp.float32) * .3,
              "wo": jnp.asarray(rng.normal(size=(D, V)), jnp.float32) * .3}
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}

    def loss_fn(p, b, tap):
        h = tap.embedding(p["emb"], b["ids"])

        def blk(h, w):
            z = tap.dense(h, w)
            return jnp.tanh(z) + h, None

        h, _ = pexns.scan(blk, h, p["ws"], tap=tap, remat=True)
        logits = tap.dense(h, p["wo"])
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
        return -jnp.sum(ll, -1), {}

    eng = Engine(PexSpec(enabled=True, method="gram"))

    @jax.jit
    def run(p, b):
        return eng.value_and_norms(loss_fn, p, b).sq_norms

    ours = jnp.sum(run(params, batch), -1)
    oracle = _oracle(params, batch, loss_fn)
    np.testing.assert_allclose(ours, oracle, rtol=2e-5)


def test_disabled_spec_is_plain():
    params, batch, loss_fn = _toy()
    lv, aux = loss_fn(params, batch, Tap(DISABLED))
    assert lv.shape == (4,)
    res = Engine(DISABLED).value_and_norms(loss_fn, params, batch)
    np.testing.assert_array_equal(res.sq_norms, jnp.zeros((4, 1)))


def test_norm_only_pass_value_matches():
    params, batch, loss_fn = _toy()
    res = Engine(PexSpec(enabled=True, method="gram")).value_and_norms(
        loss_fn, params, batch)
    lv, _ = loss_fn(params, batch, NULL)
    np.testing.assert_allclose(res.loss, jnp.sum(lv), rtol=1e-6)
    np.testing.assert_allclose(res.loss_vec, lv, rtol=1e-6)


def test_onepass_s6_sequence_model():
    """§6 one-pass on a weight-shared (sequence) model: Gram norms +
    W̄' = XᵀZ̄' match the naive per-example clip exactly."""
    rng = np.random.default_rng(3)
    B, S, D, H = 4, 6, 8, 10
    params = {"w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * .4,
              "w2": jnp.asarray(rng.normal(size=(H, D)), jnp.float32) * .4}
    batch = {"x": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)}

    def forward(p, tp, b):
        hs = {"w1": b["x"]}
        z1 = b["x"] @ p["w1"] + tp["w1"]
        h1 = jnp.tanh(z1)
        hs["w2"] = h1
        z2 = h1 @ p["w2"] + tp["w2"]
        lv = jnp.sum(jnp.square(z2 - b["y"]), axis=(1, 2))
        return lv, hs

    shapes = {"w1": (B, S, H), "w2": (B, S, D)}
    _, sq, wbar = clipping.onepass_clipped_weight_grads_seq(
        forward, params, batch, shapes, clip_norm=0.9)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda v: v[None], ex)
        tz = {k: jnp.zeros((1,) + s[1:]) for k, s in shapes.items()}
        return forward(p, tz, b1)[0][0]

    oracle = naive.per_example_sq_norms(single, params, batch)
    np.testing.assert_allclose(sq, oracle, rtol=1e-5)
    pg = naive.per_example_grads(single, params, batch)
    c = jnp.minimum(1.0, 0.9 / (jnp.sqrt(oracle) + 1e-6))
    for k in params:
        want = jnp.einsum("b,b...->...", c, pg[k])
        np.testing.assert_allclose(wbar[k], want, rtol=1e-4, atol=1e-6)


def test_per_group_norm_columns():
    """acc columns split per group and sum to the total."""
    spec_g = PexSpec(enabled=True, method="gram",
                     groups=("embed", "dense", "norm"))
    rng = np.random.default_rng(5)
    B, S, D, V = 3, 5, 6, 8
    params = {"emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * .3,
              "w": jnp.asarray(rng.normal(size=(D, V)), jnp.float32) * .3,
              "g": jnp.ones((D,), jnp.float32)}
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}

    def loss_fn(p, b, tap):
        h = tap.embedding(p["emb"], b["ids"], group="embed")
        h = tap.scale(h, p["g"], group="norm")
        logits = tap.dense(h, p["w"], group="dense")
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
        return -jnp.sum(ll, -1), {}

    res = Engine(spec_g).value_and_norms(loss_fn, params, batch)
    assert res.sq_norms.shape == (B, 3)

    # column-wise oracle via param filters
    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]
    for col, key in [(0, "emb"), (1, "w"), (2, "g")]:
        want = naive.per_example_sq_norms(
            single, params, batch, lambda path, k=key: f"'{k}'" in str(path))
        np.testing.assert_allclose(res.sq_norms[:, col], want, rtol=1e-4)


def test_per_token_norms_exact():
    """Per-token §4 via the unified tap registry (TokenLayout):
    s_{j,t} = ||h_t||²||z̄_t||² exactly equals the Frobenius norm of
    token t's rank-1 gradient contribution, and the contributions
    reconstruct the full dW."""
    rng = np.random.default_rng(9)
    B, S, D, H = 3, 7, 6, 10
    params = {"w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * .4,
              "w2": jnp.asarray(rng.normal(size=(H, D)), jnp.float32) * .4}
    batch = {"x": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)}

    def loss_fn(p, b, tap):
        z1 = tap.dense(b["x"], p["w1"])
        h1 = jnp.tanh(z1)
        z2 = tap.dense(h1, p["w2"])
        return jnp.sum(jnp.square(z2 - b["y"]), axis=(1, 2)), {}

    eng = Engine(PexSpec(enabled=True, method="factorized"),
                 granularity="token")
    res = eng.value_and_norms(loss_fn, params, batch)
    assert res.sq_norms.shape == (B, S)

    # oracle: materialize z̄ via perturbation taps
    def f(tp):
        z1 = batch["x"] @ params["w1"] + tp["t1"]
        h1 = jnp.tanh(z1)
        z2 = h1 @ params["w2"] + tp["t2"]
        return jnp.sum(jnp.square(z2 - batch["y"])), h1

    taps0 = {"t1": jnp.zeros((B, S, H)), "t2": jnp.zeros((B, S, D))}
    total, vjp, h1 = jax.vjp(f, taps0, has_aux=True)
    (zb,) = vjp(jnp.ones(()))
    want = (np.sum(np.square(np.asarray(batch["x"])), -1) *
            np.sum(np.square(np.asarray(zb["t1"])), -1) +
            np.sum(np.square(np.asarray(h1)), -1) *
            np.sum(np.square(np.asarray(zb["t2"])), -1))
    np.testing.assert_allclose(res.sq_norms, want, rtol=1e-5)

    # rank-1 reconstruction: Σ_{j,t} h z̄ᵀ == dW
    dw1 = jnp.einsum("bsi,bso->io", batch["x"], zb["t1"])
    g = jax.grad(lambda p: jnp.sum(loss_fn(p, batch, NULL)[0]))(params)
    np.testing.assert_allclose(dw1, g["w1"], rtol=1e-5)
