"""The zero-overhead claim, pinned by HLO cost: an instrumented model
whose taps are disabled — or enabled but with norms not requested —
must lower to the same flop/byte cost as the plain model, so the DCE
property (taps docstring / DESIGN.md §1) can't silently regress."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.taps import DISABLED, ExampleLayout, PexSpec, Tap, NULL
from repro.models import registry
from repro.roofline.hlo import compiled_cost


def _setup():
    from repro.configs.common import ShapeSpec
    from repro.nn.param import unbox
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("dce", "train", 8, 2))
    loss_v2 = registry.make_loss_fn_v2(aspec, cfg)
    return params, batch, loss_v2


def _grad_cost(loss_v2, params, batch, spec):
    """Compile grad-wrt-params of the (possibly instrumented) total
    loss; the accumulator gradient is never requested."""
    def total(p):
        if spec is None:
            lv, _ = loss_v2(p, batch, NULL)
        else:
            tap = Tap(spec, acc=ExampleLayout(spec.n_groups).init(
                batch["ids"].shape[0]))
            lv, _ = loss_v2(p, batch, tap)
        return jnp.sum(lv)

    compiled = jax.jit(jax.grad(total)).lower(params).compile()
    return compiled_cost(compiled)


def test_disabled_spec_compiles_to_plain_model():
    params, batch, loss_v2 = _setup()
    flops_plain, bytes_plain = _grad_cost(loss_v2, params, batch, None)
    flops_off, bytes_off = _grad_cost(loss_v2, params, batch, DISABLED)
    assert flops_off == pytest.approx(flops_plain, rel=1e-6)
    assert bytes_off == pytest.approx(bytes_plain, rel=1e-6)


def test_unrequested_norms_are_dce_dead():
    """Taps ENABLED, but grad taken w.r.t. params only: every stat
    chain must be dead code — no flop/byte cost over the plain model.
    (The instrumented program may lower marginally *cheaper*: the
    custom_vjp backward rules emit slightly different HLO under remat
    than autodiff transpose of the plain einsum; what must never
    appear is the O(B·S²)/O(mnp) stat work.)"""
    params, batch, loss_v2 = _setup()
    flops_plain, bytes_plain = _grad_cost(loss_v2, params, batch, None)
    flops_on, bytes_on = _grad_cost(loss_v2, params, batch,
                                    PexSpec(enabled=True, method="gram"))
    assert flops_on <= flops_plain * (1 + 1e-6)
    assert bytes_on <= bytes_plain * (1 + 1e-6)
