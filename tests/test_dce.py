"""The zero-overhead claim, pinned by HLO cost: an instrumented model
whose taps are disabled — or enabled but with norms not requested —
must lower to the same flop/byte cost as the plain model, so the DCE
property (taps docstring / DESIGN.md §1) can't silently regress.

The assertions live in ``repro.analysis.plan_invariants`` (pexlint
pass 2) — this file pins them on a real arch; the benches reuse the
same ``check_*`` arithmetic on their own measurements.
"""
import jax

from repro.analysis import plan_invariants as pi
from repro.core.taps import PexSpec
from repro.models import registry


def _setup():
    from repro.configs.common import ShapeSpec
    from repro.nn.param import unbox
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("dce", "train", 8, 2))
    loss_v2 = registry.make_loss_fn_v2(aspec, cfg)
    return params, batch, loss_v2


def test_disabled_spec_compiles_to_plain_model():
    params, batch, loss_v2 = _setup()
    pi.assert_disabled_spec_is_plain(loss_v2, params, batch)


def test_unrequested_norms_are_dce_dead():
    """Taps ENABLED, but grad taken w.r.t. params only: every stat
    chain must be dead code — no flop/byte cost over the plain model.
    (The instrumented program may lower marginally *cheaper*: the
    custom_vjp backward rules emit slightly different HLO under remat
    than autodiff transpose of the plain einsum; what must never
    appear is the O(B·S²)/O(mnp) stat work.)"""
    params, batch, loss_v2 = _setup()
    pi.assert_unrequested_norms_dce(
        loss_v2, params, batch, spec=PexSpec(enabled=True, method="gram"))


def test_empty_plan_is_plain_forward():
    """step([]) lowers to exactly the plain forward — promoted from
    benchmarks/bench_plan.py so the invariant is pinned in tier 1."""
    params, batch, loss_v2 = _setup()
    pi.assert_empty_plan_is_plain(loss_v2, params, batch)


def test_clip_plan_fits_backward_budget():
    """The Clip plan fits cost(norms) + (cost(grad) − cost(fwd)) —
    one tapped forward, one activation backward, ONE reweighted
    backward; a second forward would blow the budget."""
    from repro import pex
    params, batch, loss_v2 = _setup()
    pi.assert_backward_budget(loss_v2, params, batch, [pex.Clip(1.0)])
