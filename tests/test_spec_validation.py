"""PexSpec construction-time validation: group patterns resolve by
first match, so duplicates and shadowed catch-alls must be rejected at
construction with the conflict named, not discovered as silently-merged
stat columns."""
import pytest

from repro.core.taps import PexSpec


def test_valid_specs_construct():
    PexSpec(enabled=True)
    PexSpec(enabled=True, groups=("attn", "mlp", "other"))
    PexSpec(enabled=True, groups=("embed", "all"))
    PexSpec(enabled=False)


def test_duplicate_group_rejected():
    with pytest.raises(ValueError, match="duplicate pex group"):
        PexSpec(enabled=True, groups=("attn", "mlp", "attn"))


def test_duplicate_error_names_columns():
    with pytest.raises(ValueError, match=r"'mlp' \(columns 0 and 2\)"):
        PexSpec(enabled=True, groups=("mlp", "attn", "mlp"))


def test_shadowing_catch_alls_rejected():
    with pytest.raises(ValueError, match="catch-all"):
        PexSpec(enabled=True, groups=("all", "other"))


def test_single_catch_all_ok():
    assert PexSpec(enabled=True, groups=("attn", "other")).n_groups == 2
