"""Serving: prefill+decode consistency vs full teacher-forced forward,
for every decode-capable arch (deliverable b/e substrate)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.common import ShapeSpec
from repro.models import registry
from repro.nn.param import unbox

ARCHS = ["llama3.2-1b", "qwen2-7b", "qwen2-vl-7b", "minitron-4b",
         "gemma2-9b", "deepseek-v2-236b", "phi3.5-moe", "zamba2-7b",
         "rwkv6-3b", "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    aspec = registry.get(arch)
    mod = registry.family_module(aspec)
    B, S = 2, 8
    cfg = registry.serving_config(aspec, aspec.smoke(),
                                  ShapeSpec("t", "decode", S, B))
    if getattr(cfg, "moe", None) is not None:
        # prefill+decode ≡ full forward only holds under drop-free
        # routing: with capacity drops, a token's slot depends on which
        # other tokens it is batched with (last-position tokens lose
        # slots to the full teacher-forced batch that they keep in the
        # 1-token decode step). Give every expert enough capacity that
        # nothing drops at this smoke scale.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    fwd = registry.make_forward_tokens(aspec, cfg)
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("t", "train", S, B))

    caches0 = mod.init_caches(B, cfg)
    full_logits, _ = mod.forward_tokens(
        params, batch, None if aspec.family == "transformer" else caches0,
        None if aspec.family == "transformer" else 0, cfg=cfg)

    caches = mod.init_caches(B, cfg)
    pre = {k: (v[:, :S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
           for k, v in batch.items()}
    if "positions" in batch:
        pre["positions"] = batch["positions"][:, :, :S - 1]
    if "src_frames" in batch:
        pre["src_frames"] = batch["src_frames"]   # encoder sees full source
    _, caches = fwd(params, pre, caches, 0)
    logits, _ = fwd(params, {"ids": batch["ids"][:, S - 1:S]}, caches, S - 1)

    # compare on the real vocab only (padded rows are -inf-masked)
    v = cfg.vocab
    a = np.asarray(full_logits[:, -1, :v], np.float32)
    b = np.asarray(logits[:, 0, :v], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["zamba2-7b", "rwkv6-3b"])
def test_ssm_decode_state_is_o1(arch):
    """The long-context archs carry O(1) recurrent state per token."""
    aspec = registry.get(arch)
    mod = registry.family_module(aspec)
    cfg = registry.serving_config(aspec, aspec.smoke(),
                                  ShapeSpec("t", "decode", 16, 2))
    caches = mod.init_caches(2, cfg)
    if arch == "rwkv6-3b":
        leaves = jax.tree_util.tree_leaves(caches)
        assert all(16 not in leaf.shape for leaf in leaves), \
            "rwkv cache must not scale with context"
