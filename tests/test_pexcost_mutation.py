"""Mutation corpus for the pexcost traffic pass (DESIGN.md §13): plant
each of the four cost bugs the pass exists to catch — an extra
full-gradient HBM stream, a duplicated forward, a dropped-residual
second linearization, a silent f32 upcast of a bf16 gradient tree —
through the same seams the pipeline resolves at call time
(``plan.run_fused``, ``optim.adamw.update``), and prove each mutant is
detected through the real ``Engine.verify(cost=True)`` entry point by
its OWN finding code (clean separation), while the unmutated trace
stays green (test_pexcost.py's sweep)."""
import jax
import jax.numpy as jnp
import pytest

from repro import pex
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.models import registry
from repro.optim import adamw

from tests.test_pexlint import abstract_setup

tree_map = jax.tree_util.tree_map


def _verify(loss_fn, params, batch, *, model="llama3.2-1b"):
    eng = Engine(PexSpec(enabled=True))
    return eng.verify(
        loss_fn, params, batch,
        [[pex.Clip(1.0), pex.Noise(0.1, jax.random.PRNGKey(0)),
          pex.GNS()]],
        allow=registry.untapped_allowlist(model), seq=8,
        deep=False, cost=True, model=model)


def _codes(rep):
    return {f.code for f in rep.findings}


def test_extra_gradient_stream_is_detected(monkeypatch):
    """A gradient-normalization pre-pass bolted onto the optimizer adds
    full-tree HBM streams beyond the structural expectation —
    redundant-hbm-stream must escalate to a hard ERROR (not the
    allowlisted 'expected today' report)."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    real_update = adamw.update

    def normalizing_update(cfg, state, p, grads):
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                          for g in jax.tree_util.tree_leaves(grads)))
        grads = tree_map(lambda g: g / (gn + 1e-6), grads)
        return real_update(cfg, state, p, grads)

    monkeypatch.setattr(adamw, "update", normalizing_update)
    rep = _verify(loss_fn, params, batch)
    assert not rep.ok
    assert "redundant-hbm-stream" in _codes(rep)
    (tr,) = rep.traffic
    assert tr.n_streams > tr.expected_streams


def test_duplicated_forward_is_detected(monkeypatch):
    """A plan layer that silently traces the forward twice (the classic
    pre-fusion clipped path) must trip duplicate-forward."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    real = plan_mod.run_fused

    def doubled(plan, acc_loss, params, batch, bs, layout, **kw):
        lv, aux, sq, grads, w, tw, cc = real(plan, acc_loss, params,
                                             batch, bs, layout, **kw)
        lv2, *_ = real(plan, acc_loss, params, batch, bs, layout, **kw)
        return lv + 0.0 * lv2, aux, sq, grads, w, tw, cc

    monkeypatch.setattr(plan_mod, "run_fused", doubled)
    rep = _verify(loss_fn, params, batch)
    assert not rep.ok
    assert "duplicate-forward" in _codes(rep)
    (tr,) = rep.traffic
    assert tr.forward_flops > 1.5 * tr.ref_forward_flops


def test_dropped_residual_sharing_is_detected(monkeypatch):
    """Taking the reweighted gradients from a SECOND independent
    linearization (instead of reusing the norms backward's residuals)
    must trip dead-residual."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    real = plan_mod.run_fused

    def relinearized(plan, acc_loss, params, batch, bs, layout, **kw):
        lv, aux, sq, _, w, tw, cc = real(plan, acc_loss, params,
                                         batch, bs, layout, **kw)
        _, _, _, grads, *_ = real(plan, acc_loss, params, batch, bs,
                                  layout, **kw)
        return lv, aux, sq, grads, w, tw, cc

    monkeypatch.setattr(plan_mod, "run_fused", relinearized)
    rep = _verify(loss_fn, params, batch)
    assert not rep.ok
    assert "dead-residual" in _codes(rep)
    (tr,) = rep.traffic
    assert tr.residual_sharing < 0.25


def test_silent_f32_upcast_is_detected(monkeypatch):
    """bf16 gradient trees silently materialized as f32 copies before
    the optimizer read must trip upcast-materialization — and ONLY it
    (the stream count stays at the structural expectation)."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    bf16_params = tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, params)
    real_update = adamw.update

    def upcasting_update(cfg, state, p, grads):
        g32 = tree_map(lambda g: g.astype(jnp.float32), grads)
        return real_update(cfg, state, p, g32)

    monkeypatch.setattr(adamw, "update", upcasting_update)
    rep = _verify(loss_fn, bf16_params, batch)
    assert not rep.ok
    assert "upcast-materialization" in _codes(rep)
    (tr,) = rep.traffic
    assert tr.n_streams == tr.expected_streams   # clean separation


def test_bf16_params_alone_stay_clean():
    """The upcast detector keys on a *materialized* copy, not on mixed
    precision itself: the unmutated bf16 step is finding-free."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    bf16_params = tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        if s.dtype == jnp.float32 else s, params)
    rep = _verify(loss_fn, bf16_params, batch)
    assert rep.ok, rep.summary()
    assert not rep.findings


def test_mutants_fire_through_the_cli_gate(monkeypatch):
    """The same detection must reach the CLI exit code: a mutated
    pipeline under ``--cost --fail-on-error`` exits nonzero."""
    from repro.analysis.__main__ import main
    real = plan_mod.run_fused

    def doubled(plan, acc_loss, params, batch, bs, layout, **kw):
        lv, aux, sq, grads, w, tw, cc = real(plan, acc_loss, params,
                                             batch, bs, layout, **kw)
        lv2, *_ = real(plan, acc_loss, params, batch, bs, layout, **kw)
        return lv + 0.0 * lv2, aux, sq, grads, w, tw, cc

    monkeypatch.setattr(plan_mod, "run_fused", doubled)
    assert main(["--arch", "llama3.2-1b", "--fast", "--cost",
                 "--fail-on-error"]) == 1
