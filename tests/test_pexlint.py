"""pexlint passes on the real registry: tap coverage is clean on every
registered arch (zero false positives), the allowlist is load-bearing
(removing it flags the declared leaves), the analysis is trace-only,
and Engine.verify composes the passes."""
import jax
import pytest

from repro.analysis import coverage as cov
from repro.analysis.verify import verify as verify_model
from repro.configs.common import ShapeSpec
from repro.core.engine import Engine
from repro.core.taps import PexSpec, TokenLayout
from repro.models import registry
from repro.nn.param import unbox

ALL_ARCHS = sorted(registry.ARCHS)


def abstract_setup(arch_id, b=3, s=8):
    aspec = registry.get(arch_id)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = jax.eval_shape(
        lambda: unbox(mod.init(jax.random.PRNGKey(0), cfg)))
    batch = registry.train_batch_specs(aspec, cfg,
                                       ShapeSpec("lint", "train", s, b))
    return aspec, registry.make_loss_fn_v2(aspec, cfg), params, batch


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_clean_arch_has_no_coverage_errors(arch_id):
    _, loss_fn, params, batch = abstract_setup(arch_id)
    rep = cov.trace_coverage(loss_fn, params, batch,
                             allow=registry.untapped_allowlist(arch_id))
    assert rep.ok, rep.summary()
    c = rep.counts()
    assert c[cov.TAPPED] > 0
    assert len(rep.sites) > 0


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "phi3.5-moe",
                                     "rwkv6-3b", "seamless-m4t-medium"])
def test_token_layout_coverage_is_clean(arch_id):
    _, loss_fn, params, batch = abstract_setup(arch_id)
    spec = PexSpec(enabled=True)
    rep = cov.trace_coverage(loss_fn, params, batch, spec=spec,
                             layout=TokenLayout(8),
                             allow=registry.untapped_allowlist(arch_id))
    assert rep.ok, rep.summary()


@pytest.mark.parametrize("arch_id", sorted(registry.UNTAPPED_ALLOWLIST))
def test_allowlist_is_load_bearing(arch_id):
    """Without the declared allowlist the untapped-but-trained leaves
    must surface as errors — proving the pass detects them and the
    allowlist is the only thing keeping these archs green."""
    _, loss_fn, params, batch = abstract_setup(arch_id)
    rep = cov.trace_coverage(loss_fn, params, batch, allow=())
    assert not rep.ok
    allow = registry.untapped_allowlist(arch_id)
    for leaf in rep.errors:
        # leaf.path is the keystr form, e.g. ['blocks']['tmix']['mu']
        assert any(a in leaf.path for a in allow), (
            f"undeclared untapped leaf {leaf.path}")
    with pytest.raises(cov.AnalysisError):
        rep.raise_if_errors()


def test_coverage_is_trace_only():
    """The pass must never reach XLA compilation (abstract inputs and
    a blocked compile entry point)."""
    from jax._src import compiler
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    orig = compiler.backend_compile

    def blocked(*a, **kw):
        raise AssertionError("coverage pass triggered an XLA compile")

    compiler.backend_compile = blocked
    try:
        rep = cov.trace_coverage(loss_fn, params, batch)
    finally:
        compiler.backend_compile = orig
    assert rep.ok


def test_engine_verify_end_to_end():
    from repro import pex
    aspec, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    eng = Engine(PexSpec(enabled=True), clip_norm=1.0)
    key = jax.random.PRNGKey(1)
    rep = eng.verify(
        loss_fn, params, batch,
        [[], [pex.Norms()], [pex.Clip(1.0), pex.Noise(0.1, key),
                             pex.GNS()]],
        cfg=aspec.full())
    assert rep.ok, rep.summary()
    assert len(rep.plans) == 3
    assert rep.plans[0].n_backwards == 0
    assert rep.plans[1].n_backwards == 1
    assert rep.plans[2].n_backwards == 2
    rep.raise_if_errors()
    assert "backwards=2" in rep.plans[2].describe()


def test_engine_verify_rejects_invalid_plan():
    aspec, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    from repro import pex
    eng = Engine(PexSpec(enabled=True), granularity="token")
    with pytest.raises(NotImplementedError, match="GNS"):
        eng.verify(loss_fn, params, batch,
                   [pex.Clip(1.0, granularity="token"), pex.GNS()],
                   seq=8)


def test_verify_token_granularity():
    aspec, loss_fn, params, batch = abstract_setup("phi3.5-moe")
    from repro import pex
    key = jax.random.PRNGKey(0)
    rep = verify_model(
        loss_fn, params, batch,
        [[pex.Clip(1.0, granularity="token"),
          pex.Noise(0.1, key, scale=1.0)]],
        granularity="token", seq=8,
        allow=registry.untapped_allowlist("phi3.5-moe"))
    assert rep.ok, rep.summary()
    assert rep.plans[0].token_norms


def test_cli_main_single_arch():
    from repro.analysis.__main__ import main
    assert main(["--arch", "llama3.2-1b", "--fail-on-error"]) == 0
