"""pexlint v2 unit tests: the flow passes' report shapes, the CLI's
exit-code and JSON contracts, and the allowlist staleness check.

The mutation corpus (test_pexlint_mutation.py) proves detection; this
file pins the *interfaces* — a warnings-only run must exit 0 under
--fail-on-error (warnings are advisory), --json must be parseable and
carry every finding, stale allowlist entries must warn without
failing, and the new passes must stay trace-only.
"""
import json
import types

import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import pex
from repro.analysis import _jaxpr as _J
from repro.analysis import collectives as col
from repro.analysis import coverage as cov
from repro.analysis import determinism as det
from repro.analysis import privacy as priv
from repro.analysis.__main__ import main, resolve_exit
from repro.core import plan as plan_mod
from repro.models import registry

from tests.test_pexlint import abstract_setup

KEY = jax.random.PRNGKey(0)


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _dp_trace(mesh=None, consumers=None):
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    cs = consumers if consumers is not None \
        else [pex.Clip(1.0), pex.Noise(0.1, KEY), pex.GNS()]
    return _J.trace_step(loss_fn, params, batch, cs, mesh=mesh,
                         batch_size=3)


# ---------------------------------------------------------------------------
# exit codes (the satellite fix: warnings must not fail --fail-on-error)
# ---------------------------------------------------------------------------

def test_resolve_exit_matrix():
    # (n_err, n_warn, fail_on_error, fail_on_warn) -> exit
    assert resolve_exit(0, 0, True, False) == 0
    assert resolve_exit(0, 0, False, False) == 0
    assert resolve_exit(3, 0, True, False) == 1
    assert resolve_exit(3, 0, False, False) == 0
    # THE fix: warnings-only exits 0 under --fail-on-error ...
    assert resolve_exit(0, 5, True, False) == 0
    # ... and nonzero only under --fail-on-warn
    assert resolve_exit(0, 5, False, True) == 1
    assert resolve_exit(0, 5, True, True) == 1
    assert resolve_exit(3, 5, False, True) == 1


def test_warnings_only_run_exit_codes(monkeypatch):
    """An unregistered allowlist key is a WARNING: green under
    --fail-on-error, red under --fail-on-warn."""
    monkeypatch.setitem(registry.UNTAPPED_ALLOWLIST,
                        "no-such-arch", ("bogus",))
    args = ["--arch", "llama3.2-1b", "--fast"]
    assert main(args + ["--fail-on-error"]) == 0
    assert main(args + ["--fail-on-warn"]) == 1


def test_json_report(capsys):
    assert main(["--arch", "llama3.2-1b", "--json",
                 "--fail-on-error"]) == 0
    out = capsys.readouterr()
    doc = json.loads(out.out)
    assert doc["archs"] == ["llama3.2-1b"]
    assert doc["errors"] == 0
    assert isinstance(doc["findings"], list)
    for f in doc["findings"]:
        assert {"pass", "severity", "code", "message"} <= set(f)
    # human status lines moved off stdout
    assert "pexlint:" in out.err


# ---------------------------------------------------------------------------
# allowlist staleness (satellite: stale entries warn, never fail)
# ---------------------------------------------------------------------------

def test_stale_allow_entry_warns_but_passes():
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    rep = cov.trace_coverage(loss_fn, params, batch,
                             allow=("no/such/param",))
    assert rep.ok
    assert rep.stale_allow == ("no/such/param",)
    assert "WARNING" in rep.summary()


def test_live_allow_entries_are_not_stale():
    arch = sorted(registry.UNTAPPED_ALLOWLIST)[0]
    _, loss_fn, params, batch = abstract_setup(arch)
    rep = cov.trace_coverage(loss_fn, params, batch,
                             allow=registry.untapped_allowlist(arch))
    assert rep.ok
    assert rep.stale_allow == ()


# ---------------------------------------------------------------------------
# privacy report internals
# ---------------------------------------------------------------------------

def test_privacy_report_shape():
    rep = priv.analyze_trace(_dp_trace())
    assert rep.ok, rep.summary()
    n_leaves = len(rep.leaves)
    assert n_leaves > 0
    by_tag = {}
    for m in rep.marks:
        by_tag.setdefault(m.tag, []).append(m)
    # one clip marker; one noise + one rng_use marker per leaf
    assert len(by_tag["clip_coef"]) == 1
    assert len(by_tag["noise"]) == n_leaves
    assert len(by_tag["rng_use"]) == n_leaves
    # exactly-once: each leaf carries exactly one noise token
    for leaf in rep.leaves:
        assert len(leaf.noise_tokens) == 1
    assert "privacy:" in rep.summary()


def test_privacy_clean_without_noise():
    rep = priv.analyze_trace(_dp_trace(consumers=[pex.Clip(1.0)]))
    assert rep.ok, rep.summary()
    assert all(m.tag != "noise" for m in rep.marks)


# ---------------------------------------------------------------------------
# collectives: region classification and the DP×TP schedule
# ---------------------------------------------------------------------------

def test_collectives_region_shape():
    rep = col.analyze_trace(_dp_trace(mesh=_mesh()))
    assert rep.ok, rep.summary()
    assert len(rep.regions) == 1
    r = rep.regions[0]
    assert r.mesh_axes == ("data",)
    assert len(r.psums) == 1
    assert r.psums[0].axes == ("data",)
    per_ex = [o for o in r.outputs if o.sharded_over_data]
    repl = [o for o in r.outputs if not o.sharded_over_data]
    assert per_ex and repl
    assert all(o.data_psums == 0 for o in per_ex)
    assert all(o.data_psums == 1 for o in repl)


def test_collectives_local_trace_is_trivially_clean():
    rep = col.analyze_trace(_dp_trace())
    assert rep.ok
    assert rep.regions == ()


def test_expected_schedule_degenerate_and_dptp():
    plan = plan_mod.analyze([pex.Clip(1.0), pex.Noise(0.1, KEY)])
    flat = types.SimpleNamespace(axis_names=("data",),
                                 shape={"data": 8})
    sched = {e.output: e for e in
             col.expected_schedule(plan, flat, ("data",))}
    assert sched["loss_vec"].psum_axes == ()
    assert sched["grads"].psum_axes == ("data",)
    # 2-D DP×TP: per-example norms/losses gain the model-axis psum,
    # gradients reduce over both (the static half of the contract)
    dptp = types.SimpleNamespace(axis_names=("data", "model"),
                                 shape={"data": 4, "model": 2})
    sched2 = {e.output: e for e in
              col.expected_schedule(plan, dptp, ("data",))}
    assert sched2["loss_vec"].psum_axes == ("model",)
    assert sched2["sq_norms"].psum_axes == ("model",)
    assert sched2["sq_norms"].per_example
    assert sched2["grads"].psum_axes == ("data", "model")
    assert not sched2["grads"].per_example
    # weights derive from complete norms: never reduced
    assert sched2["weights"].psum_axes == ()


# ---------------------------------------------------------------------------
# determinism: clean targets + the non-seed-drift purity rules
# ---------------------------------------------------------------------------

def test_determinism_shipping_targets_are_clean():
    rep = det.analyze()
    assert rep.ok, rep.summary()
    names = [t.name for t in rep.targets]
    assert any("pipeline" in n for n in names)
    assert any("_probe_batch" in n for n in names)


@pytest.mark.parametrize("src,code", [
    ("def f(step):\n    return np.random.default_rng((time.time(), step))",
     "forbidden-call"),
    ("def f(step):\n    return np.random.randint(0, 9)",
     "forbidden-call"),
    ("def f(step):\n    return random.random()", "forbidden-call"),
    ("def f(step):\n    rng = np.random.default_rng()\n"
     "    return rng.integers(step)", "unseeded-rng"),
    ("def f(step):\n    return np.random.default_rng((hash('s'), step))",
     "unstable-hash"),
    ("class S:\n    def batch_at(self, step):\n"
     "        self.cursor = step\n"
     "        return np.random.default_rng((self.cursor, step))",
     "iterator-state"),
    ("def f(step):\n    global cur\n    cur += 1\n    return cur",
     "global-state"),
], ids=["wall-clock", "legacy-np", "stdlib-random", "unseeded",
        "hash-seed", "iter-state", "global"])
def test_determinism_rule(src, code):
    findings = det.check_source(src, "snippet")
    assert code in {f.code for f in findings}


def test_determinism_allows_seeded_streams():
    src = ("class S:\n"
           "    def __init__(self, seed):\n"
           "        self.seed = seed\n"
           "    def batch_at(self, step):\n"
           "        rng = np.random.default_rng((self.seed, step))\n"
           "        return rng.integers(0, 9, size=(4,))\n")
    assert not det.check_source(src, "snippet")


# ---------------------------------------------------------------------------
# composition: Engine.verify carries the new passes, trace-only holds
# ---------------------------------------------------------------------------

def test_engine_verify_deep_fields():
    from repro.core.engine import Engine
    from repro.core.taps import PexSpec
    aspec, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    eng = Engine(PexSpec(enabled=True), mesh=_mesh())
    rep = eng.verify(loss_fn, params, batch,
                     [[pex.Clip(1.0), pex.Noise(0.1, KEY)]])
    assert rep.ok, rep.summary()
    assert len(rep.privacy) == 1
    assert len(rep.collectives) == 1
    assert rep.determinism is not None and rep.determinism.ok
    assert rep.findings == ()
    assert "collectives:" in rep.summary()
    assert "determinism:" in rep.summary()


def test_bench_drift_gate_clean_on_committed_baseline():
    from benchmarks import check_drift
    path = check_drift.newest_bench(
        check_drift.os.path.dirname(check_drift._HERE))
    with open(path) as f:
        bench = json.load(f)
    assert check_drift.check(bench) == []


def test_bench_drift_gate_catches_drift():
    from benchmarks import check_drift
    bench = {
        # crossover numbers far from what the current model computes
        "methods.crossover[p=512x512]": 0.0,
        "methods.crossover[p=512x512]#derived": "xla_s=9999;pallas_s=9999",
        # a pick the model disagrees with, and a measured pair where
        # the "picked" method is 10x the best
        "methods.gram[b=2,s=256,p=64x64]": 548.2,
        "methods.gram[b=2,s=256,p=64x64]#derived": "cost_model_pick=gram",
        "methods.direct[b=2,s=256,p=64x64]": 54.8,
        # interpret-mode rows must be skipped, however wrong
        "seg.crossover[p=32x32,n=4]": 0.0,
        "seg.crossover[p=32x32,n=4]#derived":
            "model_t=1;measured_t=1;interpret_mode",
    }
    problems = check_drift.check(bench)
    assert any("xla_s drifted" in p for p in problems)
    assert any("pick flipped" in p for p in problems)
    assert any("measured best" in p for p in problems)
    assert not any("seg.crossover[" in p for p in problems)


def test_flow_passes_are_trace_only():
    """Privacy + collectives over a mesh trace must never reach XLA
    compilation (key created before the block goes up)."""
    from jax._src import compiler
    mesh = _mesh()
    orig = compiler.backend_compile

    def blocked(*a, **kw):
        raise AssertionError("flow pass triggered an XLA compile")

    compiler.backend_compile = blocked
    try:
        tr = _dp_trace(mesh=mesh)
        assert priv.analyze_trace(tr).ok
        assert col.analyze_trace(tr).ok
        assert det.analyze().ok
    finally:
        compiler.backend_compile = orig
