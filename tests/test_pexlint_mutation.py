"""Mutation corpus for the pexlint passes: break one privacy-critical
piece of the pipeline at a time and prove the static passes flag EVERY
mutant (100% detection) while the clean traces stay green (zero false
positives — test_pexlint.py and the cross-pass sweep below).

Coverage mutants programmatically delete one tap site at a time —
route the k-th instrumented op of the trace through its plain
counterpart — across all four model families. A deleted site sends the
weight's gradient down the ordinary autodiff path, so its taint
reaches the loss and the leaf classifies as untapped-but-trained; a
site inside a scan body covers all layers at once (the body traces
once), which only makes the mutant bigger, not harder to see.

Flow mutants (DESIGN.md §12) monkeypatch the plan layer's seams —
``run_fused`` / ``add_grad_noise`` / ``_compose_weights`` are
module-level names both ``plan.execute`` and ``dist.pex.plan_step``
resolve at call time — to build the classic DP-pipeline bugs:

  * noise added per shard inside the region (before the psum);
  * noise applied twice to the reduced gradient;
  * clip coefficients computed but never folded into the backward seed;
  * one PRNG key shared by every leaf's noise draw;
  * a per-example output psum'd over the data axis;
  * the data pipeline's seed ignoring the step cursor.
"""
import dataclasses
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import pex
from repro.analysis import _jaxpr as _J
from repro.analysis import collectives as col
from repro.analysis import coverage as cov
from repro.analysis import determinism as det
from repro.analysis import privacy as priv
from repro.core import plan as plan_mod
from repro.core.provenance import mark_noise, mark_rng
from repro.core.taps import Tap
from repro.models import registry

from tests.test_pexlint import abstract_setup

# one representative per family: transformer (with MoE), rwkv6,
# zamba2, seamless (encoder-decoder)
CORPUS = ["phi3.5-moe", "rwkv6-3b", "zamba2-7b", "seamless-m4t-medium"]


class MutantTap(Tap):
    """Tap that drops its ``kill``-th op call (trace order) to the
    uninstrumented path; ``kill=-1`` counts sites without mutating."""
    __slots__ = ("kill", "count")

    def __init__(self, spec, acc=None, layout=None, kill=-1):
        super().__init__(spec, acc, layout)
        self.kill = kill
        self.count = 0

    def _dead(self) -> bool:
        k = self.count
        self.count += 1
        return k == self.kill

    def dense(self, h, w, **kw):
        if self._dead():
            return jnp.einsum("...i,io->...o", h, w)
        return super().dense(h, w, **kw)

    def bias_add(self, x, b, **kw):
        if self._dead():
            return x + b
        return super().bias_add(x, b, **kw)

    def scale(self, h, g, **kw):
        if self._dead():
            return h * g
        return super().scale(h, g, **kw)

    def embedding(self, table, ids, **kw):
        if self._dead():
            return jnp.take(table, ids, axis=0)
        return super().embedding(table, ids, **kw)

    def dense_expert(self, x, w, seg, tok=None, **kw):
        if self._dead():
            return jnp.einsum("ecd,edf->ecf", x, w)
        return super().dense_expert(x, w, seg, tok, **kw)

    def dense_expert_grouped(self, x, w, seg, bg, tok=None, **kw):
        if self._dead():
            return jnp.einsum("gecd,gedf->gecf", x, w) \
                if w.ndim == 4 else jnp.einsum("gecd,edf->gecf", x, w)
        return super().dense_expert_grouped(x, w, seg, bg, tok, **kw)


def _count_sites(loss_fn, params, batch) -> int:
    holder = {}

    def factory(spec, acc=None, layout=None):
        tap = MutantTap(spec, acc, layout, kill=-1)
        holder["tap"] = tap
        return tap

    cov.trace_coverage(loss_fn, params, batch, tap_factory=factory)
    return holder["tap"].count


@pytest.mark.parametrize("arch_id", CORPUS)
def test_every_deleted_tap_site_is_flagged(arch_id):
    _, loss_fn, params, batch = abstract_setup(arch_id)
    allow = registry.untapped_allowlist(arch_id)
    n = _count_sites(loss_fn, params, batch)
    assert n > 0
    missed = []
    for k in range(n):
        rep = cov.trace_coverage(
            loss_fn, params, batch, allow=allow,
            tap_factory=lambda spec, acc=None, layout=None:
                MutantTap(spec, acc, layout, kill=k))
        if rep.ok:
            missed.append(k)
    assert not missed, (
        f"{arch_id}: deleting tap call(s) {missed} of {n} went "
        f"undetected by the coverage pass")


def test_mutant_errors_name_the_right_leaves():
    """Killing the first dense site must flag exactly the weight(s)
    routed through it — not unrelated leaves (precision, not just
    recall)."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    clean = cov.trace_coverage(loss_fn, params, batch)
    assert clean.ok
    rep = cov.trace_coverage(
        loss_fn, params, batch,
        tap_factory=lambda spec, acc=None, layout=None:
            MutantTap(spec, acc, layout, kill=0))
    assert not rep.ok
    # every flagged leaf was TAPPED in the clean trace
    clean_by_path = {str(l.path): l.status for l in clean.leaves}
    for leaf in rep.errors:
        assert clean_by_path[str(leaf.path)] == cov.TAPPED


# ---------------------------------------------------------------------------
# flow mutants — privacy / collectives / determinism (DESIGN.md §12)
# ---------------------------------------------------------------------------

KEY = jax.random.PRNGKey(0)
DP_CONSUMERS = [pex.Clip(1.0), pex.Noise(0.1, KEY)]


def _one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _dp_trace(mesh=None):
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    return _J.trace_step(loss_fn, params, batch, DP_CONSUMERS,
                         mesh=mesh, batch_size=3)


def _codes(report):
    return {f.code for f in report.findings}


def test_mutant_noise_before_psum(monkeypatch):
    """Noise applied per shard inside the region — each shard's draw
    adds up, inflating the variance by the shard count."""
    real_fused = plan_mod.run_fused
    real_noise = plan_mod.add_grad_noise

    def in_region(sub, acc_loss, p, b, bs, layout, *, loss_weights=None):
        lv, aux, sq, grads, w, tw, cc = real_fused(
            sub, acc_loss, p, b, bs, layout, loss_weights=loss_weights)
        if sub.noise is not None and grads is not None:
            scale = sub.noise.scale if sub.noise.scale is not None \
                else sub.clip.clip_norm
            grads = real_noise(grads, sub.noise.noise_std, scale,
                               sub.noise.rng)
        return lv, aux, sq, grads, w, tw, cc

    monkeypatch.setattr(plan_mod, "run_fused", in_region)
    monkeypatch.setattr(plan_mod, "add_grad_noise",
                        lambda g, *a, **kw: g)
    rep = priv.analyze_trace(_dp_trace(mesh=_one_device_mesh()))
    assert not rep.ok
    assert "noise-before-psum" in _codes(rep)


def test_mutant_double_noise(monkeypatch):
    """The noise step applied twice to the reduced gradient — double
    the privacy budget's variance, silently."""
    real_noise = plan_mod.add_grad_noise

    def twice(grads, noise_std, clip_norm, rng):
        once = real_noise(grads, noise_std, clip_norm, rng)
        return real_noise(once, noise_std, clip_norm,
                          jax.random.fold_in(rng, 1))

    monkeypatch.setattr(plan_mod, "add_grad_noise", twice)
    rep = priv.analyze_trace(_dp_trace())
    assert not rep.ok
    assert "double-noise" in _codes(rep)


def test_mutant_unclipped_leaf(monkeypatch):
    """Clip coefficients computed (and returned!) but never folded
    into the backward seed — the result LOOKS clipped while every
    gradient is the raw sum."""
    real_compose = plan_mod._compose_weights

    def drop_fold(plan, sq_norms, loss_weights, extra_weights=None):
        _, tw, cc = real_compose(plan, sq_norms, loss_weights,
                                 extra_weights)
        unfolded = real_compose(
            dataclasses.replace(plan, clip=None), sq_norms,
            loss_weights, extra_weights)[0]
        return unfolded, tw, cc

    monkeypatch.setattr(plan_mod, "_compose_weights", drop_fold)
    rep = priv.analyze_trace(_dp_trace())
    assert not rep.ok
    assert "unclipped-leaf" in _codes(rep)


def test_mutant_reused_key(monkeypatch):
    """Every leaf's noise drawn from the SAME key — perfectly
    correlated noise across leaves, not independent Gaussians."""
    def shared_key(grads, noise_std, clip_norm, rng):
        flat, tree = jax.tree_util.tree_flatten(grads)
        out = []
        for i, g in enumerate(flat):
            k = mark_rng(rng, purpose="noise", index=i)
            sample = noise_std * clip_norm * \
                jax.random.normal(k, g.shape, jnp.float32).astype(g.dtype)
            out.append(g + mark_noise(sample, noise_std=noise_std,
                                      scale=clip_norm, leaf=i))
        return jax.tree_util.tree_unflatten(tree, out)

    monkeypatch.setattr(plan_mod, "add_grad_noise", shared_key)
    rep = priv.analyze_trace(_dp_trace())
    assert not rep.ok
    assert "key-reuse" in _codes(rep)


def test_mutant_per_example_psum(monkeypatch):
    """A per-example output reduced over the data axis — every shard's
    loss vector silently becomes the cross-shard sum."""
    real_fused = plan_mod.run_fused

    def psum_lv(sub, acc_loss, p, b, bs, layout, *, loss_weights=None):
        lv, aux, sq, grads, w, tw, cc = real_fused(
            sub, acc_loss, p, b, bs, layout, loss_weights=loss_weights)
        return jax.lax.psum(lv, "data"), aux, sq, grads, w, tw, cc

    monkeypatch.setattr(plan_mod, "run_fused", psum_lv)
    rep = col.analyze_trace(_dp_trace(mesh=_one_device_mesh()))
    assert not rep.ok
    assert "per-example-psum" in _codes(rep)


def test_mutant_seed_drift():
    """The real pipeline source with ``step`` dropped from the seed
    tuple — every step replays step-0 data after a restore."""
    import repro.data.pipeline as pipeline
    src = inspect.getsource(pipeline)
    assert "(cfg.seed, step, self.host_id, 0xDA7A)" in src
    mutated = src.replace("(cfg.seed, step, self.host_id, 0xDA7A)",
                          "(cfg.seed, self.host_id, 0xDA7A)")
    findings = det.check_source(mutated, "data/pipeline.py")
    assert "seed-ignores-step" in {f.code for f in findings}
    # and the unmutated source is clean
    assert not det.check_source(src, "data/pipeline.py")


# ---------------------------------------------------------------------------
# cross-pass clean sweep — zero false positives on every registered
# arch × granularity × consumer set, through every pass at once
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", sorted(registry.ARCHS))
def test_clean_sweep_all_passes(arch_id):
    from repro.analysis.__main__ import lint_arch
    findings, _costs = lint_arch(arch_id, backend="tpu", production=True,
                                 key=KEY, mesh=_one_device_mesh(), deep=True)
    assert not findings, "\n".join(f.render() for f in findings)
