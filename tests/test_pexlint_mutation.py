"""Mutation corpus for the tap-coverage verifier: programmatically
delete one tap site at a time — route the k-th instrumented op of the
trace through its plain counterpart — across all four model families;
pexlint must flag EVERY mutant (100% detection) while the clean traces
stay green (zero false positives, test_pexlint.py).

A deleted site sends the weight's gradient down the ordinary autodiff
path, so its taint reaches the loss and the leaf classifies as
untapped-but-trained; a site inside a scan body covers all layers at
once (the body traces once), which only makes the mutant bigger, not
harder to see.
"""
import jax.numpy as jnp
import pytest

from repro.analysis import coverage as cov
from repro.core.taps import Tap
from repro.models import registry

from tests.test_pexlint import abstract_setup

# one representative per family: transformer (with MoE), rwkv6,
# zamba2, seamless (encoder-decoder)
CORPUS = ["phi3.5-moe", "rwkv6-3b", "zamba2-7b", "seamless-m4t-medium"]


class MutantTap(Tap):
    """Tap that drops its ``kill``-th op call (trace order) to the
    uninstrumented path; ``kill=-1`` counts sites without mutating."""
    __slots__ = ("kill", "count")

    def __init__(self, spec, acc=None, layout=None, kill=-1):
        super().__init__(spec, acc, layout)
        self.kill = kill
        self.count = 0

    def _dead(self) -> bool:
        k = self.count
        self.count += 1
        return k == self.kill

    def dense(self, h, w, **kw):
        if self._dead():
            return jnp.einsum("...i,io->...o", h, w)
        return super().dense(h, w, **kw)

    def bias_add(self, x, b, **kw):
        if self._dead():
            return x + b
        return super().bias_add(x, b, **kw)

    def scale(self, h, g, **kw):
        if self._dead():
            return h * g
        return super().scale(h, g, **kw)

    def embedding(self, table, ids, **kw):
        if self._dead():
            return jnp.take(table, ids, axis=0)
        return super().embedding(table, ids, **kw)

    def dense_expert(self, x, w, seg, tok=None, **kw):
        if self._dead():
            return jnp.einsum("ecd,edf->ecf", x, w)
        return super().dense_expert(x, w, seg, tok, **kw)

    def dense_expert_grouped(self, x, w, seg, bg, tok=None, **kw):
        if self._dead():
            return jnp.einsum("gecd,gedf->gecf", x, w) \
                if w.ndim == 4 else jnp.einsum("gecd,edf->gecf", x, w)
        return super().dense_expert_grouped(x, w, seg, bg, tok, **kw)


def _count_sites(loss_fn, params, batch) -> int:
    holder = {}

    def factory(spec, acc=None, layout=None):
        tap = MutantTap(spec, acc, layout, kill=-1)
        holder["tap"] = tap
        return tap

    cov.trace_coverage(loss_fn, params, batch, tap_factory=factory)
    return holder["tap"].count


@pytest.mark.parametrize("arch_id", CORPUS)
def test_every_deleted_tap_site_is_flagged(arch_id):
    _, loss_fn, params, batch = abstract_setup(arch_id)
    allow = registry.untapped_allowlist(arch_id)
    n = _count_sites(loss_fn, params, batch)
    assert n > 0
    missed = []
    for k in range(n):
        rep = cov.trace_coverage(
            loss_fn, params, batch, allow=allow,
            tap_factory=lambda spec, acc=None, layout=None:
                MutantTap(spec, acc, layout, kill=k))
        if rep.ok:
            missed.append(k)
    assert not missed, (
        f"{arch_id}: deleting tap call(s) {missed} of {n} went "
        f"undetected by the coverage pass")


def test_mutant_errors_name_the_right_leaves():
    """Killing the first dense site must flag exactly the weight(s)
    routed through it — not unrelated leaves (precision, not just
    recall)."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    clean = cov.trace_coverage(loss_fn, params, batch)
    assert clean.ok
    rep = cov.trace_coverage(
        loss_fn, params, batch,
        tap_factory=lambda spec, acc=None, layout=None:
            MutantTap(spec, acc, layout, kill=0))
    assert not rep.ok
    # every flagged leaf was TAPPED in the clean trace
    clean_by_path = {str(l.path): l.status for l in clean.leaves}
    for leaf in rep.errors:
        assert clean_by_path[str(leaf.path)] == cov.TAPPED
