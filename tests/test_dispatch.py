"""The two-sided (backend-aware) dense-stat dispatch.

``stat_dense(method="auto")`` must (a) price gram vs direct with the
cost model of the backend that will actually run the stat, and (b)
with ``use_pallas=True`` land on a *real Pallas kernel* in both
regimes — the triangular gram kernel for short S, the blocked HᵀZ̄
direct kernel for long S — never the lax.scan fallback.
"""
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import norms as N
from repro.kernels import ops, ref

RNG = np.random.default_rng(11)

GRAM_REGIME = dict(s=64, pi=512, po=512)     # s ≪ pi·po/(pi+po) = 256
DIRECT_REGIME = dict(s=1024, pi=256, po=256)  # s ≫ crossover


def _hz(b, s, pi, po):
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), jnp.float32)
    return h, z


@pytest.mark.parametrize("use_pallas", [False, True])
def test_pick_method_regimes(use_pallas):
    assert N.pick_method(GRAM_REGIME["s"], GRAM_REGIME["pi"],
                         GRAM_REGIME["po"], use_pallas) == "gram"
    assert N.pick_method(DIRECT_REGIME["s"], DIRECT_REGIME["pi"],
                         DIRECT_REGIME["po"], use_pallas) == "direct"


def test_pallas_crossover_later_than_xla():
    """The triangular kernel halves gram's cost, so on the Pallas
    backend gram stays competitive to ~2× longer sequences."""
    xla = N.crossover_s(512, 512)
    plls = N.crossover_s(512, 512, use_pallas=True)
    assert xla == pytest.approx(512 * 512 / (512 + 512), rel=0.05)
    assert 1.2 * xla < plls <= 2.2 * xla


def test_pallas_cost_charges_padding():
    """A 1-wide p_out pads to a 128-lane chunk; the Pallas direct cost
    must reflect the padded shape, the XLA cost the logical one."""
    xla = N.dense_cost("direct", 256, 256, 1)
    plls = N.dense_cost("direct", 256, 256, 1, use_pallas=True)
    assert plls >= 100 * xla  # 1 → 128 lanes


def test_auto_dispatches_to_pallas_kernel_in_both_regimes():
    """use_pallas + auto must invoke ops.gram_norm in the gram regime
    and ops.direct_norm in the direct regime (no scan fallback)."""
    for regime, expect_called, expect_not in [
            (GRAM_REGIME, "gram_norm", "direct_norm"),
            (DIRECT_REGIME, "direct_norm", "gram_norm")]:
        h, z = _hz(2, regime["s"], regime["pi"], regime["po"])
        with mock.patch.object(ops, expect_called,
                               wraps=getattr(ops, expect_called)) as hit, \
             mock.patch.object(ops, expect_not,
                               wraps=getattr(ops, expect_not)) as miss:
            got = N.stat_dense(h, z, method="auto", use_pallas=True)
            assert hit.call_count == 1, regime
            assert miss.call_count == 0, regime
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.gram_norm_ref(h, z)),
                                   rtol=1e-4)


@pytest.mark.parametrize("method", ["gram", "direct"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_stat_dense_parity_across_backends(method, use_pallas):
    h, z = _hz(2, 96, 160, 224)
    got = N.stat_dense(h, z, method=method, use_pallas=use_pallas)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.gram_norm_ref(h, z)),
                               rtol=1e-4)


def test_taps_auto_pallas_hits_direct_kernel():
    """End-to-end: a long-S dense tap with PexSpec(use_pallas, auto)
    reaches the Pallas direct kernel inside the custom_vjp backward,
    and the recovered norms match the hand-computed oracle."""
    from repro.core.engine import Engine
    from repro.core.taps import PexSpec

    b, s, pi, po = 2, 512, 32, 48  # crossover ≈ 19 ⇒ direct regime
    spec = PexSpec(enabled=True, method="auto", use_pallas=True)
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(pi, po)) / np.sqrt(pi), jnp.float32)

    def loss_fn(p, batch, tap):
        z = tap.dense(batch["h"], p["w"])
        return jnp.sum(jnp.square(z), axis=(1, 2)), {}

    with mock.patch.object(ops, "direct_norm",
                           wraps=ops.direct_norm) as hit:
        res = Engine(spec).value_and_norms(loss_fn, {"w": w}, {"h": h})
        assert hit.call_count >= 1

    # oracle: z̄ = 2z per example, s_j = ||h_jᵀ z̄_j||²_F
    z = np.asarray(h) @ np.asarray(w)
    want = np.stack([((np.asarray(h)[j].T @ (2 * z[j])) ** 2).sum()
                     for j in range(b)])
    np.testing.assert_allclose(np.asarray(jnp.sum(res.sq_norms, -1)), want,
                               rtol=1e-4)


def test_cost_analysis_exposes_flops():
    """compiled.cost_analysis() stays consumable for both kernels (on
    TPU it reflects the attached CostEstimate — the halved gram work;
    XLA:CPU counts the interpreter's grid loop body once, so here we
    only pin the plumbing and the model ratio)."""
    from repro.kernels import direct_norm as dn
    from repro.kernels import gram_norm as gn

    h, z = _hz(1, 256, 256, 256)

    def run_tri(h, z):
        return gn.gram_norm(h, z, tile_s=64, chunk_in=256, chunk_out=256,
                            triangular=True, interpret=True)

    def run_dir(h, z):
        return dn.direct_norm(h, z, tile_s=64, chunk_in=256, chunk_out=256,
                              interpret=True)

    for fn in (run_tri, run_dir):
        ca = jax.jit(fn).lower(h, z).compile().cost_analysis()
        if isinstance(ca, list):  # jax<0.4.30 returned [dict]
            ca = ca[0]
        assert ca.get("flops", 0) > 0

    full = gn.flop_estimate(1, 1024, 512, 512, triangular=False)
    tri = gn.flop_estimate(1, 1024, 512, 512, triangular=True)
    assert full / tri >= 1.7
