"""Integration: trainer step modes, serving engine, decode-state
continuity, local/global masking, MoE capacity behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.common import ShapeSpec
from repro.core import taps
from repro.core.taps import PexSpec
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.nn.param import unbox
from repro.optim import adamw
from repro.serve.engine import Engine, Request
from repro.train.trainer import TrainConfig, Trainer, consumers_for_mode

from helpers import smoke_setup


def _trainer(consumers, steps=6, arch="llama3.2-1b", **kw):
    aspec = registry.get(arch)
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    pex = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    return Trainer(loss_fn, params, pex, adamw.AdamWConfig(lr=1e-3),
                   TrainConfig(consumers=consumers, steps=steps,
                               log_every=0, **kw),
                   DataConfig(vocab=cfg.vocab, seq=16, global_batch=8))


@pytest.mark.parametrize("mode", ["plain", "norms", "clip", "importance"])
def test_trainer_modes_reduce_loss_and_run(mode):
    t = _trainer(consumers_for_mode(mode, 8, clip_norm=1.0), steps=8)
    ms = t.train()
    assert len(ms) == 8
    assert all(np.isfinite(m["loss"]) for m in ms)
    if mode in ("norms", "clip"):
        assert all(m["norm_mean"] > 0 for m in ms)


def test_trainer_consumer_plan_step():
    """A composed plan (clip + noise + GNS telemetry) trains and logs
    its consumers' outputs from ONE fused step."""
    from repro import pex as P
    t = _trainer((P.Norms(), P.Clip(1.0), P.Noise(0.05), P.GNS()), steps=4)
    ms = t.train()
    assert all(np.isfinite(m["loss"]) for m in ms)
    assert all(np.isfinite(m["gns"]) for m in ms)
    assert all(m["norm_mean"] > 0 for m in ms)


def test_trainer_grad_compression_runs():
    t = _trainer(consumers_for_mode("norms", 8), steps=4,
                 compress_grads=True)
    ms = t.train()
    assert np.isfinite(ms[-1]["loss"])


def test_engine_slot_recycling_and_lengths():
    arch = "llama3.2-1b"
    aspec = registry.get(arch)
    cfg = registry.serving_config(aspec, aspec.smoke(),
                                  ShapeSpec("t", "decode", 32, 2))
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    eng = Engine(arch, cfg, params, batch_slots=2, temperature=0.0)
    reqs = [Request(prompt=[1, 2, 3], max_new=4),
            Request(prompt=[4, 5], max_new=6),
            Request(prompt=[7], max_new=3)]
    done = eng.generate(reqs)
    assert [len(r.out) for r in done] == [4, 6, 3]
    # greedy decode is deterministic
    done2 = Engine(arch, cfg, params, batch_slots=2,
                   temperature=0.0).generate(
        [Request(prompt=[1, 2, 3], max_new=4)])
    assert done2[0].out == done[0].out


@pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-7b"])
def test_ssm_two_segment_prefill_matches_one_shot(arch):
    """Feeding S tokens as two chunks through the recurrent state must
    equal one-shot prefill — the property elastic decode relies on."""
    aspec = registry.get(arch)
    mod = registry.family_module(aspec)
    B, S = 2, 8
    cfg = registry.serving_config(aspec, aspec.smoke(),
                                  ShapeSpec("t", "decode", S, B))
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    fwd = registry.make_forward_tokens(aspec, cfg)
    batch = registry.make_train_batch(aspec, cfg, ShapeSpec("t", "train", S, B))

    logits_full, _ = fwd(params, batch, mod.init_caches(B, cfg), 0)
    c = mod.init_caches(B, cfg)
    _, c = fwd(params, {"ids": batch["ids"][:, :5]}, c, 0)
    logits_b, _ = fwd(params, {"ids": batch["ids"][:, 5:]}, c, 5)
    np.testing.assert_allclose(np.asarray(logits_full[:, 5:, :cfg.vocab]),
                               np.asarray(logits_b[:, :, :cfg.vocab]),
                               rtol=2e-3, atol=2e-3)


def test_gemma2_local_layers_actually_window():
    """Even layers must not see beyond the window; odd (global) must."""
    from repro.nn.attention import AttnCfg, _attend
    cfg = AttnCfg(d_model=8, n_heads=1, n_kv=1, head_dim=8, window=2,
                  head_multiple=1)
    B, S = 1, 6
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, 1, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 1, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 1, 8)), jnp.float32)
    out_local = _attend(q, k, v, cfg, 0, None, local_flag=jnp.array(True))
    out_global = _attend(q, k, v, cfg, 0, None, local_flag=jnp.array(False))
    # with window=2 the last query ignores k[:3]; perturbing k[0] must
    # change only the global variant
    k2 = k.at[:, 0].add(10.0)
    out_local2 = _attend(q, k2, v, cfg, 0, None, local_flag=jnp.array(True))
    out_global2 = _attend(q, k2, v, cfg, 0, None, local_flag=jnp.array(False))
    np.testing.assert_allclose(out_local[:, -1], out_local2[:, -1], rtol=1e-6)
    assert np.abs(np.asarray(out_global[:, -1] - out_global2[:, -1])).max() > 1e-4


def test_moe_capacity_drops_tokens_not_nans():
    from repro.nn.moe import MoeCfg, init_moe, moe
    cfg = MoeCfg(d_model=16, d_ff=8, n_experts=4, top_k=2,
                 capacity_factor=0.25)   # aggressive drops
    p = unbox(init_moe(jax.random.PRNGKey(0), cfg, dtype=jnp.float32))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)),
                    jnp.float32)
    y = moe(p, x, tap=taps.NULL, cfg=cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def test_elastic_restore_reshard(tmp_path):
    """Checkpoint written once restores under a different (simulated)
    topology: values identical, placement re-derived."""
    from repro.ckpt.checkpoint import CheckpointManager
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree, block=True)
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    restored, _ = mgr.restore(1, tree,
                              shardings={"w": sh})
    np.testing.assert_array_equal(restored["w"], tree["w"])
    assert restored["w"].sharding == sh


def test_flash_attention_wiring_matches_plain():
    """flash=True must not change loss, grads, or per-example norms."""
    from repro.core.engine import Engine
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    cfg_f = dataclasses.replace(
        cfg, attn=dataclasses.replace(cfg.attn, flash=True))
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("t", "train", 128, 2))
    eng = Engine(PexSpec(enabled=True, method="gram"))
    r1 = eng.value_grads_and_norms(
        registry.make_loss_fn_v2(aspec, cfg), params, batch)
    r2 = eng.value_grads_and_norms(
        registry.make_loss_fn_v2(aspec, cfg_f), params, batch)
    np.testing.assert_allclose(r1.loss, r2.loss, rtol=1e-4)
    np.testing.assert_allclose(r1.sq_norms, r2.sq_norms, rtol=1e-3)
