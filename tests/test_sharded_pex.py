"""Mesh-sharded per-example pipeline (repro.dist.pex).

The multi-device check runs in a subprocess: the in-suite jax has
already initialized a single CPU device, and the host-device-count XLA
flag must precede jax init (same pattern as test_dryrun).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.dist import pex, sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_sharded_matches_single_device_subprocess():
    """Acceptance: value_and_norms / grads / clipped grads allclose
    between single-device and an 8-way data-parallel host mesh."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.dist.selfcheck"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "PASS: 8-way data-parallel" in r.stdout, r.stdout


def _toy_loss(params, batch, tap):
    z = tap.dense(batch["x"], params["w"], group="all")
    loss_vec = jnp.sum(jnp.square(z), axis=tuple(range(1, z.ndim)))
    return loss_vec, {}


def _one_device_mesh():
    return shd.make_mesh((1, 1), ("data", "model"))


def test_pex_one_shard_identity():
    """The shard_map path must be exact on a trivial mesh (the in-suite
    single CPU device), through the one Engine entry point."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)}
    batch = {"x": jnp.asarray(rng.normal(size=(8, 3, 6)), jnp.float32)}
    spec = PexSpec(enabled=True)
    local = Engine(spec, clip_norm=1.0)
    sharded = Engine(spec, clip_norm=1.0, mesh=_one_device_mesh())
    ref = local.value_grads_and_norms(_toy_loss, params, batch)
    got = sharded.value_grads_and_norms(_toy_loss, params, batch)
    np.testing.assert_allclose(ref.loss, got.loss, rtol=1e-6)
    np.testing.assert_allclose(ref.sq_norms, got.sq_norms, rtol=1e-6)
    np.testing.assert_allclose(ref.grads["w"], got.grads["w"], rtol=1e-6)

    ref_c = local.clipped_step(_toy_loss, params, batch)
    got_c = sharded.clipped_step(_toy_loss, params, batch)
    np.testing.assert_allclose(ref_c.grads["w"], got_c.grads["w"],
                               rtol=1e-6)


def test_local_batch_divisibility():
    mesh = _one_device_mesh()
    assert shd.local_batch(8, ("data",), mesh) == 8
    assert shd.axis_size(("data", "model"), mesh) == 1
    assert shd.axis_size(None, mesh) == 1
    # non-divisible global batches are rejected before any shard_map;
    # multi-way extents are exercised in the selfcheck subprocess
    with pytest.raises(ValueError):
        shd.pad_to(4, 0)


def test_trainer_runs_on_mesh():
    """Trainer(mesh=...) routes steps through dist.pex and trains
    identically to the single-device path on a trivial mesh."""
    from repro.data.pipeline import DataConfig
    from repro.models import registry
    from repro.nn.param import unbox
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer

    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    spec = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=8, global_batch=4)
    ocfg = adamw.AdamWConfig(lr=1e-3)
    tcfg = TrainConfig(steps=2, log_every=0,
                       ckpt_every=10 ** 9)

    t_ref = Trainer(loss_fn, params, spec, ocfg, tcfg, dcfg)
    t_ref.train()
    t_mesh = Trainer(loss_fn, params, spec, ocfg, tcfg, dcfg,
                     mesh=_one_device_mesh())
    t_mesh.train()
    for a, b in zip(jax.tree_util.tree_leaves(t_ref.params),
                    jax.tree_util.tree_leaves(t_mesh.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_gradient_noise_scale_formula():
    """B_simple from (sq_norms, summed grads) must equal the explicit
    two-moment estimate computed from per-example gradients."""
    rng = np.random.default_rng(3)
    b, d = 16, 32
    g_i = rng.normal(size=(b, d)).astype(np.float32) + 0.5
    sq = np.sum(g_i ** 2, axis=1)
    grads = {"w": jnp.asarray(g_i.sum(0))}
    got = float(pex.gradient_noise_scale(jnp.asarray(sq), grads))
    s_bar = sq.mean()
    g_mean_sq = float(np.sum(g_i.sum(0) ** 2)) / (b * b)
    tr_sigma = (s_bar - g_mean_sq) * b / (b - 1)
    norm_g_sq = (b * g_mean_sq - s_bar) / (b - 1)
    np.testing.assert_allclose(got, tr_sigma / norm_g_sq, rtol=1e-4)


def test_gradient_noise_scale_zero_for_identical_examples():
    g = np.ones((8, 5), np.float32)
    sq = jnp.asarray(np.sum(g ** 2, 1))
    gns = float(pex.gradient_noise_scale(sq, {"w": jnp.asarray(g.sum(0))}))
    assert abs(gns) < 1e-4
