"""Per-arch exactness of per-example norms vs the naive (§3) oracle,
over the documented pex scope."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.taps import NULL, PexSpec
from repro.models import registry

from helpers import oracle_sq_norms, scope_filter, smoke_setup

ALL_ARCHS = sorted(registry.ARCHS)


def _nodrops(cfg):
    """MoE capacity high enough that routing is batch-size invariant
    (otherwise the naive oracle computes a *different function*)."""
    if getattr(cfg, "moe", None) is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_norms_exact_vs_naive(arch):
    aspec, cfg, mod, params, batch = smoke_setup(arch, cfg_edit=_nodrops)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    res = Engine(PexSpec(enabled=True, method="gram")).value_and_norms(
        loss_fn, params, batch)
    oracle = oracle_sq_norms(aspec, cfg, params, batch, scope_filter(arch))
    ours = np.asarray(jnp.sum(res.sq_norms, -1))
    np.testing.assert_allclose(ours, np.asarray(oracle), rtol=5e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-9b", "rwkv6-3b"])
def test_norms_exact_direct_method(arch):
    aspec, cfg, mod, params, batch = smoke_setup(arch, cfg_edit=_nodrops)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    res = Engine(PexSpec(enabled=True, method="direct")).value_and_norms(
        loss_fn, params, batch)
    oracle = oracle_sq_norms(aspec, cfg, params, batch, scope_filter(arch))
    np.testing.assert_allclose(np.asarray(jnp.sum(res.sq_norms, -1)),
                               np.asarray(oracle), rtol=5e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "phi3.5-moe"])
def test_clipped_grads_exact(arch):
    from repro.core import naive
    aspec, cfg, mod, params, batch = smoke_setup(arch, cfg_edit=_nodrops)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    clip = 5.0
    res = Engine(PexSpec(enabled=True, method="gram"),
                 clip_norm=clip).clipped_step(loss_fn, params, batch)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]

    pg = naive.per_example_grads(single, params, batch)
    # clip coefficients from the *scoped* norms our machinery computes
    c = jnp.minimum(1.0, clip / (jnp.sqrt(jnp.sum(res.sq_norms, -1)) + 1e-6))
    flat_ours, _ = jax.tree_util.tree_flatten(res.grads)
    flat_naive, _ = jax.tree_util.tree_flatten(
        jax.tree_util.tree_map(lambda g: jnp.einsum("b,b...->...", c, g), pg))
    for a, b in zip(flat_ours, flat_naive):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)
