"""Unit tests for ``roofline/hlo.py`` collective-byte parsing — the
edge cases the probe pipeline only exercises indirectly: operands whose
definitions are unresolvable (fusion parameters), variadic/fused
all-reduce forms, async start/done pairs, and zero-collective
modules."""
import pytest

from repro.roofline import hlo


def test_simple_all_reduce_counts_operand_bytes():
    text = """
HloModule m
ENTRY e {
  %a = f32[128,4] parameter(0)
  %ar = f32[128,4] all-reduce(%a), replica_groups={}
  ROOT %r = f32[128,4] add(%ar, %ar)
}
"""
    out = hlo.collective_bytes(text)
    assert out["all-reduce"] == 128 * 4 * 4
    assert out["total"] == out["all-reduce"]


def test_missing_operand_bytes_fall_back_to_result():
    """An operand defined outside the parsed scope (e.g. a fusion
    parameter) has no recorded size — the result type is the
    fallback, not a silent zero."""
    text = """
ENTRY e {
  %ar = bf16[64,32] all-reduce(%mystery.param), replica_groups={}
}
"""
    out = hlo.collective_bytes(text)
    assert out["all-reduce"] == 64 * 32 * 2


def test_variadic_all_reduce_sums_all_operands():
    """Fused/variadic all-reduce: tuple result, several operands — all
    operand buffers cross the wire."""
    text = """
ENTRY e {
  %a = f32[128] parameter(0)
  %b = bf16[256,2] parameter(1)
  %ar = (f32[128], bf16[256,2]) all-reduce(%a, %b), replica_groups={}
}
"""
    out = hlo.collective_bytes(text)
    assert out["all-reduce"] == 128 * 4 + 256 * 2 * 2


def test_variadic_fallback_uses_tuple_result_bytes():
    text = """
ENTRY e {
  %ar = (f32[128], bf16[256,2]) all-reduce(%p0, %p1), replica_groups={}
}
"""
    out = hlo.collective_bytes(text)
    assert out["all-reduce"] == 128 * 4 + 256 * 2 * 2


def test_async_start_variant_is_recognized():
    """``all-reduce-start`` (async form) attributes bytes to the
    all-reduce kind; the ``-done`` op must not double-count in
    collective_counts."""
    text = """
ENTRY e {
  %a = f32[1024] parameter(0)
  %s = f32[1024] all-reduce-start(%a), replica_groups={}
  %d = f32[1024] all-reduce-done(%s)
}
"""
    counts = hlo.collective_counts(text)
    assert counts == {"all-reduce": 1}
    by = hlo.collective_bytes(text)
    assert by["all-reduce"] >= 1024 * 4


def test_every_collective_kind_is_classified():
    text = """
ENTRY e {
  %a = f32[64] parameter(0)
  %g = f32[256] all-gather(%a), dimensions={0}
  %rs = f32[16] reduce-scatter(%a), dimensions={0}
  %p = f32[64] collective-permute(%a), source_target_pairs={{0,1}}
  %t = f32[64] all-to-all(%a), dimensions={0}
}
"""
    counts = hlo.collective_counts(text)
    assert counts == {"all-gather": 1, "reduce-scatter": 1,
                      "collective-permute": 1, "all-to-all": 1}
    by = hlo.collective_bytes(text)
    for kind in counts:
        assert by[kind] == 64 * 4, kind
    assert by["total"] == 4 * 64 * 4


def test_zero_collective_module():
    text = """
ENTRY e {
  %a = f32[64] parameter(0)
  ROOT %r = f32[64] add(%a, %a)
}
"""
    assert hlo.collective_bytes(text) == {"total": 0.0}
    assert hlo.collective_counts(text) == {}


def test_unknown_dtype_and_token_operands_contribute_zero():
    text = """
ENTRY e {
  %tok = token[] parameter(0)
  %ar = token[] all-reduce(%tok), replica_groups={}
}
"""
    out = hlo.collective_bytes(text)
    assert out["all-reduce"] == 0
    assert out["total"] == 0.0


def test_compiled_cost_tolerates_list_and_absent_analyses():
    class _Listy:
        def cost_analysis(self):
            return [{"flops": 7.0, "bytes accessed": 11.0}]

    class _Empty:
        def cost_analysis(self):
            return None

    assert hlo.compiled_cost(_Listy()) == (7.0, 11.0)
    assert hlo.compiled_cost(_Empty()) == (0.0, 0.0)
