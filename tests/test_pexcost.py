"""pexcost (traffic + cost) on the real registry: the clean sweep is
finding-free on every arch × granularity (zero false positives), the
known 3-stream apply path is detected and allowlisted, the CostReport
arithmetic and hardware profiles are sane, the baseline gate has
teeth, and the static flop predictions stay within tolerance of the
measured BENCH telemetry."""
import dataclasses
import json
import os

import jax
import pytest

from repro import pex
from repro.analysis import cost as cost_mod
from repro.analysis import traffic
from repro.analysis.findings import ERROR, WARNING
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.taps import PexSpec
from repro.models import registry
from repro.roofline import constants as hw

from tests.test_pexlint import abstract_setup

ALL_ARCHS = sorted(registry.ARCHS)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dp_consumers(granularity, key):
    if granularity == "token":
        return [pex.Clip(1.0, granularity="token"),
                pex.Noise(0.1, key, scale=1.0)]
    return [pex.Clip(1.0), pex.Noise(0.1, key), pex.GNS()]


# ---------------------------------------------------------------------------
# traffic pass — clean paths
# ---------------------------------------------------------------------------

def test_dp_step_counts_three_allowlisted_streams():
    """Today's unfused apply streams every gradient 3× (noise add,
    global-norm clip, adamw update); the pass must report exactly that
    as allowlisted — not as a failure — with the ROADMAP pointer."""
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    rep = traffic.check_train_step(
        loss_fn, params, batch,
        _dp_consumers("example", jax.random.PRNGKey(0)))
    assert rep.n_streams == 3
    assert rep.expected_streams == 3
    assert rep.ok and not rep.findings, rep.summary()
    assert len(rep.allowlisted) == 1
    f = rep.allowlisted[0]
    assert f.code == "redundant-hbm-stream"
    assert "ROADMAP" in f.message and "3" in f.message


def test_strict_mode_moves_known_streams_into_findings():
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    rep = traffic.check_train_step(
        loss_fn, params, batch,
        _dp_consumers("example", jax.random.PRNGKey(0)),
        allow_known_streams=False)
    assert not rep.ok
    assert any(f.code == "redundant-hbm-stream" and f.severity == ERROR
               for f in rep.findings)


def test_norms_only_step_has_no_apply_traffic():
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    rep = traffic.check_train_step(loss_fn, params, batch, [pex.Norms()])
    assert rep.n_streams == 0 == rep.expected_streams
    assert rep.ok and not rep.allowlisted


def test_phase_attribution_covers_the_step():
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    rep = traffic.check_train_step(
        loss_fn, params, batch,
        _dp_consumers("example", jax.random.PRNGKey(0)))
    bytes_by_phase = dict(rep.phase_bytes)
    assert bytes_by_phase["forward"] > 0
    assert bytes_by_phase["activation-bwd"] > 0
    assert bytes_by_phase["apply"] > 0
    assert abs(sum(bytes_by_phase.values()) - rep.hbm_bytes) \
        <= 1e-6 * rep.hbm_bytes
    # the reweighted backward reuses the norms backward's residuals
    assert rep.residual_sharing == pytest.approx(1.0)
    # one forward only
    assert rep.forward_flops <= 1.1 * rep.ref_forward_flops


# ---------------------------------------------------------------------------
# clean sweep through the real entry point (zero false positives)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_cost_sweep_is_clean(arch_id):
    """Engine.verify(cost=True) over every arch × granularity: no
    findings, streams always at the structural expectation, and every
    CostReport names its profile."""
    _, loss_fn, params, batch = abstract_setup(arch_id)
    allow = registry.untapped_allowlist(arch_id)
    for gran in ("example", "token"):
        eng = Engine(PexSpec(enabled=True), granularity=gran)
        rep = eng.verify(
            loss_fn, params, batch,
            [_dp_consumers(gran, jax.random.PRNGKey(0))],
            allow=allow, seq=8, deep=False, cost=True, model=arch_id)
        assert rep.ok, rep.summary()
        assert not rep.findings
        (tr,) = rep.traffic
        assert tr.n_streams == tr.expected_streams, tr.summary()
        (cr,) = rep.cost
        assert cr.model == arch_id
        assert cr.profile == hw.DEFAULT_PROFILE
        assert cr.t_step > 0
        assert cr.flops_hlo > 0 and cr.hbm_bytes > 0


# ---------------------------------------------------------------------------
# cost composition
# ---------------------------------------------------------------------------

def _llama_traffic():
    _, loss_fn, params, batch = abstract_setup("llama3.2-1b")
    return traffic.check_train_step(
        loss_fn, params, batch,
        _dp_consumers("example", jax.random.PRNGKey(0)))


def test_cost_report_roofline_arithmetic():
    tr = _llama_traffic()
    cr = cost_mod.build_cost(tr, model="llama3.2-1b")
    p = hw.get_profile(cr.profile)
    assert cr.t_compute == pytest.approx(
        cr.flops_hlo / p.peak_flops_bf16)
    assert cr.t_memory == pytest.approx(cr.hbm_bytes / p.hbm_bw)
    assert cr.t_collective == 0.0           # single chip, no wire term
    assert cr.t_step == max(cr.t_compute, cr.t_memory, cr.t_collective)
    assert cr.bottleneck in ("compute", "memory", "collective")
    # the smoke step is tiny: memory-bound on every current profile
    assert cr.bottleneck == "memory"


def test_cost_collective_term_scales_with_chips():
    tr = dataclasses.replace(_llama_traffic(), coll_bytes=1e9)
    one = cost_mod.build_cost(tr, chips=1)
    four = cost_mod.build_cost(tr, chips=4)
    p = hw.get_profile(four.profile)
    assert one.t_collective == 0.0
    # ring all-reduce wire volume: bytes × 2(n−1)/n over one ICI link
    assert four.t_collective == pytest.approx(
        1e9 * 2 * 3 / 4 / p.ici_bw)
    # per-chip compute/memory shares shrink with the fleet
    assert four.t_compute == pytest.approx(one.t_compute / 4)


def test_cost_composes_launch_contracts():
    from repro.kernels import gram_norm, rowsumsq
    tr = _llama_traffic()
    contracts = (gram_norm.launch_contract(4, 16, 64, 64),
                 rowsumsq.launch_contract(8, 2048))
    assert all(c.flops > 0 for c in contracts)
    assert all(c.hbm_bytes() > 0 for c in contracts)
    base = cost_mod.build_cost(tr)
    with_k = cost_mod.build_cost(tr, contracts=contracts)
    assert with_k.kernel_flops == pytest.approx(
        sum(c.flops for c in contracts))
    assert with_k.kernel_hbm_bytes == sum(c.hbm_bytes()
                                          for c in contracts)
    assert with_k.t_compute > base.t_compute
    assert with_k.t_memory > base.t_memory


def test_cost_report_json_round_trips():
    cr = cost_mod.build_cost(_llama_traffic(), model="llama3.2-1b")
    d = json.loads(json.dumps(cr.to_json()))
    assert d["model"] == "llama3.2-1b"
    assert d["profile"] == hw.DEFAULT_PROFILE
    assert d["bottleneck"] == cr.bottleneck
    assert d["n_streams"] == 3


# ---------------------------------------------------------------------------
# hardware profiles (roofline/constants.py)
# ---------------------------------------------------------------------------

def test_profiles_registry():
    assert hw.DEFAULT_PROFILE in hw.PROFILES
    for name, p in hw.PROFILES.items():
        assert p.name == name
        assert p.peak_flops_bf16 > 0 and p.hbm_bw > 0 and p.ici_bw > 0
        assert name in p.describe() or p.name in p.describe()
    with pytest.raises(KeyError, match="unknown hardware profile"):
        hw.get_profile("tpu-v99")


def test_legacy_flat_constants_track_default_profile():
    p = hw.PROFILES[hw.DEFAULT_PROFILE]
    assert hw.PEAK_FLOPS_BF16 == p.peak_flops_bf16
    assert hw.HBM_BW == p.hbm_bw
    assert hw.ICI_BW == p.ici_bw
    assert hw.HBM_BYTES == p.hbm_bytes


def test_cost_states_its_denominators():
    cr = cost_mod.build_cost(_llama_traffic(), profile="tpu-v5p")
    assert cr.profile == "tpu-v5p"
    assert "tpu-v5p" in cr.summary()
    p = hw.get_profile("tpu-v5p")
    assert cr.t_memory == pytest.approx(cr.hbm_bytes / p.hbm_bw)


# ---------------------------------------------------------------------------
# baseline gate
# ---------------------------------------------------------------------------

def _report():
    return cost_mod.build_cost(_llama_traffic(), model="llama3.2-1b")


def test_baseline_gate_round_trip_is_clean():
    cr = _report()
    baseline = cost_mod.baseline_payload([cr])
    assert not cost_mod.check_baseline([cr], baseline)


def test_baseline_gate_fails_on_growth():
    cr = _report()
    baseline = cost_mod.baseline_payload([cr])
    key = cost_mod.baseline_key(cr)
    baseline[key]["hbm_bytes"] *= 0.5      # HEAD now reads 2× the baseline
    out = cost_mod.check_baseline([cr], baseline)
    assert any(f.code == "cost-regression" and f.severity == ERROR
               and "hbm_bytes" in f.message for f in out)


def test_baseline_gate_warns_on_shrink_and_churn():
    cr = _report()
    baseline = cost_mod.baseline_payload([cr])
    key = cost_mod.baseline_key(cr)
    baseline[key]["flops_hlo"] *= 2.0      # HEAD got cheaper: re-baseline
    baseline["gone/example/plan"] = {"flops_hlo": 1.0}
    out = cost_mod.check_baseline([cr], baseline)
    assert all(f.severity == WARNING for f in out)
    codes = {f.code for f in out}
    assert codes == {"cost-baseline-stale"}


def test_baseline_gate_warns_on_missing_key():
    cr = _report()
    out = cost_mod.check_baseline([cr], {})
    assert [f.code for f in out] == ["cost-baseline-missing"]
    assert out[0].severity == WARNING


def test_committed_baseline_matches_head():
    """The committed COST_BASELINE.json must agree with HEAD's
    predictions — the same invariant the CI gate enforces."""
    path = os.path.join(ROOT, "COST_BASELINE.json")
    with open(path) as f:
        baseline = json.load(f)
    cr = _report()
    assert cost_mod.baseline_key(cr) in baseline
    out = cost_mod.check_baseline([cr], baseline)
    assert not [f for f in out if f.severity == ERROR], \
        [f.render() for f in out]


# ---------------------------------------------------------------------------
# Plan static-cost satellite
# ---------------------------------------------------------------------------

def test_plan_static_cost_and_describe():
    plan = plan_mod.analyze([pex.Clip(1.0),
                             pex.Noise(0.1, jax.random.PRNGKey(0)),
                             pex.GNS()])
    est = plan.static_cost(fwd_flops=1e9, param_bytes=1e6)
    assert est["regions"] == 1 and est["backwards"] == 2
    assert est["grad_stream_reads"] == 2    # write-back read + noise add
    # 1 forward + 2 backwards at 2× each
    assert est["flops_est"] == pytest.approx(5e9)
    assert est["grad_bytes_est"] == pytest.approx(3e6)
    desc = plan.describe(fwd_flops=1e9, param_bytes=1e6)
    assert "flops≈5e+09" in desc and "grad_bytes≈3e+06" in desc
    # the pinned default rendering is unchanged
    assert "flops" not in plan.describe()
    assert plan.describe().startswith("regions=1 backwards=2")


def test_plan_static_cost_tracks_plan_shape():
    norms_only = plan_mod.analyze([pex.Norms()])
    est = norms_only.static_cost(fwd_flops=1e9)
    assert est["backwards"] == 1 and est["grad_stream_reads"] == 0
    assert est["flops_est"] == pytest.approx(3e9)
    empty = plan_mod.analyze([])
    assert empty.static_cost(fwd_flops=1e9)["flops_est"] \
        == pytest.approx(1e9)


# ---------------------------------------------------------------------------
# cross-validation against the measured BENCH telemetry
# ---------------------------------------------------------------------------

def test_pexcost_predictions_within_bench_tolerance():
    """Every flops-telemetry ``#derived`` row of the newest committed
    BENCH baseline must be predicted within 25% by the static walker —
    exactly what benchmarks/check_drift.py gates in CI."""
    from benchmarks import check_drift
    path = check_drift.newest_bench(ROOT)
    with open(path) as f:
        bench = json.load(f)
    rows = {}
    for k, v in bench.items():
        if not (k.endswith("#derived") and isinstance(v, str)
                and v.startswith("flops=")):
            continue
        base, cfg = check_drift._parse(k[: -len("#derived")])
        if base in check_drift._PEXCOST_ROWS:
            rows[k[: -len("#derived")]] = (
                base, cfg, float(v[len("flops="):]))
    assert len(rows) == 4, sorted(rows)
    assert check_drift._check_pexcost(rows, tolerance=0.25) == []
