"""Shared test utilities."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import ShapeSpec
from repro.core import naive
from repro.core.taps import NULL
from repro.models import registry
from repro.nn.param import unbox

# params outside the pex norm scope, per arch — derived from the
# registry's declared allowlist (DESIGN.md §5, §10) so the oracle
# scope filter and the static tap-coverage verifier share one table
PEX_SCOPE_EXCLUDE = registry.UNTAPPED_ALLOWLIST


def scope_filter(arch_id):
    excl = registry.untapped_allowlist(arch_id)
    return lambda path: not any(e in str(path) for e in excl)


def smoke_setup(arch_id, B=3, S=8, seed=0, cfg_edit=None):
    aspec = registry.get(arch_id)
    cfg = aspec.smoke()
    if cfg_edit:
        cfg = cfg_edit(cfg)
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(seed), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("t", "train", S, B), seed)
    return aspec, cfg, mod, params, batch


def oracle_sq_norms(aspec, cfg, params, batch, param_filter=None):
    plain = registry.make_loss_fn_v2(aspec, cfg)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return plain(p, b1, NULL)[0][0]

    return naive.per_example_sq_norms(single, params, batch, param_filter)
