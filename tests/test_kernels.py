"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode
on CPU; the kernels target TPU BlockSpec tiling)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(2, 16, 24, 40), (3, 128, 512, 512),
                                   (2, 200, 300, 520), (1, 256, 1024, 256),
                                   (4, 64, 128, 896)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_norm(shape, dtype):
    b, s, pi, po = shape
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), dtype)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), dtype)
    got = ops.gram_norm(h, z)
    want = ref.gram_norm_ref(h, z)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=rtol)


@pytest.mark.parametrize("shape", [(4, 100), (8, 2048), (3, 5000), (16, 128),
                                   (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowsumsq(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(ops.rowsumsq(x), ref.rowsumsq_ref(x), rtol=rtol)


@pytest.mark.parametrize("shape", [(2, 10, 36), (4, 256, 512), (3, 100, 130)])
def test_clip_scale(shape):
    z = jnp.asarray(RNG.normal(size=shape), jnp.float32)
    c = jnp.asarray(RNG.uniform(0, 1, size=shape[0]), jnp.float32)
    np.testing.assert_allclose(ops.clip_scale(z, c), ref.clip_scale_ref(z, c),
                               rtol=1e-6)


@pytest.mark.parametrize("shape", [
    (2, 64, 1024, 128),    # p_in ≫ p_out: old shared chunk padded z 4×
    (2, 64, 128, 1024),    # p_out ≫ p_in
    (1, 32, 704, 96),      # non-multiple dims (704 → 2×384, 96 → 128)
    (2, 16, 24, 1000),     # tiny vs large (24 → 128, 1000 → 2×512)
])
def test_gram_norm_asymmetric_chunks(shape):
    """Independent p_in/p_out chunk sizing must stay exact for strongly
    asymmetric feature dims (each dim pads only to its own chunk)."""
    from repro.kernels.ops import _chunk_for
    b, s, pi, po = shape
    assert _chunk_for(pi) != _chunk_for(po)   # the asymmetry under test
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), jnp.float32)
    np.testing.assert_allclose(ops.gram_norm(h, z), ref.gram_norm_ref(h, z),
                               rtol=1e-5)


def test_gram_norm_zero_padding_exact():
    """Padding rows/features must contribute exactly nothing."""
    b, s, pi, po = 2, 100, 130, 70   # deliberately awkward sizes
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), jnp.float32)
    np.testing.assert_allclose(ops.gram_norm(h, z), ref.gram_norm_ref(h, z),
                               rtol=1e-5)


def test_gram_norm_matches_direct_identity():
    """‖HᵀZ‖²_F == Σ_{tt'} <h,h><z,z> (the identity the kernel exploits)."""
    b, s, pi, po = 3, 32, 48, 24
    h = np.asarray(RNG.normal(size=(b, s, pi)), np.float32)
    z = np.asarray(RNG.normal(size=(b, s, po)), np.float32)
    direct = np.stack([((h[i].T @ z[i]) ** 2).sum() for i in range(b)])
    np.testing.assert_allclose(np.asarray(ops.gram_norm(jnp.asarray(h),
                                                        jnp.asarray(z))),
                               direct, rtol=1e-5)


@pytest.mark.parametrize("tile_s", [64, 128])
def test_gram_triangular_matches_full_grid(tile_s):
    """Symmetry regression: the triangular grid (off-diagonal pairs
    folded with weight 2) must reproduce the full-grid value to f32
    tolerance — same dots, same accumulation order within a pair."""
    from repro.kernels import gram_norm as gn
    b, s, pi, po = 2, 512, 256, 384
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), jnp.float32)
    tri = gn.gram_norm(h, z, tile_s=tile_s, chunk_in=256, chunk_out=384,
                       triangular=True, interpret=True)
    full = gn.gram_norm(h, z, tile_s=tile_s, chunk_in=256, chunk_out=384,
                        triangular=False, interpret=True)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(full), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tri), ref.gram_norm_ref(h, z),
                               rtol=1e-5)


def test_gram_triangular_flop_model_halving():
    """The triangular grid's flop model must approach 2× below the full
    grid as S grows (exactly n_s²/(n_s(n_s+1)/2) = 2n_s/(n_s+1))."""
    from repro.kernels import gram_norm as gn

    def ratio(s):
        full = gn.flop_estimate(1, s, 512, 512, triangular=False)
        tri = gn.flop_estimate(1, s, 512, 512, triangular=True)
        return full / tri

    assert ratio(512) >= 1.5
    assert ratio(1024) >= 1.7
    assert ratio(8192) >= 1.9   # → 2× asymptotically


@pytest.mark.parametrize("shape", [(2, 16, 24, 40),     # tiny, odd dims
                                   (3, 128, 512, 512),  # aligned
                                   (2, 200, 300, 520),  # S not a tile multiple
                                   (1, 256, 1024, 256), # B=1, p_in ≠ p_out
                                   (2, 64, 128, 896),
                                   (1, 640, 640, 96)])  # long S, odd chunks
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_direct_norm(shape, dtype):
    """Blocked HᵀZ̄ kernel vs the gram oracle (mathematically the same
    quantity: ||H_jᵀZ̄_j||²_F)."""
    b, s, pi, po = shape
    h = jnp.asarray(RNG.normal(size=(b, s, pi)), dtype)
    z = jnp.asarray(RNG.normal(size=(b, s, po)), dtype)
    got = ops.direct_norm(h, z)
    want = ref.gram_norm_ref(h, z)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=rtol)


def test_direct_norm_matches_bruteforce():
    b, s, pi, po = 3, 40, 36, 20
    h = np.asarray(RNG.normal(size=(b, s, pi)), np.float32)
    z = np.asarray(RNG.normal(size=(b, s, po)), np.float32)
    brute = np.stack([((h[i].T @ z[i]) ** 2).sum() for i in range(b)])
    got = ops.direct_norm(jnp.asarray(h), jnp.asarray(z))
    np.testing.assert_allclose(np.asarray(got), brute, rtol=1e-5)


@pytest.mark.parametrize("p,expect", [
    (640, 128),    # 5×128 exact — the old schedule padded to 1024 (+60%)
    (768, 384),    # 2×384 exact (384 beats equally-exact 256/128: fewer k)
    (1152, 384),   # 3×384 exact — old: 1536 (+33%)
    (1024, 512),   # unchanged: 2×512
    (512, 512),
    (384, 384),    # < 512: single chunk at the 128-lane boundary
    (96, 128),
    (1, 128),
])
def test_chunk_for_schedule(p, expect):
    from repro.kernels.ops import _chunk_for
    assert _chunk_for(p) == expect
    assert _chunk_for(p) % 128 == 0


def test_chunk_for_never_worse_than_512():
    """The adaptive schedule's padding is ≤ the old always-512 rule."""
    from repro.kernels.ops import _chunk_for, _round_up
    for p in range(512, 4097, 32):
        c = _chunk_for(p)
        assert _round_up(p, c) - p <= _round_up(p, 512) - p, p


@pytest.mark.parametrize("cfg", [
    dict(B=2, Hq=4, Hkv=2, S=256, D=64, cap=None, win=None),
    dict(B=1, Hq=8, Hkv=8, S=512, D=32, cap=None, win=None),
    dict(B=2, Hq=4, Hkv=1, S=256, D=64, cap=50.0, win=None),
    dict(B=1, Hq=2, Hkv=2, S=512, D=64, cap=None, win=128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(cfg, dtype):
    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(RNG.normal(size=(cfg["B"], cfg["Hq"], cfg["S"], cfg["D"])), dtype)
    k = jnp.asarray(RNG.normal(size=(cfg["B"], cfg["Hkv"], cfg["S"], cfg["D"])), dtype)
    v = jnp.asarray(RNG.normal(size=(cfg["B"], cfg["Hkv"], cfg["S"], cfg["D"])), dtype)
    got = flash_attention(q, k, v, scale=cfg["D"] ** -0.5, softcap=cfg["cap"],
                          window=cfg["win"], block_q=128, block_k=128,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, scale=cfg["D"] ** -0.5,
                                   softcap=cfg["cap"], window=cfg["win"])
    rtol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("cfg", [
    dict(B=1, Hq=2, Hkv=1, S=256, D=32, cap=None, win=None),
    dict(B=2, Hq=4, Hkv=2, S=256, D=64, cap=None, win=None),
    dict(B=1, Hq=2, Hkv=2, S=256, D=32, cap=None, win=128),
    dict(B=1, Hq=2, Hkv=1, S=256, D=32, cap=30.0, win=None),
])
def test_flash_attention_backward(cfg):
    """Pallas dq/dk/dv kernels vs the reference VJP (GQA accumulation,
    sliding window, softcap chain rule)."""
    import jax
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_bwd)
    B, Hq, Hkv, S, D = (cfg[k] for k in ("B", "Hq", "Hkv", "S", "D"))
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, D)), jnp.float32)
    o, lse = flash_attention(q, k, v, scale=D ** -0.5, softcap=cfg["cap"],
                             window=cfg["win"], block_q=128, block_k=128,
                             interpret=True, return_lse=True)
    do = jnp.asarray(RNG.normal(size=o.shape), jnp.float32)
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, scale=D ** -0.5, softcap=cfg["cap"],
        window=cfg["win"], block_q=128, block_k=128, interpret=True)
    _, vjp = jax.vjp(lambda a, b, c: ref.flash_attention_ref(
        a, b, c, scale=D ** -0.5, softcap=cfg["cap"], window=cfg["win"]),
        q, k, v)
    rq, rk, rv = vjp(do)
    np.testing.assert_allclose(dq, rq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dk, rk, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dv, rv, rtol=2e-4, atol=2e-4)
