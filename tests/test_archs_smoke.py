"""Per-arch reduced-config smoke tests: one instrumented train step on
CPU — output shapes, finiteness, and norm positivity (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.taps import ExampleLayout, NULL, PexSpec, Tap
from repro.models import registry

from helpers import smoke_setup

ALL_ARCHS = sorted(registry.ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    aspec, cfg, mod, params, batch = smoke_setup(arch)
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    res = Engine(PexSpec(enabled=True, method="gram")).value_grads_and_norms(
        loss_fn, params, batch)
    assert res.loss_vec.shape == (3,)
    assert res.sq_norms.shape == (3, 1)
    assert np.isfinite(float(res.loss))
    assert np.all(np.isfinite(np.asarray(res.sq_norms)))
    assert np.all(np.asarray(res.sq_norms) > 0)
    for leaf in jax.tree_util.tree_leaves(res.grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = registry.get(arch).full()
    expected = {
        "qwen2-vl-7b": dict(n_layers=28, d_model=3584, vocab=152064),
        "zamba2-7b": dict(n_layers=81, d_model=3584, vocab=32000),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, vocab=128256),
        "qwen2-7b": dict(n_layers=28, d_model=3584, vocab=152064),
        "minitron-4b": dict(n_layers=32, d_model=3072, vocab=256000),
        "gemma2-9b": dict(n_layers=42, d_model=3584, vocab=256000),
        "rwkv6-3b": dict(n_layers=32, d_model=2560, vocab=65536),
        "seamless-m4t-medium": dict(d_model=1024, vocab=256206),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, vocab=102400),
        "phi3.5-moe": dict(n_layers=32, d_model=4096, vocab=32064),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # spot-check family-specific fields
    if arch == "deepseek-v2-236b":
        assert cfg.moe.n_experts == 160 and cfg.moe.top_k == 6
        assert cfg.mla.kv_lora == 512 and cfg.mla.n_heads == 128
    if arch == "phi3.5-moe":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
    if arch == "gemma2-9b":
        assert cfg.attn.softcap == 50.0 and cfg.logit_softcap == 30.0
        assert cfg.attn.n_kv == 8 and cfg.attn.n_heads == 16
    if arch == "qwen2-7b":
        assert cfg.attn.bias and cfg.attn.n_kv == 4 and cfg.attn.n_heads == 28
    if arch == "zamba2-7b":
        assert cfg.ssm.d_state == 64
    if arch == "rwkv6-3b":
        assert cfg.d_ff == 8960


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_instrumentation_off_matches_on_loss(arch):
    """Taps change nothing about the forward computation."""
    aspec, cfg, mod, params, batch = smoke_setup(arch)
    spec = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    tap = Tap(spec, acc=ExampleLayout(spec.n_groups).init(3))
    lv_on, _ = loss_fn(params, batch, tap)
    lv_off, _ = loss_fn(params, batch, NULL)
    np.testing.assert_allclose(lv_on, lv_off, rtol=1e-6)
