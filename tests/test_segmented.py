"""Segmented direct-norm kernel (kernels/segmented_norm.py) vs the XLA
scan/segment_sum oracle and a numpy naive oracle: fuzz parity across
ragged T / chunked features / capacity drops, explicit edge cases
(all-dropped blocks, n_examples=1), grouped-dispatch composites, the
two-sided segmented cost model, and end-to-end dispatch through the
expert taps. Runs in interpret mode on CPU so tier-1 exercises the
kernel without a TPU."""
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import norms as N
from repro.kernels import ops

RTOL = 1e-5


def _naive(h, z, seg, n):
    """Numpy oracle: materialize every segment's partial gradient."""
    h = np.asarray(h, np.float64)
    z = np.asarray(z, np.float64)
    seg = np.asarray(seg)
    out = np.zeros((n,))
    for j in range(n):
        rows = seg == j
        g = h[rows].T @ z[rows]
        out[j] = np.sum(g * g)
    return out


def _case(rng, t, p_in, p_out, n, drop_frac=0.2):
    h = jnp.asarray(rng.normal(size=(t, p_in)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, p_out)), jnp.float32)
    seg = rng.integers(0, n, size=(t,))
    dropped = rng.random(t) < drop_frac
    seg = np.where(dropped, n + rng.integers(0, 7, size=(t,)), seg)
    return h, z, jnp.asarray(seg, jnp.int32)


# --- fuzz parity ------------------------------------------------------------

FUZZ = [
    # ragged T (tile-misaligned), chunked p_in, small/large segments
    (7, 3, 5, 2), (64, 16, 16, 4), (130, 12, 40, 9), (100, 140, 36, 3),
    (256, 130, 258, 16), (33, 260, 7, 33), (129, 64, 129, 1),
    (512, 96, 24, 128),
]


@pytest.mark.parametrize("t,p_in,p_out,n", FUZZ)
def test_fuzz_parity_xla_pallas_naive(t, p_in, p_out, n):
    rng = np.random.default_rng(t * 1000 + p_in + p_out + n)
    h, z, seg = _case(rng, t, p_in, p_out, n)
    want = _naive(h, z, seg, n)
    got_x = N.stat_direct_segmented(h, z, seg, n, method="xla")
    got_p = N.stat_direct_segmented(h, z, seg, n, method="pallas")
    np.testing.assert_allclose(np.asarray(got_x), want, rtol=RTOL,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_p), want, rtol=RTOL,
                               atol=1e-6)


def test_fuzz_many_seeds_small():
    """Dense seed sweep at one awkward geometry (odd dims, heavy drops)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        t = int(rng.integers(1, 90))
        n = int(rng.integers(1, 12))
        h, z, seg = _case(rng, t, int(rng.integers(1, 50)),
                          int(rng.integers(1, 50)), n, drop_frac=0.5)
        want = _naive(h, z, seg, n)
        got = N.stat_direct_segmented(h, z, seg, n, method="pallas")
        np.testing.assert_allclose(np.asarray(got), want, rtol=RTOL,
                                   atol=1e-6, err_msg=f"seed={seed}")


# --- explicit edge cases ----------------------------------------------------

@pytest.mark.parametrize("method", ["xla", "pallas"])
def test_all_rows_dropped(method):
    """Every row capacity-dropped ⇒ exactly zero, on both backends."""
    h = jnp.ones((48, 8), jnp.float32)
    z = jnp.ones((48, 4), jnp.float32)
    seg = jnp.full((48,), 7, jnp.int32)
    got = N.stat_direct_segmented(h, z, seg, 3, method=method)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((3,)))


@pytest.mark.parametrize("method", ["xla", "pallas"])
def test_fully_dropped_token_block(method):
    """One whole token block (> token_block rows) dropped mid-stream:
    the oracle's scan and the kernel's inert runs must agree."""
    rng = np.random.default_rng(5)
    t, n = 96, 4
    h = jnp.asarray(rng.normal(size=(t, 10)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, 6)), jnp.float32)
    seg = np.asarray(rng.integers(0, n, size=(t,)), np.int32)
    seg[32:64] = n + 100  # a full 32-row block of drops
    seg = jnp.asarray(seg)
    got = N.stat_direct_segmented(h, z, seg, n, method=method,
                                  token_block=32)
    np.testing.assert_allclose(np.asarray(got), _naive(h, z, seg, n),
                               rtol=RTOL)


@pytest.mark.parametrize("method", ["xla", "pallas"])
def test_single_example(method):
    """n_examples=1: the clamped drop bucket is segment 1; nothing of
    the stat depends on implicit out-of-bounds scatter behavior."""
    rng = np.random.default_rng(6)
    h = jnp.asarray(rng.normal(size=(40, 12)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(40, 8)), jnp.float32)
    seg = np.zeros((40,), np.int32)
    seg[::3] = 9  # dropped
    seg = jnp.asarray(seg)
    got = N.stat_direct_segmented(h, z, seg, 1, method=method)
    np.testing.assert_allclose(np.asarray(got), _naive(h, z, seg, 1),
                               rtol=RTOL)


def test_degenerate_sizes():
    for method in ("xla", "pallas"):
        assert N.stat_direct_segmented(jnp.zeros((0, 4)), jnp.zeros((0, 3)),
                                       jnp.zeros((0,), jnp.int32), 2,
                                       method=method).shape == (2,)
    assert N.stat_direct_segmented(jnp.zeros((4, 4)), jnp.zeros((4, 3)),
                                   jnp.zeros((4,), jnp.int32), 0).shape == (0,)


def test_empty_segments_are_zero():
    """Segments with no rows report exactly 0 (not garbage from the
    sort padding)."""
    rng = np.random.default_rng(7)
    h = jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)
    seg = jnp.asarray(np.full((16,), 2, np.int32))  # only segment 2 present
    for method in ("xla", "pallas"):
        got = np.asarray(N.stat_direct_segmented(h, z, seg, 5, method=method))
        assert got[2] > 0
        np.testing.assert_array_equal(got[[0, 1, 3, 4]], np.zeros((4,)))


# --- grouped-dispatch composites (what the expert taps actually launch) -----

def test_grouped_composite_parity():
    """The flattened (group, expert, example) composite the Pallas path
    uses must equal the per-group vmap'd XLA form."""
    rng = np.random.default_rng(8)
    ng, e, c, d, f, bg = 2, 3, 8, 10, 6, 4
    x = jnp.asarray(rng.normal(size=(ng, e, c, d)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(ng, e, c, f)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, bg + 1, size=(ng, e, c)), jnp.int32)

    def xla_groups():
        def one(xg, zg, sg):
            comp = (jnp.arange(e, dtype=sg.dtype)[:, None] * (bg + 1)
                    + jnp.minimum(sg, bg))
            st = N.stat_direct_segmented(xg.reshape(e * c, d),
                                         zg.reshape(e * c, f),
                                         comp.reshape(e * c), e * (bg + 1),
                                         method="xla")
            return st.reshape(e, bg + 1)[:, :bg].sum(axis=0)
        return jax.vmap(one)(x, z, seg).reshape(ng * bg)

    ge = (jnp.arange(ng, dtype=jnp.int32)[:, None, None] * e
          + jnp.arange(e, dtype=jnp.int32)[None, :, None])
    comp = ge * (bg + 1) + jnp.minimum(seg, bg)
    flat = N.stat_direct_segmented(x.reshape(ng * e * c, d),
                                   z.reshape(ng * e * c, f),
                                   comp.reshape(-1), ng * e * (bg + 1),
                                   method="pallas")
    got = flat.reshape(ng, e, bg + 1)[:, :, :bg].sum(axis=1).reshape(ng * bg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(xla_groups()),
                               rtol=RTOL)


# --- dispatch / cost model --------------------------------------------------

def test_auto_routes_to_kernel_in_kernel_regime():
    """method='auto' + use_pallas must invoke ops.segmented_norm in the
    long-T / few-segment regime and the XLA scan (no kernel call) in
    the many-segment regime."""
    rng = np.random.default_rng(9)
    t, p, n = 1024, 64, 4
    h = jnp.asarray(rng.normal(size=(t, p)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, p)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, n, size=(t,)), jnp.int32)
    assert N.pick_segmented(t, p, p, n, use_pallas=True) == "pallas"
    with mock.patch.object(ops, "segmented_norm",
                           wraps=ops.segmented_norm) as hit:
        N.stat_direct_segmented(h, z, seg, n, method="auto", use_pallas=True)
        assert hit.call_count == 1
    # many tiny segments: run-splitting waste prices the kernel out
    n2 = 900
    assert N.pick_segmented(32, 8, 8, n2, use_pallas=True) == "xla"
    with mock.patch.object(ops, "segmented_norm",
                           wraps=ops.segmented_norm) as miss:
        N.stat_direct_segmented(h[:32, :8], z[:32, :8],
                                seg[:32], n2, method="auto", use_pallas=True)
        assert miss.call_count == 0
    # without use_pallas, auto never leaves the oracle
    assert N.pick_segmented(t, p, p, n) == "xla"


def test_segmented_cost_charges_padding_and_dummies():
    """The Pallas side must price its static launch: a 1-wide p_out pads
    to 128 lanes, and many segments inflate the work-item grid."""
    base = N.segmented_cost(256, 128, 128, 4, use_pallas=True)
    padded = N.segmented_cost(256, 128, 1, 4, use_pallas=True)
    assert padded == base  # 1 → 128 lanes: same launch
    many = N.segmented_cost(256, 128, 128, 256, use_pallas=True)
    assert many > 10 * base  # run-splitting dummies are charged
    # XLA side scales with n_seg through the scan carry
    assert N.segmented_cost(256, 128, 128, 256) > \
        N.segmented_cost(256, 128, 128, 4)


def test_expert_tap_pallas_end_to_end():
    """PexSpec(seg_method='pallas') reaches the kernel inside the expert
    custom_vjp backward and recovers exact norms."""
    from repro.core.engine import Engine
    from repro.core.taps import NULL, PexSpec
    rng = np.random.default_rng(10)
    e, c, d, f, b = 3, 8, 6, 5, 4
    x = jnp.asarray(rng.normal(size=(e, c, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(e, d, f)) * 0.3, jnp.float32)
    seg = jnp.asarray(rng.integers(0, b + 1, size=(e, c)), jnp.int32)

    def loss_fn(p, batch, tap):
        z = tap.dense_expert(batch["x"], p["w"], batch["seg"])
        per_slot = jnp.sum(jnp.square(z), axis=-1)  # (e, c)
        onehot = jax.nn.one_hot(jnp.minimum(batch["seg"], b), b + 1,
                                dtype=z.dtype)
        lv = jnp.einsum("ec,ecj->j", per_slot, onehot)[:b]
        return lv, {}

    # batch leaves need a leading B axis for the Engine; the loss uses
    # the shared buffer (slot → example attribution lives in seg)
    batch = {"x": jnp.stack([x] * b), "seg": jnp.stack([seg] * b)}

    def loss_b(p, bt, tap):
        return loss_fn(p, {"x": bt["x"][0], "seg": bt["seg"][0]}, tap)

    with mock.patch.object(ops, "segmented_norm",
                           wraps=ops.segmented_norm) as hit:
        res = Engine(PexSpec(seg_method="pallas")).value_and_norms(
            loss_b, {"w": w}, batch)
        assert hit.call_count >= 1
    ref = Engine(PexSpec(seg_method="xla")).value_and_norms(
        loss_b, {"w": w}, batch)
    np.testing.assert_allclose(np.asarray(res.sq_norms),
                               np.asarray(ref.sq_norms), rtol=RTOL)

    # naive: one backprop per example on its own loss entry
    def one(j):
        def lj(p):
            return loss_fn(p, {"x": x, "seg": seg}, NULL)[0][j]
        g = jax.grad(lj)({"w": w})
        return float(jnp.sum(jnp.square(g["w"])))

    want = np.asarray([one(j) for j in range(b)])
    np.testing.assert_allclose(np.asarray(jnp.sum(res.sq_norms, -1)), want,
                               rtol=1e-4)


# --- hypothesis property (optional dep, mirrors test_property.py) -----------

def test_hypothesis_fuzz_if_available():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (optional dev dep)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 80), st.integers(1, 40), st.integers(1, 40),
           st.integers(1, 10), st.integers(0, 2 ** 31 - 1))
    def prop(t, p_in, p_out, n, seed):
        rng = np.random.default_rng(seed)
        h, z, seg = _case(rng, t, p_in, p_out, n, drop_frac=0.3)
        want = _naive(h, z, seg, n)
        got = N.stat_direct_segmented(h, z, seg, n, method="pallas")
        np.testing.assert_allclose(np.asarray(got), want, rtol=RTOL,
                                   atol=1e-6)

    prop()
