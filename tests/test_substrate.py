"""Substrate: data determinism, optimizer, compression, checkpointing,
fault tolerance, importance sampling, HLO parsing, sharding utils."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import importance
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import sharding as shd
from repro.ft import elastic, heartbeat, inject
from repro.optim import adamw, grad_compress, schedule
from repro.roofline import hlo as hlo_parse


# --- data ------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, seq=16, global_batch=8, seed=3)
    p1 = SyntheticLM(cfg)
    p2 = SyntheticLM(cfg)
    for step in (0, 5, 17):
        a, b = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(a["ids"], b["ids"])
    assert not np.array_equal(p1.batch_at(0)["ids"], p1.batch_at(1)["ids"])


def test_pipeline_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=100, seq=8, global_batch=8)
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2)
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2)
    b0, b1 = h0.batch_at(0), h1.batch_at(0)
    assert b0["ids"].shape == (4, 8) and b1["ids"].shape == (4, 8)
    assert not np.array_equal(b0["ids"], b1["ids"])


# --- optimizer -------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, global_clip=None)
    state = adamw.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state = adamw.update(opt, state, params, g)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_global_clip_bounds_update_norm():
    params = {"w": jnp.zeros(4)}
    opt = adamw.AdamWConfig(lr=1.0, global_clip=1.0, weight_decay=0.0)
    state = adamw.init(params)
    g = {"w": jnp.full(4, 100.0)}
    _, state2 = adamw.update(opt, state, params, g)
    np.testing.assert_allclose(float(adamw.global_norm(state2.mu)),
                               0.1, rtol=1e-4)  # (1-b1)·clipped(g)


def test_schedule_warmup_cosine():
    f = schedule.linear_warmup_cosine(10, 100)
    assert float(f(jnp.array(0))) == 0.0
    np.testing.assert_allclose(float(f(jnp.array(10))), 1.0, rtol=1e-5)
    assert float(f(jnp.array(100))) <= 0.11


def test_grad_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    err = grad_compress.init_error(g)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for i in range(50):
        gi = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        cg, err = grad_compress.compress_decompress(gi, err)
        acc_true += np.asarray(gi["w"])
        acc_comp += np.asarray(cg["w"])
    # error feedback: accumulated compressed ≈ accumulated true
    denom = np.abs(acc_true).mean()
    assert np.abs(acc_comp - acc_true).mean() / denom < 0.05


# --- checkpoint ------------------------------------------------------------

def test_checkpoint_roundtrip_atomic_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"step": step}, block=True)
    assert mgr.all_steps() == [2, 3]           # retention
    restored, extra = mgr.restore(None, tree)
    assert extra["step"] == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = {"a": jnp.ones(8)}
    mgr.save(7, tree, block=True)
    shard = os.path.join(mgr._step_dir(7), "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\xde\xad")
    with pytest.raises(IOError):
        mgr.restore(7, tree)


def test_checkpoint_resume_is_bit_deterministic(tmp_path):
    """Train 4 steps straight vs 2 + restore + 2 — identical params."""
    from repro.core.taps import PexSpec
    from repro.data.pipeline import DataConfig
    from repro.models import registry
    from repro.nn.param import unbox
    from repro.train.trainer import TrainConfig, Trainer
    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    pex = PexSpec(enabled=True, method="gram")
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq=8, global_batch=4)
    ocfg = adamw.AdamWConfig(lr=1e-3)

    def mk(steps, ckpt_dir, ckpt_every):
        return Trainer(loss_fn, params, pex, ocfg,
                       TrainConfig(steps=steps, log_every=0,
                                   ckpt_every=ckpt_every, ckpt_dir=ckpt_dir),
                       dcfg)

    t_straight = mk(4, None, 10 ** 9)
    t_straight.train()
    d = str(tmp_path / "ck")
    t_a = mk(2, d, 2)
    t_a.train()
    t_b = mk(4, d, 10 ** 9)
    t_b.train(resume=True)
    for a, b in zip(jax.tree_util.tree_leaves(t_straight.params),
                    jax.tree_util.tree_leaves(t_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- fault tolerance ---------------------------------------------------------

def test_heartbeat_detects_dead_host(tmp_path):
    cfg = heartbeat.HeartbeatConfig(deadline_s=5.0)
    h0 = heartbeat.HeartbeatMonitor(str(tmp_path), 0, cfg)
    h1 = heartbeat.HeartbeatMonitor(str(tmp_path), 1, cfg)
    h0.beat(step=10, now=1000.0)
    h1.beat(step=10, now=990.0)          # stale
    assert h0.dead_hosts(now=1001.0) == [1]


def test_straggler_detection_mad():
    times = {i: 1.0 for i in range(8)}
    times[5] = 3.0
    assert heartbeat.detect_stragglers(times) == [5]
    assert heartbeat.detect_stragglers({0: 1.0, 1: 1.01}) == []


def test_elastic_contraction_plan():
    topo = elastic.Topology(n_hosts=64, devices_per_host=4, model_parallel=16)
    new = elastic.plan_contraction(topo, dead_hosts=[3, 17, 40])
    assert new.n_hosts == 32                       # largest pow2 ≤ 61
    assert elastic.mesh_shape(new) == (8, 16)
    with pytest.raises(RuntimeError):
        elastic.plan_contraction(
            elastic.Topology(4, 4, 16), dead_hosts=[0, 1, 2])


def test_elastic_reassign():
    hosts = list(range(8))
    assert elastic.reassign_data_hosts(hosts, dead=[2, 5], new_count=4) == \
        [0, 1, 3, 4]


def test_heartbeat_survey_tolerates_torn_files(tmp_path):
    """A dying host's torn/empty heartbeat is a dead host, not a
    crashed survey — the monitor must keep working while hosts fail."""
    cfg = heartbeat.HeartbeatConfig(deadline_s=5.0)
    mon = heartbeat.HeartbeatMonitor(str(tmp_path), 0, cfg)
    mon.beat(step=3, now=100.0)
    (tmp_path / "host_00001.json").write_text('{"step": 3, "ti')  # torn
    (tmp_path / "host_00002.json").write_text("")                 # empty
    (tmp_path / "host_junk.json").write_text("{}")       # not a heartbeat
    (tmp_path / "host_00004.json.tmp").write_text("{}")  # in-flight write
    s = mon.survey(now=101.0)
    assert set(s) == {0, 1, 2}
    assert s[0]["alive"]
    assert not s[1]["alive"] and "error" in s[1]
    assert not s[2]["alive"] and "error" in s[2]
    assert mon.dead_hosts(now=101.0) == [1, 2]


def test_checkpoint_writer_failure_surfaces_at_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones(4)}
    # block the async writer's tmp dir with a *file*: its makedirs
    # fails on the background thread, where an uncaught exception
    # would silently vanish
    open(os.path.join(str(tmp_path), "step_000000005.tmp"), "w").close()
    mgr.save(5, tree)                       # async: no error here
    with pytest.raises(OSError):
        mgr.wait()                          # ...it surfaces here
    mgr.save(6, tree, block=True)           # captured error was consumed
    assert mgr.latest_step() == 6


def test_checkpoint_restore_falls_back_past_corruption(tmp_path):
    import warnings as _w
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in (5, 6, 7):
        mgr.save(s, {"a": jnp.full(8, float(s))}, extra={"step": s},
                 block=True)
    assert inject.corrupt_newest_checkpoint(str(tmp_path)) == 7
    with pytest.warns(UserWarning, match="falling back"):
        tree, extra = mgr.restore(None, {"a": jnp.ones(8)})
    assert extra["step"] == 6 and mgr.last_restored_step == 6
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.full(8, 6.0))
    # truncate 6 as well: falls all the way to 5
    with open(os.path.join(mgr._step_dir(6), "shard_00000.npz"),
              "r+b") as f:
        f.truncate(4)
    with pytest.warns(UserWarning):
        _, extra = mgr.restore(None, {"a": jnp.ones(8)})
    assert extra["step"] == 5 and mgr.last_restored_step == 5
    # only when *no* committed step is restorable does restore raise
    with open(os.path.join(mgr._step_dir(5), "shard_00000.npz"),
              "r+b") as f:
        f.truncate(4)
    with pytest.raises(IOError):
        with _w.catch_warnings():
            _w.simplefilter("ignore")
            mgr.restore(None, {"a": jnp.ones(8)})


def test_checkpoint_sweeps_stale_tmp_on_construction(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"a": jnp.ones(2)}, block=True)
    inject.litter_tmp_dir(str(tmp_path), step=99)
    assert any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    with pytest.warns(UserWarning, match="sweeping"):
        mgr2 = CheckpointManager(str(tmp_path))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    assert mgr2.all_steps() == [3]          # committed data untouched


def test_elastic_contraction_exactly_at_floor():
    # pow2 survivors landing exactly on the model-parallel floor: legal
    topo = elastic.Topology(n_hosts=16, devices_per_host=1,
                            model_parallel=8)
    new = elastic.plan_contraction(topo, dead_hosts=list(range(8, 15)))
    assert new.n_hosts == 8 and new.n_devices == topo.model_parallel
    assert elastic.mesh_shape(new) == (1, 8)
    # raw device count passes (3·4 ≥ 12) but the pow2 rounding gives
    # 2 hosts = 8 devices < 12: must refuse, not under-provision
    with pytest.raises(RuntimeError, match="power-of-two"):
        elastic.plan_contraction(elastic.Topology(4, 4, 12),
                                 dead_hosts=[0])


def test_elastic_reassign_more_deaths_than_drops():
    hosts = list(range(8))
    # 5 deaths, but the plan only keeps 2: survivors in order
    assert elastic.reassign_data_hosts(hosts, dead=[0, 1, 2, 3, 4],
                                       new_count=2) == [5, 6]
    # fewer survivors than requested: return who's alive (caller halts)
    assert elastic.reassign_data_hosts(hosts, dead=list(range(7)),
                                       new_count=2) == [7]


def test_elastic_expansion_roundtrip():
    for n in (4, 8, 16):
        t = elastic.Topology(n, 2, 4)
        c = elastic.plan_contraction(t, dead_hosts=[0])
        assert c.n_hosts == n // 2
        # full pool back → the original pow2 topology, exactly
        assert elastic.plan_expansion(c, available_hosts=n) == t
        # partial pool → largest pow2 ≤ pool, never exceeding it
        assert elastic.plan_expansion(c, available_hosts=n - 1).n_hosts \
            == n // 2
    with pytest.raises(RuntimeError):
        elastic.plan_expansion(elastic.Topology(4, 1, 4),
                               available_hosts=3)   # pow2(3)=2 < mp=4
    with pytest.raises(RuntimeError):
        elastic.plan_expansion(elastic.Topology(4, 1, 1),
                               available_hosts=0)


# --- importance sampling -----------------------------------------------------

def test_importance_distribution_proportional_to_norm():
    sq = jnp.array([1.0, 4.0, 16.0, 0.0])
    p = importance.sampling_distribution(sq)
    np.testing.assert_allclose(p, np.array([1, 2, 4, 0]) / 7.0, rtol=1e-5)


def test_importance_weights_unbiased():
    rng = jax.random.PRNGKey(0)
    sq = jnp.asarray(np.random.default_rng(1).uniform(0.1, 4.0, 64) ** 2)
    vals = jnp.asarray(np.random.default_rng(2).normal(size=64))
    total = float(jnp.sum(vals))
    ests = []
    for i in range(600):
        rng, sub = jax.random.split(rng)
        s = importance.sample(sub, sq, 16, smoothing=0.3)
        ests.append(float(jnp.sum(s.weights * vals[s.indices])))
    se = np.std(ests) / np.sqrt(len(ests))
    assert abs(np.mean(ests) - total) < 4 * se  # unbiased within 4σ


def test_ess_diagnostic():
    assert float(importance.effective_sample_size(jnp.ones(10))) == \
        pytest.approx(10.0)


# --- hlo parsing -------------------------------------------------------------

def test_collective_bytes_parser():
    txt = """
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[64]{0} all-reduce(%x), to_apply=%add
  %x = f32[64]{0} constant(0)
  %cp = f32[8,8]{1,0} collective-permute(%y)
  %y = f32[8,8]{1,0} constant(0)
"""
    out = hlo_parse.collective_bytes(txt)
    assert out["all-gather"] == 128 * 256 * 2          # operand bytes
    assert out["all-reduce"] == 64 * 4
    assert out["collective-permute"] == 64 * 4
    counts = hlo_parse.collective_counts(txt)
    assert counts == {"all-gather": 1, "all-reduce": 1,
                      "collective-permute": 1}


# --- sharding utils ----------------------------------------------------------

def test_rules_spec_and_padding():
    assert shd.pad_to(28, 16) == 32
    assert shd.pad_to(32, 16) == 32
    with shd.use_rules(None, {"mlp": "model"}):
        s = shd.spec("batch", None, "mlp")
        assert s == jax.sharding.PartitionSpec(None, None, "model")
    # no active mesh → shard() is identity
    x = jnp.ones(3)
    assert shd.shard(x, "batch") is x


def test_adafactor_converges_and_state_is_factored():
    from repro.optim import adafactor
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 6)),
                               jnp.float32) * 3.0,
              "b": jnp.ones(6) * 2.0}
    cfg = adafactor.AdafactorConfig(lr=0.3)
    state = adafactor.init(params)
    # factored: row+col vectors, not full matrices
    assert state.vr["w"].shape == (8,) and state.vc["w"].shape == (6,)
    assert state.vr["b"].shape == (6,) and state.vc["b"].shape == ()
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])) +
                     jnp.sum(jnp.square(p["b"])))(params)
        params, state = adafactor.update(cfg, state, params, g)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05
    assert float(jnp.max(jnp.abs(params["b"]))) < 0.05
