"""Hypothesis property tests for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional dev dep)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import norms as N
from repro.kernels import ops, ref

DIM = st.integers(min_value=1, max_value=12)


@settings(max_examples=40, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(1, 10), pi=DIM, po=DIM,
       seed=st.integers(0, 2 ** 16))
def test_gram_equals_direct_equals_bruteforce(b, s, pi, po, seed):
    """All exact estimators agree with ‖H_jᵀZ̄_j‖²_F for any shape."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, po)), jnp.float32)
    brute = np.stack([((np.asarray(h[i]).T @ np.asarray(z[i])) ** 2).sum()
                      for i in range(b)])
    np.testing.assert_allclose(N.stat_gram(h, z), brute, rtol=1e-4)
    np.testing.assert_allclose(N.stat_direct(h, z, chunk=3), brute, rtol=1e-4)


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(1, 8), p=DIM,
       seed=st.integers(0, 2 ** 16))
def test_factorized_upper_bounds_exact(b, s, p, seed):
    """Mechanical §4 on flattened rows ≥ the exact norm (Cauchy–Schwarz);
    equality at s=1 — the paper's setting."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(b, s, p)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, p)), jnp.float32)
    fact = np.asarray(N.stat_factorized(h, z))
    exact = np.asarray(N.stat_gram(h, z))
    assert np.all(fact >= exact * (1 - 1e-5))
    if s == 1:
        np.testing.assert_allclose(fact, exact, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(t=st.integers(1, 24), pi=DIM, po=DIM, b=st.integers(1, 5),
       seed=st.integers(0, 2 ** 16))
def test_segmented_direct_bruteforce(t, pi, po, b, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(t, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(t, po)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, b + 1, size=(t,)), jnp.int32)
    got = N.stat_direct_segmented(h, z, seg, b, chunk_in=4, token_block=5)
    want = []
    for j in range(b):
        m = np.asarray(seg) == j
        g = np.asarray(h)[m].T @ np.asarray(z)[m]
        want.append((g ** 2).sum())
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(1, 10), d=DIM, v=st.integers(2, 9),
       seed=st.integers(0, 2 ** 16))
def test_embedding_segment_norm_bruteforce(b, s, d, v, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    z = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    got = N.stat_embedding(ids, z)
    want = np.zeros(b)
    for j in range(b):
        acc = {}
        for t in range(s):
            acc.setdefault(int(ids[j, t]), np.zeros(d))
            acc[int(ids[j, t])] += np.asarray(z[j, t])
        want[j] = sum((vv ** 2).sum() for vv in acc.values())
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 6), s=st.integers(1, 40), pi=st.integers(1, 80),
       po=st.integers(1, 80), seed=st.integers(0, 2 ** 16))
def test_pallas_gram_any_shape(b, s, pi, po, seed):
    """Kernel wrapper pads arbitrary shapes exactly."""
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.normal(size=(b, s, pi)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(b, s, po)), jnp.float32)
    np.testing.assert_allclose(ops.gram_norm(h, z), ref.gram_norm_ref(h, z),
                               rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 5), n=st.integers(1, 300), seed=st.integers(0, 2**16))
def test_pallas_rowsumsq_any_shape(b, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    np.testing.assert_allclose(ops.rowsumsq(x), ref.rowsumsq_ref(x), rtol=1e-5)


@settings(max_examples=80, deadline=None)
@given(n=st.integers(1, 64), dph=st.integers(1, 8),
       mp_pow=st.integers(0, 4), n_dead=st.integers(0, 63))
def test_elastic_contract_expand_roundtrip(n, dph, mp_pow, n_dead):
    """expand(contract(t)) ⊆ t: contraction lands on a runnable pow2
    topology at or above the model-parallel floor, and re-expanding on
    the full original pool never exceeds it (lands on pow2_floor(n))."""
    from repro.ft import elastic
    topo = elastic.Topology(n, dph, 2 ** mp_pow)
    dead = list(range(min(n_dead, n - 1)))
    try:
        c = elastic.plan_contraction(topo, dead)
    except RuntimeError:
        return                      # unrecoverable worlds may refuse
    assert c.n_hosts <= n - len(dead)
    assert c.n_hosts & (c.n_hosts - 1) == 0          # power of two
    assert c.n_devices >= topo.model_parallel        # runnable
    e = elastic.plan_expansion(c, n)
    assert c.n_hosts <= e.n_hosts <= n
    assert e.n_hosts == elastic._pow2_floor(n)
    assert (e.devices_per_host, e.model_parallel) == \
        (topo.devices_per_host, topo.model_parallel)
