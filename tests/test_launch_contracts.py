"""Kernel-launch contracts: the wrapper-side builders reproduce each
call site's real schedule and pass validation; seeded schedule bugs
(broken divisibility, VMEM blow-ups, non-f32 accumulators) are
caught."""
import jax.numpy as jnp
import pytest

from repro.kernels import contract as c
from repro.kernels import ops


def test_all_builders_validate_clean():
    contracts = [
        ops.gram_contract(8, 64, 256, 512),
        ops.gram_contract(8, 64, 256, 512, triangular=False),
        ops.direct_contract(8, 64, 256, 512),
        ops.segmented_contract(128, 256, 512, 33),
        ops.clip_scale_contract(8, 64, 512),
        ops.rowsumsq_contract(8, 4096),
        ops.rowsumsq_contract(3, 100),  # odd batch -> tile_b=1 path
    ]
    contracts += list(ops.attention_contracts(2, 8, 4, 512, 512, 64))
    for ct in contracts:
        assert c.validate(ct, "tpu") == [], (ct.kernel,
                                             c.validate(ct, "tpu"))


def test_odd_shapes_still_validate():
    # builders must pad exactly like the call sites do
    for ct in [ops.gram_contract(3, 7, 33, 65),
               ops.direct_contract(3, 7, 33, 65),
               ops.segmented_contract(7, 33, 65, 5),
               ops.clip_scale_contract(3, 7, 33),
               ops.rowsumsq_contract(5, 7)]:
        assert c.validate(ct, "tpu") == [], (ct.kernel,
                                             c.validate(ct, "tpu"))


def test_divisibility_violation_detected():
    ct = c.LaunchContract(
        kernel="bad", grid=(4,),
        blocks=(c.Block("x", (128, 100), jnp.float32),),
        divisibility=(c.Divisibility("p", 100, 64),))
    errs = c.validate(ct, "tpu")
    assert any("100" in e and "64" in e for e in errs)


def test_vmem_budget_violation_detected():
    # a 32 MiB double-buffered block cannot fit the 16 MiB budget
    ct = c.LaunchContract(
        kernel="hog", grid=(1,),
        blocks=(c.Block("x", (2048, 2048), jnp.float32),))
    errs = c.validate(ct, "tpu")
    assert any("VMEM" in e for e in errs)


def test_non_f32_accumulator_detected():
    ct = c.LaunchContract(
        kernel="badacc", grid=(1,),
        blocks=(c.Block("acc", (128, 128), jnp.bfloat16, kind="scratch",
                        accumulator=True),))
    errs = c.validate(ct, "tpu")
    assert any("accumulator" in e for e in errs)


def test_empty_grid_detected():
    ct = c.LaunchContract(kernel="degenerate", grid=(0, 4), blocks=())
    assert c.validate(ct, "tpu")


def test_vmem_estimate_counts_double_buffering():
    blk_io = c.Block("x", (128, 128), jnp.float32)
    blk_scratch = c.Block("a", (128, 128), jnp.float32, kind="scratch")
    ct = c.LaunchContract(kernel="k", grid=(1,),
                          blocks=(blk_io, blk_scratch))
    assert ct.vmem_bytes() == 2 * blk_io.bytes + blk_scratch.bytes


def test_seeded_bad_schedule_via_builder():
    """A hand-broken schedule -- chunk larger than the padded dim with
    non-divisible remainder -- must fail, proving the validator is not
    vacuously green."""
    from repro.kernels import gram_norm
    ct = gram_norm.launch_contract(8, 64, 250, 512, tile_s=64,
                                   chunk_in=96, chunk_out=512)
    errs = c.validate(ct, "tpu")
    assert errs, "expected a divisibility error for 250 % 96"
