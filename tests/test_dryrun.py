"""Dry-run regression: the production-mesh lowering path compiles for
reduced configs of every family, on both meshes, in a subprocess (the
512-host-device XLA flag must be set before jax init, so this cannot
run in-process with the rest of the suite)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=900)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-236b",
                                  "zamba2-7b", "rwkv6-3b",
                                  "seamless-m4t-medium"])
def test_smoke_dryrun_single_pod(arch):
    r = _run(["--smoke", "--arch", arch])
    assert r.returncode == 0, r.stdout + r.stderr


def test_smoke_dryrun_multi_pod():
    r = _run(["--smoke", "--arch", "qwen2-7b", "--multi-pod"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_smoke_dryrun_pex_spmd():
    """The dist.pex shard_map pipeline lowers on a 16-way data mesh."""
    r = _run(["--smoke", "--pex-spmd", "--arch", "llama3.2-1b"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_smoke_dryrun_pex_spmd_multi_pod():
    """... and with gradient psum over the ("pod", "data") axes."""
    r = _run(["--smoke", "--pex-spmd", "--arch", "qwen2-7b", "--multi-pod"])
    assert r.returncode == 0, r.stdout + r.stderr
