"""Import every repro.* submodule.

The seed shipped ten modules importing a package (repro.dist) that was
never committed; the damage surfaced as six unrelated-looking pytest
collection errors. This test turns any such regression into one
obvious failure naming the missing module.
"""
import importlib
import os
import pkgutil

import pytest

import repro

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _all_modules():
    mods = []
    for info in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        mods.append(info.name)
    return sorted(mods)


MODULES = _all_modules()


def test_package_tree_is_nonempty():
    assert len(MODULES) > 40, MODULES   # 60+ modules in the seed tree


@pytest.mark.parametrize("name", MODULES)
def test_importable(name):
    # entry-point modules (launch.dryrun, dist.selfcheck, ...) set
    # XLA_FLAGS at module top; importing them after jax init is
    # harmless — the env var just has no effect in this process.
    importlib.import_module(name)


def test_every_intra_repo_import_resolves():
    """Static sweep: every `repro.xxx` dotted name mentioned in an
    import statement must be an importable module or an attribute of
    one (catches imports hidden behind `if TYPE_CHECKING` or lazy
    wrappers that the runtime imports above would miss)."""
    import ast
    bad = []
    for root, _, files in os.walk(os.path.join(SRC, "repro")):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(root, fn)
            with open(path) as f:
                tree = ast.parse(f.read(), filename=path)
            for node in ast.walk(tree):
                names = []
                if isinstance(node, ast.Import):
                    names = [a.name for a in node.names
                             if a.name.startswith("repro.")]
                elif isinstance(node, ast.ImportFrom) and node.module \
                        and node.module.startswith("repro"):
                    names = [node.module]
                for name in names:
                    try:
                        importlib.import_module(name)
                    except ImportError:
                        bad.append((path, name))
    assert not bad, f"unresolvable intra-repo imports: {bad}"
